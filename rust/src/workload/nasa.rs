//! The NASA-KSC trace substitute (paper §5.2.2, Fig 6).
//!
//! The paper replays a 2-day subset of the NASA Kennedy Space Center WWW
//! logs (July 1995), bucketed per minute and scaled so the peak fits the
//! edge testbed. That dataset is not redistributable here, so
//! [`nasa_synthetic`] generates a trace with the same *shape*: two diurnal
//! cycles with an afternoon peak, a deep overnight trough (day/night ratio
//! ≈ 3.5x), short-timescale Poisson jitter, and occasional bursts — the
//! properties that actually drive autoscaler behaviour. If you have the
//! real logs, preprocess them to per-minute counts (one integer per line)
//! and feed them through [`load_minute_counts`] instead.

use crate::util::rng::Pcg64;
use std::f64::consts::PI;
use std::path::Path;

/// Shape parameters for the synthetic NASA-like trace.
#[derive(Debug, Clone, Copy)]
pub struct NasaTraceConfig {
    /// Trace length in minutes (paper: 2 days).
    pub minutes: usize,
    /// Peak requests/minute after scaling (paper: scaled so the peak does
    /// not exceed the edge capacity).
    pub peak_per_minute: f64,
    /// Trough-to-peak ratio (NASA KSC shows ~0.25–0.35 overnight).
    pub trough_ratio: f64,
    /// Hour of the daily peak (local time; KSC logs peak mid-afternoon).
    pub peak_hour: f64,
    /// Relative short-term noise (std of multiplicative jitter).
    pub noise: f64,
    /// Expected number of burst events per day.
    pub bursts_per_day: f64,
    pub seed: u64,
}

impl Default for NasaTraceConfig {
    fn default() -> Self {
        NasaTraceConfig {
            minutes: 2 * 24 * 60,
            // Scaled so the peak sweeps the edge pools through their full
            // replica range while the cloud Eigen pool stays at (but
            // within) its Table-2 capacity — the paper's "adjusted to a
            // proper scale so that the peak workload does not exceed
            // resource limitations".
            peak_per_minute: 260.0,
            trough_ratio: 0.2,
            peak_hour: 15.0,
            noise: 0.10,
            bursts_per_day: 3.0,
            seed: 1995,
        }
    }
}

/// Generate per-minute request counts with the NASA trace's shape.
pub fn nasa_synthetic(cfg: &NasaTraceConfig) -> Vec<f64> {
    let mut rng = Pcg64::new(cfg.seed, 1995);
    let mut counts = Vec::with_capacity(cfg.minutes);

    // Pre-draw burst windows: (start_minute, length_minutes, amplitude).
    let days = cfg.minutes as f64 / 1440.0;
    let n_bursts = rng.poisson(cfg.bursts_per_day * days) as usize;
    let bursts: Vec<(usize, usize, f64)> = (0..n_bursts)
        .map(|_| {
            let start = rng.below(cfg.minutes as u64) as usize;
            let len = rng.int_range(5, 30) as usize;
            let amp = rng.range(1.3, 2.0);
            (start, len, amp)
        })
        .collect();

    // Slow day-to-day drift (the two NASA days differ slightly).
    let day_gain: Vec<f64> = (0..days.ceil() as usize + 1)
        .map(|_| rng.range(0.9, 1.1))
        .collect();

    for m in 0..cfg.minutes {
        let hour = (m as f64 / 60.0) % 24.0;
        // Diurnal base: cosine dipped at (peak_hour + 12) mod 24.
        let phase = (hour - cfg.peak_hour) / 24.0 * 2.0 * PI;
        let diurnal = 0.5 * (1.0 + phase.cos()); // 1 at peak, 0 at trough
        let base = cfg.trough_ratio + (1.0 - cfg.trough_ratio) * diurnal;

        let mut v = cfg.peak_per_minute * base * day_gain[m / 1440];
        for &(start, len, amp) in &bursts {
            if m >= start && m < start + len {
                v *= amp;
            }
        }
        // Multiplicative jitter + Poisson integerization.
        let jittered = (v * (1.0 + cfg.noise * rng.normal())).max(0.0);
        counts.push(rng.poisson(jittered) as f64);
    }
    counts
}

/// A UTF-8 byte-order mark, as some Windows-exported traces start with
/// one. `char::is_whitespace` does not cover it, so `trim` alone leaves
/// it glued to the first count.
const BOM: char = '\u{feff}';

/// One line of a trace file, normalized: BOM/CRLF/whitespace trimmed and
/// anything from an (inline or full-line) `#` comment on dropped.
/// Returns `None` for lines with no payload.
fn trace_payload(line: &str) -> Option<&str> {
    let line = line.split('#').next().unwrap_or("");
    let line = line.trim_matches(|c: char| c.is_whitespace() || c == BOM);
    if line.is_empty() {
        None
    } else {
        Some(line)
    }
}

/// Load per-minute counts from a preprocessed text file (one count per
/// line) — the path for users who have the real NASA logs. Tolerates
/// the usual export noise: CRLF line endings, a leading BOM, leading and
/// trailing blank lines, and `#` comments (full-line or inline after a
/// count).
pub fn load_minute_counts(path: &Path) -> crate::Result<Vec<f64>> {
    use anyhow::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut counts = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let Some(line) = trace_payload(line) else {
            continue;
        };
        let v: f64 = line
            .parse()
            .with_context(|| format!("bad count on line {}", i + 1))?;
        anyhow::ensure!(v >= 0.0 && v.is_finite(), "negative count on line {}", i + 1);
        counts.push(v);
    }
    anyhow::ensure!(!counts.is_empty(), "empty trace file");
    Ok(counts)
}

/// Load an Azure-Functions-style per-minute invocation CSV and collapse
/// it to one aggregate per-minute trace.
///
/// The Azure Functions 2019 dataset ships one row per function: a few
/// identity columns (owner/app/function hashes, trigger type) followed
/// by integer-named columns `1..=1440`, one invocation count per minute
/// of the day. This adapter finds the first integer-named header column,
/// treats it and everything after as the minute axis, and sums the
/// counts across all function rows — producing the same shape
/// [`load_minute_counts`] does, ready for trace replay. The same export
/// noise is tolerated (CRLF, BOM, blank lines, `#` comments).
pub fn load_azure_minute_counts(path: &Path) -> crate::Result<Vec<f64>> {
    use anyhow::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut rows = text
        .lines()
        .enumerate()
        .filter_map(|(i, l)| trace_payload(l).map(|p| (i, p)));

    let (_, header) = rows.next().context("empty trace file")?;
    let fields: Vec<&str> = header.split(',').map(str::trim).collect();
    let first_minute = fields
        .iter()
        .position(|f| f.parse::<u64>().is_ok())
        .context("no integer-named minute columns in the CSV header")?;
    let n_minutes = fields.len() - first_minute;

    let mut totals = vec![0.0; n_minutes];
    let mut n_rows = 0usize;
    for (i, row) in rows {
        let cells: Vec<&str> = row.split(',').map(str::trim).collect();
        anyhow::ensure!(
            cells.len() == fields.len(),
            "row on line {} has {} columns, header has {}",
            i + 1,
            cells.len(),
            fields.len()
        );
        for (m, cell) in cells[first_minute..].iter().enumerate() {
            let v: f64 = cell
                .parse()
                .with_context(|| format!("bad count on line {}", i + 1))?;
            anyhow::ensure!(v >= 0.0 && v.is_finite(), "negative count on line {}", i + 1);
            totals[m] += v;
        }
        n_rows += 1;
    }
    anyhow::ensure!(n_rows > 0, "no function rows after the CSV header");
    Ok(totals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_has_diurnal_shape() {
        let cfg = NasaTraceConfig::default();
        let counts = nasa_synthetic(&cfg);
        assert_eq!(counts.len(), 2880);

        // Average around the configured peak hour vs the trough.
        let hour_mean = |h: f64| -> f64 {
            let m0 = (h * 60.0) as usize;
            (0..60).map(|i| counts[m0 + i]).sum::<f64>() / 60.0
        };
        let peak_day1 = hour_mean(cfg.peak_hour);
        let trough_day1 = hour_mean((cfg.peak_hour + 12.0) % 24.0);
        assert!(
            peak_day1 > 2.0 * trough_day1,
            "peak {peak_day1} vs trough {trough_day1}"
        );
        // Peak roughly at configured scale.
        assert!(peak_day1 > cfg.peak_per_minute * 0.6);
    }

    #[test]
    fn synthetic_deterministic_per_seed() {
        let cfg = NasaTraceConfig::default();
        assert_eq!(nasa_synthetic(&cfg), nasa_synthetic(&cfg));
        let other = NasaTraceConfig {
            seed: 7,
            ..NasaTraceConfig::default()
        };
        assert_ne!(nasa_synthetic(&cfg), nasa_synthetic(&other));
    }

    #[test]
    fn synthetic_nonnegative() {
        let counts = nasa_synthetic(&NasaTraceConfig::default());
        assert!(counts.iter().all(|&c| c >= 0.0));
    }

    #[test]
    fn loads_counts_file() {
        let dir = std::env::temp_dir().join("ppa_nasa_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("counts.txt");
        std::fs::write(&path, "# header\n10\n20\n\n30\n").unwrap();
        let counts = load_minute_counts(&path).unwrap();
        assert_eq!(counts, vec![10.0, 20.0, 30.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerates_export_noise() {
        let dir = std::env::temp_dir().join("ppa_nasa_test_noise");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("noisy.txt");
        // BOM, CRLF endings, inline comment, indentation, trailing blank
        // lines — the usual spreadsheet-export artifacts.
        std::fs::write(&path, "\u{feff}# header\r\n10\r\n 20 # afternoon\r\n\r\n30\r\n\r\n\r\n")
            .unwrap();
        let counts = load_minute_counts(&path).unwrap();
        assert_eq!(counts, vec![10.0, 20.0, 30.0]);
        // A BOM directly on the first count must not break parsing.
        std::fs::write(&path, "\u{feff}5\n6\n").unwrap();
        assert_eq!(load_minute_counts(&path).unwrap(), vec![5.0, 6.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn azure_csv_sums_function_rows_per_minute() {
        let dir = std::env::temp_dir().join("ppa_nasa_test_azure");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("invocations.csv");
        // Azure Functions 2019 shape: hash columns + trigger, then one
        // column per minute of the day (trimmed to 4 minutes here).
        std::fs::write(
            &path,
            "\u{feff}HashOwner,HashApp,HashFunction,Trigger,1,2,3,4\r\n\
             o1,a1,f1,http,0,3,1,0\r\n\
             # a stray comment row\r\n\
             o1,a1,f2,timer,2,0,0,5\r\n\
             o2,a2,f3,http,1,1,1,1\r\n",
        )
        .unwrap();
        let counts = load_azure_minute_counts(&path).unwrap();
        assert_eq!(counts, vec![3.0, 4.0, 2.0, 6.0]);

        // Ragged rows and headers without minute columns are rejected.
        std::fs::write(&path, "HashOwner,Trigger,1,2\r\no1,http,1\r\n").unwrap();
        assert!(load_azure_minute_counts(&path).is_err());
        std::fs::write(&path, "HashOwner,Trigger\r\no1,http\r\n").unwrap();
        assert!(load_azure_minute_counts(&path).is_err());
        std::fs::write(&path, "HashOwner,Trigger,1,2\r\n").unwrap();
        assert!(load_azure_minute_counts(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_file() {
        let dir = std::env::temp_dir().join("ppa_nasa_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "abc\n").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::write(&path, "-5\n").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::write(&path, "").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

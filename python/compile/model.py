"""L2 — the PPA forecaster model in JAX, calling the L1 Pallas kernels.

The paper's predictive model (§5.3.1): a 50-unit LSTM layer followed by a
ReLU-activated dense layer with 5 outputs, trained with MSE loss and the
Adam optimizer. Input metric vector (protocol §4.2.2):
``[CPU, RAM, NetIn, NetOut, CustomMetric(req rate)]``.

Everything here is build-time Python: ``compile.aot`` lowers the four entry
points (init / predict / train_step / train_epoch) to HLO text once, and
the rust coordinator executes the artifacts via PJRT. Python is never on
the control path.

Parameter layout (flat, positional — the rust side mirrors this order):
  w  : (I+H, 4H)  fused LSTM gate weight, gate order [i, f, g, o]
  b  : (4H,)      fused gate bias (forget-gate slice initialized to 1.0)
  wd : (H, O)     dense weight
  bd : (O,)       dense bias
Adam state is one (m, v) pair per parameter plus a scalar step count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.lstm_cell import lstm_cell

# Model hyperparameters — fixed by the paper (§5.3.1) and baked into the
# AOT artifacts; compile.aot writes them to artifacts/manifest.json so the
# rust runtime can size its buffers without parsing HLO.
INPUT_DIM = 5
HIDDEN_DIM = 50
OUTPUT_DIM = 5
SEQ_LEN = 8  # metric-history window fed to the LSTM (paper protocol: >= 1)
BATCH = 32
EPOCH_BATCHES = 16  # minibatches fused into one train_epoch dispatch

ADAM_LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

PARAM_NAMES = ("w", "b", "wd", "bd")
PARAM_SHAPES = {
    "w": (INPUT_DIM + HIDDEN_DIM, 4 * HIDDEN_DIM),
    "b": (4 * HIDDEN_DIM,),
    "wd": (HIDDEN_DIM, OUTPUT_DIM),
    "bd": (OUTPUT_DIM,),
}


# ---------------------------------------------------------------------------
# Initialization (Keras-equivalent: glorot_uniform kernels, unit forget bias)
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[1]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def init_params(seed):
    """Seeded parameter init. ``seed`` is a uint32 scalar (traced input)."""
    key = jax.random.PRNGKey(seed)
    k_w, k_wd = jax.random.split(key)
    w = _glorot(k_w, PARAM_SHAPES["w"])
    # unit_forget_bias: the f-gate slice starts at 1.0 (Keras default).
    b = jnp.zeros(PARAM_SHAPES["b"], jnp.float32)
    b = b.at[HIDDEN_DIM : 2 * HIDDEN_DIM].set(1.0)
    wd = _glorot(k_wd, PARAM_SHAPES["wd"])
    bd = jnp.zeros(PARAM_SHAPES["bd"], jnp.float32)
    return w, b, wd, bd


def zeros_like_params():
    return tuple(jnp.zeros(PARAM_SHAPES[n], jnp.float32) for n in PARAM_NAMES)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forecast(params, x):
    """Model forward pass.

    Args:
      params: (w, b, wd, bd) tuple.
      x: (B, T, I) scaled metric windows.

    Returns:
      (B, O) predicted next-step metric vector (ReLU-activated — metrics
      are non-negative after the rust-side scaler's inverse transform).
    """
    w, b, wd, bd = params
    batch = x.shape[0]
    h = jnp.zeros((batch, HIDDEN_DIM), x.dtype)
    c = jnp.zeros((batch, HIDDEN_DIM), x.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(x_t, h, c, w, b)
        return (h, c), None

    xs = jnp.swapaxes(x, 0, 1)  # (T, B, I)
    (h, _c), _ = jax.lax.scan(step, (h, c), xs)
    return jax.nn.relu(jnp.dot(h, wd) + bd)


def loss_fn(params, xb, yb):
    pred = forecast(params, xb)
    return jnp.mean((pred - yb) ** 2)


# ---------------------------------------------------------------------------
# Adam (from scratch — optimizer state is explicit so rust owns it between
# dispatches)
# ---------------------------------------------------------------------------


def adam_update(params, grads, m, v, t):
    """One Adam step. ``t`` is the 1-based step count AFTER this update."""
    t_new = t + 1.0
    b1t = ADAM_B1**t_new
    b2t = ADAM_B2**t_new
    new_params, new_m, new_v = [], [], []
    for p, g, m_i, v_i in zip(params, grads, m, v):
        m_n = ADAM_B1 * m_i + (1.0 - ADAM_B1) * g
        v_n = ADAM_B2 * v_i + (1.0 - ADAM_B2) * (g * g)
        m_hat = m_n / (1.0 - b1t)
        v_hat = v_n / (1.0 - b2t)
        new_params.append(p - ADAM_LR * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS))
        new_m.append(m_n)
        new_v.append(v_n)
    return tuple(new_params), tuple(new_m), tuple(new_v), t_new


def train_step(params, m, v, t, xb, yb):
    """One fused fwd+bwd+Adam step on a (B, T, I)/(B, O) minibatch."""
    loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
    params, m, v, t = adam_update(params, grads, m, v, t)
    return params, m, v, t, loss


def train_epoch(params, m, v, t, xs, ys):
    """K fused train steps in one dispatch.

    Args:
      xs: (K, B, T, I) stacked minibatches.
      ys: (K, B, O) stacked targets.

    Returns:
      updated (params, m, v, t) and the mean loss across the K steps.
    """

    def body(carry, batch):
        params, m, v, t = carry
        xb, yb = batch
        params, m, v, t, loss = train_step(params, m, v, t, xb, yb)
        return (params, m, v, t), loss

    (params, m, v, t), losses = jax.lax.scan(body, (params, m, v, t), (xs, ys))
    return params, m, v, t, jnp.mean(losses)


# ---------------------------------------------------------------------------
# Flat AOT entry points (positional args mirror the rust runtime's order)
# ---------------------------------------------------------------------------


def predict_entry(w, b, wd, bd, x):
    return (forecast((w, b, wd, bd), x),)


def init_entry(seed):
    return init_params(seed)


def train_step_entry(w, b, wd, bd, mw, mb, mwd, mbd, vw, vb, vwd, vbd, t, xb, yb):
    params, m, v, t, loss = train_step(
        (w, b, wd, bd), (mw, mb, mwd, mbd), (vw, vb, vwd, vbd), t, xb, yb
    )
    return (*params, *m, *v, t, loss)


def train_epoch_entry(w, b, wd, bd, mw, mb, mwd, mbd, vw, vb, vwd, vbd, t, xs, ys):
    params, m, v, t, loss = train_epoch(
        (w, b, wd, bd), (mw, mb, mwd, mbd), (vw, vb, vwd, vbd), t, xs, ys
    )
    return (*params, *m, *v, t, loss)

//! A minimal Rust tokenizer — just enough lexical fidelity for the rule
//! checks in [`crate::rules`].
//!
//! The offline crate set has no `syn`/`proc-macro2`, so detlint carries
//! its own lexer. It understands exactly the constructs that would
//! otherwise produce false positives in a grep-style scan:
//!
//! * line comments (captured, for suppression pragmas) and nested block
//!   comments (skipped),
//! * string literals, byte strings, raw strings (`r#"…"#`, any guard
//!   depth) and char literals — so `"Instant::now"` inside a string is
//!   not a token,
//! * lifetimes vs. char literals (`'a` vs. `'a'`),
//! * raw identifiers (`r#type`),
//! * `::` as a single punctuation token (path patterns key off it).
//!
//! Everything else (numbers, identifiers, single-char punctuation) is
//! deliberately loose: the rules only ever match identifier text and a
//! few punctuation neighbors, never full expression structure.

/// Token class. String-like literals all collapse into [`TokKind::Str`];
/// the rules never need to look inside them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Str,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One `//` line comment. `doc` marks `///` and `//!` forms (which are
/// documentation, never suppression pragmas); `trailing` marks comments
/// that share their line with preceding code.
#[derive(Debug, Clone)]
pub struct Comment {
    /// Comment body with the leading slashes (and doc `!`) stripped.
    pub text: String,
    pub line: u32,
    pub doc: bool,
    pub trailing: bool,
}

/// Tokenizer output: the token stream plus every line comment.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unterminated constructs simply run to
/// end of input (the lint pass prefers resilience over strictness).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0usize;

    let at = |idx: usize| -> char {
        if idx < n {
            b[idx]
        } else {
            '\0'
        }
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && at(i + 1) == '/' {
            let mut j = i + 2;
            let mut doc = false;
            if at(j) == '/' && at(j + 1) != '/' {
                doc = true; // `///` outer doc (but `////…` is plain)
                j += 1;
            } else if at(j) == '!' {
                doc = true; // `//!` inner doc
                j += 1;
            }
            let start = j;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            let trailing = out.toks.last().is_some_and(|t| t.line == line);
            out.comments.push(Comment {
                text: b[start..j].iter().collect(),
                line,
                doc,
                trailing,
            });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && at(i + 1) == '*' {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '/' && at(j + 1) == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && at(j + 1) == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // String literal.
        if c == '"' {
            let (j, nl) = scan_quoted(&b, i);
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            line += nl;
            i = j;
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            let next = at(i + 1);
            if next == '\\' {
                // Escaped char literal: scan to the closing quote.
                let (j, nl) = scan_char(&b, i);
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
            if is_ident_start(next) && at(i + 2) != '\'' {
                // Lifetime: `'a`, `'static`, `'_`.
                let mut j = i + 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[i..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // Plain char literal: `'a'`, `'('`, `'0'`.
            let (j, nl) = scan_char(&b, i);
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            line += nl;
            i = j;
            continue;
        }
        // Identifier (and the raw-string / raw-ident lookahead).
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            let text: String = b[i..j].iter().collect();
            // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
            if text == "r" || text == "b" || text == "br" {
                let mut k = j;
                let mut hashes = 0usize;
                if text != "b" {
                    while at(k) == '#' {
                        hashes += 1;
                        k += 1;
                    }
                }
                if at(k) == '"' {
                    let (end, nl) = if text == "b" {
                        scan_quoted(&b, k)
                    } else {
                        scan_raw(&b, k, hashes)
                    };
                    out.toks.push(Tok {
                        kind: TokKind::Str,
                        text: String::new(),
                        line,
                    });
                    line += nl;
                    i = end;
                    continue;
                }
                // Raw identifier `r#type`.
                if text == "r" && hashes == 1 && is_ident_start(at(k)) {
                    let mut e = k + 1;
                    while e < n && is_ident_continue(b[e]) {
                        e += 1;
                    }
                    out.toks.push(Tok {
                        kind: TokKind::Ident,
                        text: b[k..e].iter().collect(),
                        line,
                    });
                    i = e;
                    continue;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text,
                line,
            });
            i = j;
            continue;
        }
        // Number (loose: suffixes and float tails ride along).
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            if at(j) == '.' && at(j + 1).is_ascii_digit() {
                j += 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Number,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // Punctuation: `::` is one token, everything else one char.
        if c == ':' && at(i + 1) == ':' {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: "::".to_string(),
                line,
            });
            i += 2;
            continue;
        }
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    out
}

/// Scan a `"…"` literal starting at the opening quote; returns
/// (index past the closing quote, newlines crossed).
fn scan_quoted(b: &[char], start: usize) -> (usize, u32) {
    let n = b.len();
    let mut nl = 0u32;
    let mut j = start + 1;
    while j < n {
        match b[j] {
            // An escape may hide a newline (`\` line continuation).
            '\\' => {
                if b.get(j + 1) == Some(&'\n') {
                    nl += 1;
                }
                j += 2;
            }
            '"' => return (j + 1, nl),
            '\n' => {
                nl += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (n, nl)
}

/// Scan a `'…'` char literal starting at the opening quote.
fn scan_char(b: &[char], start: usize) -> (usize, u32) {
    let n = b.len();
    let mut nl = 0u32;
    let mut j = start + 1;
    while j < n {
        match b[j] {
            '\\' => {
                if b.get(j + 1) == Some(&'\n') {
                    nl += 1;
                }
                j += 2;
            }
            '\'' => return (j + 1, nl),
            '\n' => {
                nl += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (n, nl)
}

/// Scan a raw string whose opening quote is at `quote`, guarded by
/// `hashes` hash marks.
fn scan_raw(b: &[char], quote: usize, hashes: usize) -> (usize, u32) {
    let n = b.len();
    let mut nl = 0u32;
    let mut j = quote + 1;
    while j < n {
        if b[j] == '\n' {
            nl += 1;
            j += 1;
            continue;
        }
        if b[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && k < n && b[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (k, nl);
            }
        }
        j += 1;
    }
    (n, nl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_hide_their_contents() {
        let src = r##"let s = "Instant::now()"; let r = r#"HashMap "quoted" inner"#;"##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "s", "let", "r"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let lexed = lex(src);
        let lifetimes: Vec<_> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let strs = lexed.toks.iter().filter(|t| t.kind == TokKind::Str).count();
        assert_eq!(strs, 1, "'x' is a char literal");
    }

    #[test]
    fn nested_block_comments_and_line_tracking() {
        let src = "a\n/* one /* two */ still */\nb";
        let lexed = lex(src);
        assert_eq!(lexed.toks.len(), 2);
        assert_eq!(lexed.toks[0].line, 1);
        assert_eq!(lexed.toks[1].line, 3);
    }

    #[test]
    fn comments_capture_doc_and_trailing_flags() {
        let src = "/// doc line\nlet x = 1; // trailing note\n// standalone\n";
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 3);
        assert!(lexed.comments[0].doc && !lexed.comments[0].trailing);
        assert!(!lexed.comments[1].doc && lexed.comments[1].trailing);
        assert!(!lexed.comments[2].doc && !lexed.comments[2].trailing);
        assert_eq!(lexed.comments[2].text.trim(), "standalone");
    }

    #[test]
    fn path_sep_is_one_token() {
        let toks = lex("std::time::Instant").toks;
        let texts: Vec<_> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["std", "::", "time", "::", "Instant"]);
    }

    #[test]
    fn raw_identifiers_resolve_to_their_name() {
        let ids = idents("let r#type = 1;");
        assert_eq!(ids, vec!["let", "type"]);
    }

    #[test]
    fn numeric_range_does_not_eat_dots() {
        let toks = lex("0..NUM_BUCKETS");
        let texts: Vec<_> = toks.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["0", ".", ".", "NUM_BUCKETS"]);
    }
}

//! Golden equivalence for the chaos plane's no-op contract.
//!
//! Installing an **empty** `FaultPlan` must be a strict no-op: no RNG
//! stream is constructed, no fault event is scheduled, and the run
//! evolves **bit-identically** to a world where `install_chaos` was
//! never called — same decision logs, same event counts, same
//! response-stream fingerprints, same RIR trajectories. These tests pin
//! that contract on the paper grid and a city-8 grid, under both the
//! HPA and a live-ARMA PPA, plus the sweep-cell harness (whose fault
//! counter columns must stay all-zero under the `none` plan).

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::{Autoscaler, Hpa, Ppa, PpaConfig};
use ppa_edge::cluster::FaultPlan;
use ppa_edge::config::{city_scenario_presets, paper_cluster, ClusterConfig, Topology};
use ppa_edge::experiments::{run_cell, AutoscalerKind, SimWorld};
use ppa_edge::forecast::ArmaForecaster;
use ppa_edge::sim::{CoreKind, MIN};
use ppa_edge::workload::{Generator, RandomAccessGen};

#[derive(Clone, Copy)]
enum ScalerKind {
    Hpa,
    /// ARMA PPA trained online by a live 10-minute update loop.
    PpaArma,
}

fn build_scaler(kind: ScalerKind) -> Box<dyn Autoscaler> {
    match kind {
        ScalerKind::Hpa => Box::new(Hpa::with_defaults()),
        ScalerKind::PpaArma => Box::new(Ppa::new(
            PpaConfig {
                update_interval: 10 * MIN,
                ..PpaConfig::default()
            },
            Box::new(ArmaForecaster::new()),
        )),
    }
}

/// Run the same (cluster, generators, scaler, seed) world twice — once
/// untouched, once with `install_chaos(FaultPlan::none())` — and assert
/// bit-identical evolution.
fn assert_empty_plan_is_noop(
    cfg: &ClusterConfig,
    gens: &dyn Fn() -> Vec<Generator>,
    kind: ScalerKind,
    seed: u64,
    minutes: u64,
) {
    let run_one = |install_empty_plan: bool| -> SimWorld {
        let mut w = SimWorld::build(cfg, TaskCosts::default(), seed);
        w.record_decisions();
        for g in gens() {
            w.add_generator(g);
        }
        for svc in 0..w.app.services.len() {
            w.add_scaler(build_scaler(kind), svc);
        }
        if install_empty_plan {
            w.install_chaos(&FaultPlan::none(), seed, minutes * MIN);
        }
        w.run_until(minutes * MIN);
        w
    };
    let clean = run_one(false);
    let noop = run_one(true);

    assert!(clean.events_processed > 100, "world should be busy");
    assert_eq!(
        clean.events_processed, noop.events_processed,
        "event counts diverged"
    );
    assert_eq!(clean.app.completed(), noop.app.completed());
    assert_eq!(
        clean.app.stats.fingerprint(),
        noop.app.stats.fingerprint(),
        "response streams diverged"
    );
    for svc in 0..clean.app.services.len() {
        assert_eq!(
            clean.decisions_for(svc),
            noop.decisions_for(svc),
            "service {svc}: decision logs diverged"
        );
    }
    assert_eq!(clean.rir_log.len(), noop.rir_log.len());

    // And the empty plan reports itself as exactly nothing.
    let c = noop.chaos_summary(minutes * MIN);
    assert_eq!(c.crashes, 0);
    assert_eq!(c.rejoins, 0);
    assert_eq!(c.pods_killed, 0);
    assert_eq!(c.pods_rescheduled, 0);
    assert_eq!(c.crash_loops, 0);
    assert_eq!(c.downtime, 0);
    assert!(c.cold_start_p95().is_nan(), "no pod chaos, no cold-start stats");
}

fn paper_generators() -> Vec<Generator> {
    vec![
        Generator::RandomAccess(RandomAccessGen::new(1)),
        Generator::RandomAccess(RandomAccessGen::new(2)),
    ]
}

#[test]
fn golden_chaos_noop_paper_hpa() {
    let cfg = paper_cluster();
    assert_empty_plan_is_noop(&cfg, &paper_generators, ScalerKind::Hpa, 2021, 20);
}

#[test]
fn golden_chaos_noop_paper_ppa_arma() {
    let cfg = paper_cluster();
    assert_empty_plan_is_noop(&cfg, &paper_generators, ScalerKind::PpaArma, 7, 15);
}

#[test]
fn golden_chaos_noop_city8_grid() {
    // A small city-8 grid: 2 scenarios x both scalers.
    let topo = Topology::EdgeCity {
        zones: 8,
        workers_per_zone: 2,
        mix: Default::default(),
    };
    let cfg = topo.cluster();
    for (_, scenario) in &city_scenario_presets(8)[..2] {
        for kind in [ScalerKind::Hpa, ScalerKind::PpaArma] {
            let build = || scenario.build_generators();
            assert_empty_plan_is_noop(&cfg, &build, kind, 11, 4);
        }
    }
}

#[test]
fn sweep_cell_with_none_plan_reports_zero_fault_columns() {
    // The harness path: a `none` cell must label itself "none", keep
    // every fault counter at zero, and fingerprint identically to a run
    // of the same cell — the fault columns ride along without touching
    // the science.
    let topo = Topology::EdgeCity {
        zones: 8,
        workers_per_zone: 2,
        mix: Default::default(),
    };
    let cluster = topo.cluster();
    let label = topo.label();
    let presets = city_scenario_presets(8);
    let (name, scenario) = &presets[0];
    let cell = || {
        run_cell(
            &label,
            &cluster,
            name,
            scenario,
            AutoscalerKind::Hpa,
            None,
            1000,
            4,
            CoreKind::Calendar,
            0,
            &FaultPlan::none(),
            None,
        )
    };
    let a = cell();
    let b = cell();
    assert_eq!(a.metrics.fingerprint(), b.metrics.fingerprint());
    assert_eq!(a.metrics.chaos, "none");
    assert_eq!(a.metrics.crashes, 0);
    assert_eq!(a.metrics.rejoins, 0);
    assert_eq!(a.metrics.pods_killed, 0);
    assert_eq!(a.metrics.pods_rescheduled, 0);
    assert_eq!(a.metrics.crash_loops, 0);
    assert_eq!(a.metrics.downtime_secs, 0.0);
    assert!(a.metrics.cold_start_p95.is_nan());
}

//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The python side (`python/compile/aot.py`) lowers the L2 forecaster to
//! HLO **text** once (`make artifacts`); this module loads those files,
//! compiles them on the PJRT CPU client, and exposes typed entry points
//! (`init` / `predict` / `train_step` / `train_epoch`) to the L3
//! coordinator. Python is never on the control path.
//!
//! Interchange is HLO text because jax ≥ 0.5 emits `HloModuleProto`s with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md).
//!
//! The XLA bindings are only present behind the `pjrt` cargo feature (the
//! default offline crate set has no `xla`); without it an API-identical
//! stub reports the runtime as unavailable, so every LSTM code path and
//! experiment degrades to its documented "artifacts not built" behaviour.

mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use manifest::Manifest;

#[cfg(feature = "pjrt")]
pub use pjrt::LstmRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub::LstmRuntime;

use std::path::PathBuf;

/// Model parameters as host tensors, in the canonical flat order
/// `(w, b, wd, bd)` mirrored from `python/compile/model.py`.
#[derive(Debug, Clone, PartialEq)]
pub struct LstmParams {
    pub tensors: Vec<Vec<f32>>,
}

/// Adam optimizer state: first/second moments per parameter + step count.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub t: f32,
}

impl AdamState {
    /// Fresh zeroed state shaped like `params`.
    pub fn zeros(manifest: &Manifest) -> Self {
        let zeros: Vec<Vec<f32>> = manifest
            .param_shapes
            .iter()
            .map(|(_, shape)| vec![0.0; shape.iter().product()])
            .collect();
        AdamState {
            m: zeros.clone(),
            v: zeros,
            t: 0.0,
        }
    }
}

/// Locate the artifacts directory: `$PPA_ARTIFACTS`, else `artifacts/`
/// relative to the crate root (walking up from cwd as a fallback so tests
/// and examples work from any working directory).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(dir) = std::env::var("PPA_ARTIFACTS") {
        let p = PathBuf::from(dir);
        return p.join("manifest.json").exists().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("manifest.json").exists() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_state_shaped_like_manifest() {
        let m = Manifest::parse(
            r#"{
              "input_dim": 5, "hidden_dim": 50, "output_dim": 5,
              "seq_len": 8, "batch": 32, "epoch_batches": 16,
              "adam": {"lr": 0.001, "beta1": 0.9, "beta2": 0.999, "eps": 1e-08},
              "param_shapes": {"w": [55, 200], "b": [200], "wd": [50, 5], "bd": [5]}
            }"#,
        )
        .unwrap();
        let opt = AdamState::zeros(&m);
        assert_eq!(opt.m.len(), 4);
        assert_eq!(opt.m[0].len(), 55 * 200);
        assert_eq!(opt.v[3].len(), 5);
        assert_eq!(opt.t, 0.0);
    }
}

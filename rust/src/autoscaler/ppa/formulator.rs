//! The Formulator: extracts required metrics from raw adapter output and
//! maintains the *metrics history file* (paper Fig 4).

use crate::metrics::METRIC_DIM;

/// Hard cap on the in-memory history file — at a 20 s control interval
/// this is over a week of records, far beyond any update interval.
const HISTORY_CAP: usize = 40_000;

/// The metrics history file: protocol vectors, chronological.
#[derive(Debug, Default)]
pub struct Formulator {
    history: Vec<[f64; METRIC_DIM]>,
}

impl Formulator {
    pub fn new() -> Self {
        Formulator {
            history: Vec::new(),
        }
    }

    /// Append one control-loop record.
    pub fn record(&mut self, vector: [f64; METRIC_DIM]) {
        if self.history.len() == HISTORY_CAP {
            self.history.remove(0);
        }
        self.history.push(vector);
    }

    /// The history file contents (training set for the Updater; model
    /// input window source for the Evaluator).
    pub fn history(&self) -> &[[f64; METRIC_DIM]] {
        &self.history
    }

    /// The Updater "removes the metrics history file" after an update.
    pub fn clear(&mut self) {
        self.history.clear();
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_clears() {
        let mut f = Formulator::new();
        assert!(f.is_empty());
        f.record([1.0; METRIC_DIM]);
        f.record([2.0; METRIC_DIM]);
        assert_eq!(f.len(), 2);
        assert_eq!(f.history()[1][0], 2.0);
        f.clear();
        assert!(f.is_empty());
    }

    #[test]
    fn capped_history_drops_oldest() {
        let mut f = Formulator::new();
        for i in 0..(HISTORY_CAP + 10) {
            f.record([i as f64; METRIC_DIM]);
        }
        assert_eq!(f.len(), HISTORY_CAP);
        assert_eq!(f.history()[0][0], 10.0);
    }
}

//! Paper-figure benchmark harness: regenerates every evaluation figure at
//! a reduced-but-faithful scale and prints the same rows the paper
//! reports (plus wall-clock cost). Full-scale runs are available through
//! `ppa-edge experiment <fig>` / `examples/nasa_eval.rs`.
//!
//! Run with `cargo bench --bench paper_figures`.
//! Scale up via env: `PPA_BENCH_MINUTES=200 PPA_BENCH_HOURS=48
//! PPA_BENCH_PRETRAIN=10 cargo bench --bench paper_figures`.

use ppa_edge::experiments::{
    fig6_trace, fig7_model_comparison, fig8_update_policies, fig9_fig10_key_metric, nasa_eval,
    try_runtime, FigParams, NasaParams,
};
use ppa_edge::report;
use ppa_edge::stats::summarize;
use ppa_edge::workload::NasaTraceConfig;
use std::time::Instant;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let minutes = env_f64("PPA_BENCH_MINUTES", 40.0) as u64;
    let hours = env_f64("PPA_BENCH_HOURS", 2.0);
    let pretrain = env_f64("PPA_BENCH_PRETRAIN", 1.0);
    println!(
        "paper-figure bench: {minutes} min optimization runs, {hours} h NASA eval, {pretrain} h pretraining"
    );
    println!("(paper scale: 200 min / 48 h / 10 h — set PPA_BENCH_* to reproduce)");

    let params = FigParams {
        minutes,
        pretrain_hours: pretrain,
        seed: 2021,
    };
    let nasa_params = NasaParams {
        hours,
        pretrain_hours: pretrain,
        seed: 2021,
        trace: NasaTraceConfig::default(),
    };

    // Fig 6 — trace generation.
    let t = Instant::now();
    let counts = fig6_trace(&NasaTraceConfig::default())?;
    let s = summarize(&counts);
    println!(
        "\n== Fig 6 — scaled NASA trace == [{:.2}s]\n  {} minutes, mean {:.1} req/min, peak {:.0}",
        t.elapsed().as_secs_f64(),
        counts.len(),
        s.mean,
        s.max
    );

    if try_runtime().is_none() {
        println!("\nLSTM artifacts missing — figs 7-14 need `make artifacts`. Exiting.");
        return Ok(());
    }

    let t = Instant::now();
    let fig7 = fig7_model_comparison(&params)?;
    report::print_fig7(&fig7);
    println!("  [fig7 wall: {:.1}s]", t.elapsed().as_secs_f64());

    let t = Instant::now();
    let fig8 = fig8_update_policies(&params)?;
    report::print_fig8(&fig8);
    println!("  [fig8 wall: {:.1}s]", t.elapsed().as_secs_f64());

    let t = Instant::now();
    let fig910 = fig9_fig10_key_metric(&params)?;
    report::print_fig9_10(&fig910);
    println!("  [figs 9/10 wall: {:.1}s]", t.elapsed().as_secs_f64());

    let t = Instant::now();
    let eval = nasa_eval(&nasa_params)?;
    report::print_nasa_eval(&eval);
    println!("  [figs 11-14 wall: {:.1}s]", t.elapsed().as_secs_f64());

    Ok(())
}

//! The event queue: a min-heap over `(time, seq)` with stable FIFO order
//! for simultaneous events.

use super::{Event, Time};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug)]
struct Entry {
    time: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics on BinaryHeap (a max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now: Time,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(4096),
            seq: 0,
            now: 0,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Events scheduled in the
    /// past are clamped to `now` (dispatching immediately, in order).
    pub fn schedule_at(&mut self, at: Time, event: Event) {
        let time = at.max(self.now);
        self.heap.push(Entry {
            time,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, event: Event) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        let entry = self.heap.pop()?;
        debug_assert!(entry.time >= self.now, "time went backwards");
        self.now = entry.time;
        Some((entry.time, entry.event))
    }

    /// Time of the next pending event, if any.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    fn tick(g: u32) -> Event {
        Event::WorkloadTick { generator: g }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3 * SEC, tick(3));
        q.schedule_at(1 * SEC, tick(1));
        q.schedule_at(2 * SEC, tick(2));
        let order: Vec<u32> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::WorkloadTick { generator } => generator,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for g in 0..50 {
            q.schedule_at(5 * SEC, tick(g));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                Event::WorkloadTick { generator } => generator,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(10, tick(0));
        q.schedule_at(5, tick(1));
        let (t1, _) = q.pop().unwrap();
        assert_eq!(t1, 5);
        assert_eq!(q.now(), 5);
        // Scheduling in the past clamps to now.
        q.schedule_at(1, tick(2));
        let (t2, e2) = q.pop().unwrap();
        assert_eq!(t2, 5);
        assert_eq!(e2, tick(2));
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 10);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(7, tick(0));
        q.pop().unwrap();
        q.schedule_in(3, tick(1));
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(1, tick(0));
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(1));
        q.pop();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }
}

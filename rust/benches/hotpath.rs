//! Hot-path micro-benchmarks (L3 perf deliverable): the DES event loop,
//! scheduler, metrics scrape (interned handles vs the legacy string-keyed
//! path), forecaster dispatches, end-to-end simulation rate and sweep
//! cell throughput — including city-scale (50-zone) worlds. Run with
//! `cargo bench --bench hotpath`.
//!
//! Emits a machine-readable `BENCH_hotpath.json` (events/sec, ns/scrape,
//! cells/sec, scrape speedup vs legacy) so the perf trajectory is
//! tracked across PRs.

#[path = "bench_common.rs"]
mod bench_common;
use bench_common::{print_header, run};

use ppa_edge::app::{App, TaskCosts, TaskType};
use ppa_edge::autoscaler::Hpa;
use ppa_edge::cluster::{Cluster, Deployment, NodeSpec, PodPhase, PodSpec, Selector, Tier};
use ppa_edge::config::{
    city_scenario_presets, paper_cluster, quickstart_cluster, ClusterConfig, Topology,
};
use ppa_edge::experiments::sweep::run_cell;
use ppa_edge::experiments::{AutoscalerKind, SimWorld};
use ppa_edge::forecast::{arma::fit_arma, Forecaster, LstmForecaster};
use ppa_edge::metrics::{METRIC_DIM, METRIC_NAMES};
use ppa_edge::sim::{Event, EventQueue, Time, MIN, SEC};
use ppa_edge::util::json::Json;
use ppa_edge::util::rng::Pcg64;
use ppa_edge::workload::{Generator, RandomAccessGen};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::rc::Rc;

fn bench_event_queue() {
    print_header("DES event queue");
    let mut rng = Pcg64::new(1, 0);
    run("queue push+pop, 10k events", 3, 30, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at(
                rng.below(1_000_000),
                Event::WorkloadTick { generator: i as u32 },
            );
        }
        while q.pop().is_some() {}
    });
}

fn bench_scheduler() {
    print_header("pod scheduler (filter+score over 7 nodes)");
    let cfg = paper_cluster();
    let (mut cluster, ids) = cfg.build();
    let mut q = EventQueue::new();
    let mut rng = Pcg64::new(2, 0);
    run("reconcile 0->6->0 replicas", 3, 200, || {
        cluster.reconcile(ids[0], 6, &mut q, &mut rng);
        cluster.reconcile(ids[0], 0, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::PodRunning { pod } => {
                    cluster.on_pod_running(pod);
                }
                Event::PodTerminated { pod } => cluster.on_pod_terminated(pod),
                _ => {}
            }
        }
    });
}

/// The old string-keyed store, reconstructed: `entry(name.to_string())`
/// on every insert (one String allocation per series per tick), exactly
/// what `Tsdb` did before the interner (the new `Tsdb::insert` resolves
/// through the interner and would flatter the baseline).
struct LegacyTsdb {
    series: HashMap<String, VecDeque<(Time, f64)>>,
}

impl LegacyTsdb {
    fn new() -> Self {
        LegacyTsdb {
            series: HashMap::new(),
        }
    }

    fn insert(&mut self, name: &str, t: Time, v: f64) {
        let s = self
            .series
            .entry(name.to_string())
            .or_insert_with(|| VecDeque::with_capacity(1024));
        if s.len() == 20_000 {
            s.pop_front();
        }
        s.push_back((t, v));
    }
}

/// The pre-interning scrape, reconstructed from public APIs with the same
/// per-pod arithmetic (base-burn utilization, RAM model): clones each
/// deployment's pod list, builds 8 `format!` keys per service per tick
/// and writes through the string-keyed [`LegacyTsdb::insert`]. The
/// baseline the interned hot path is measured against.
fn legacy_scrape(
    tsdb: &mut LegacyTsdb,
    now: Time,
    last: &mut Time,
    cluster: &mut Cluster,
    app: &mut App,
    base_burn: f64,
) {
    let interval = now.saturating_sub(*last);
    if interval == 0 {
        return;
    }
    let interval_secs = ppa_edge::sim::to_secs(interval);
    let counters = app.take_counters();
    for (svc_idx, svc) in app.services.iter().enumerate() {
        let dep = svc.deployment;
        let mut cpu_sum_pct = 0.0;
        let mut ram_sum_pct = 0.0;
        let mut requested = 0.0;
        let mut used = 0.0;
        let mut replicas = 0usize;
        let pod_ids: Vec<ppa_edge::sim::PodId> =
            cluster.deployments[dep.0 as usize].pods.clone();
        for pid in pod_ids {
            let pod = cluster.pod_mut(pid);
            match pod.phase {
                PodPhase::Running | PodPhase::Terminating => {
                    let busy_frac = (pod.take_busy(now) as f64 / interval as f64).min(1.0);
                    let util = (base_burn + (1.0 - base_burn) * busy_frac).min(1.0);
                    cpu_sum_pct += util * 100.0;
                    ram_sum_pct += 30.0 + 55.0 * util;
                    requested += pod.spec.cpu_millis as f64;
                    used += util * pod.spec.cpu_millis as f64;
                    replicas += 1;
                }
                PodPhase::Initializing | PodPhase::Pending => {
                    requested += pod.spec.cpu_millis as f64;
                    replicas += 1;
                }
                PodPhase::Gone => {}
            }
        }
        let c = counters[svc_idx];
        let vector = [
            cpu_sum_pct,
            ram_sum_pct,
            c.net_in_bytes as f64 / 1000.0 / interval_secs,
            c.net_out_bytes as f64 / 1000.0 / interval_secs,
            c.arrivals as f64 / interval_secs,
        ];
        let name = &svc.name;
        for (m, metric) in METRIC_NAMES.iter().enumerate() {
            tsdb.insert(&format!("{name}.{metric}"), now, vector[m]);
        }
        tsdb.insert(&format!("{name}.replicas"), now, replicas as f64);
        if requested > 0.0 {
            tsdb.insert(&format!("{name}.rir"), now, (requested - used) / requested);
        }
        tsdb.insert(&format!("{name}.queue_depth"), now, svc.queue.len() as f64);
    }
    *last = now;
}

fn busy_world(cfg: &ClusterConfig, seed: u64) -> SimWorld {
    let mut world = SimWorld::build(cfg, TaskCosts::default(), seed);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    world.run_until(5 * MIN);
    world
}

/// Returns (interned ns/scrape, legacy ns/scrape, city-50 ns/scrape).
fn bench_scrape() -> (f64, f64, f64) {
    print_header("metrics pipeline scrape");
    let mut world = busy_world(&paper_cluster(), 3);
    let mut t = 5 * MIN;
    let interned = run("paper world, interned handles", 5, 500, || {
        t += 10 * SEC;
        world.metrics.scrape(t, &mut world.cluster, &mut world.app);
    });

    let mut world = busy_world(&paper_cluster(), 3);
    let mut tsdb = LegacyTsdb::new();
    let mut t = 5 * MIN;
    let mut last = 0;
    let burn = TaskCosts::default().base_burn_frac;
    let legacy = run("paper world, legacy string keys", 5, 500, || {
        t += 10 * SEC;
        legacy_scrape(
            &mut tsdb,
            t,
            &mut last,
            &mut world.cluster,
            &mut world.app,
            burn,
        );
    });

    let city = Topology::EdgeCity {
        zones: 50,
        workers_per_zone: 2,
    };
    let mut world = SimWorld::build(&city.cluster(), TaskCosts::default(), 7);
    let presets = city_scenario_presets(50);
    for gen in presets[2].1.build_generators() {
        world.add_generator(gen);
    }
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    world.run_until(5 * MIN);
    let mut t = 5 * MIN;
    let city_r = run("city-50 world (51 services), interned", 5, 200, || {
        t += 10 * SEC;
        world.metrics.scrape(t, &mut world.cluster, &mut world.app);
    });

    let speedup = legacy.mean_us / interned.mean_us;
    println!("  -> interned scrape is {speedup:.1}x the legacy string-keyed path");
    (
        interned.mean_us * 1000.0,
        legacy.mean_us * 1000.0,
        city_r.mean_us * 1000.0,
    )
}

fn bench_forecasters() {
    print_header("forecaster hot path");
    // ARMA fit on a 200-row history (every update loop).
    let mut rng = Pcg64::new(5, 0);
    let series: Vec<f64> = (0..200)
        .map(|i| 100.0 + 30.0 * ((i as f64) / 12.0).sin() + rng.normal() * 4.0)
        .collect();
    run("ARMA(1,1) CSS fit, 200 points", 2, 20, || {
        let _ = fit_arma(&series);
    });

    // LSTM dispatches (the PJRT path) — only with artifacts.
    if let Some(rt) = ppa_edge::experiments::try_runtime() {
        let rt: Rc<_> = rt;
        let mut f = LstmForecaster::new(rt.clone(), 1).unwrap();
        let history: Vec<[f64; METRIC_DIM]> = (0..300)
            .map(|i| {
                let v = 100.0 + 50.0 * ((i as f64) / 20.0).sin();
                [v; METRIC_DIM]
            })
            .collect();
        f.pretrain_on(&history).unwrap();
        run("LSTM predict dispatch (PJRT)", 5, 200, || {
            let _ = f.predict(&history);
        });
        run("LSTM fine-tune (6 train_epoch dispatches)", 1, 5, || {
            f.retrain(&history, ppa_edge::forecast::UpdatePolicy::FineTune)
                .unwrap();
        });
    } else {
        println!("(LSTM benches skipped: run `make artifacts`)");
    }
}

/// Returns measured end-to-end events/sec (quickstart world, HPA).
fn bench_end_to_end() -> f64 {
    print_header("end-to-end simulation rate");
    let r = run("quickstart world, 60 sim-minutes (HPA)", 1, 5, || {
        let cfg = quickstart_cluster();
        let mut world = SimWorld::build(&cfg, TaskCosts::default(), 9);
        world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
        for svc in 0..world.app.services.len() {
            world.add_scaler(Box::new(Hpa::with_defaults()), svc);
        }
        world.run_until(60 * MIN);
    });
    let speedup = 3600.0 / (r.mean_us / 1e6);
    println!("  -> simulation speed ~{speedup:.0}x real time");

    // Events/sec on one measured run.
    let cfg = quickstart_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 9);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    let wall = std::time::Instant::now();
    let events = world.run_until(60 * MIN);
    let events_per_sec = events as f64 / wall.elapsed().as_secs_f64();
    println!("  -> {events_per_sec:.0} events/sec");

    // Request-to-completion throughput of the app model itself.
    let mut cluster = Cluster::new();
    cluster.add_node(NodeSpec::new("e", Tier::Edge, 1, 8000, 8192));
    let edge = cluster.add_deployment(Deployment::new(
        "edge",
        Selector::new(Tier::Edge, None),
        PodSpec::new(500, 256),
        1,
        8,
    ));
    let cloud = cluster.add_deployment(Deployment::new(
        "cloud",
        Selector::new(Tier::Edge, None),
        PodSpec::new(500, 256),
        1,
        8,
    ));
    let mut q = EventQueue::new();
    let mut rng = Pcg64::new(11, 0);
    cluster.reconcile(edge, 4, &mut q, &mut rng);
    while let Some((_, ev)) = q.pop() {
        if let Event::PodRunning { pod } = ev {
            cluster.on_pod_running(pod);
        }
    }
    let mut app = App::new(TaskCosts::default(), &[(1, edge)], cloud);
    run("submit+serve 100 sort requests", 2, 50, || {
        for _ in 0..100 {
            app.submit(TaskType::Sort, 1, q.now(), &mut q);
        }
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::RequestArrival { request_id } => {
                    app.on_arrival(request_id, &mut cluster, &mut q, &mut rng)
                }
                Event::ServiceComplete { pod, request_id } => {
                    app.on_complete(pod, request_id, &mut cluster, &mut q, &mut rng)
                }
                _ => {}
            }
        }
    });
    events_per_sec
}

/// Returns sweep cell throughput (cells/sec) on a city-8 topology.
fn bench_sweep_cells() -> f64 {
    print_header("sweep cell throughput (city-8, hpa, 5 sim-minutes)");
    let topo = Topology::EdgeCity {
        zones: 8,
        workers_per_zone: 2,
    };
    let cluster = topo.cluster();
    let label = topo.label();
    let presets = city_scenario_presets(8);
    let (name, scenario) = &presets[2]; // city8-step-carpet
    let scaler = AutoscalerKind::Hpa;
    let r = run("run_cell city-8 step-carpet", 1, 5, || {
        let _ = run_cell(&label, &cluster, name, scenario, scaler, 3, 5);
    });
    let cells_per_sec = 1e6 / r.mean_us;
    println!("  -> {cells_per_sec:.2} cells/sec (single thread)");
    cells_per_sec
}

fn write_bench_json(entries: &[(&str, f64)]) {
    let mut o = BTreeMap::new();
    o.insert("schema".to_string(), Json::Num(1.0));
    for &(k, v) in entries {
        let value = if v.is_finite() { Json::Num(v) } else { Json::Null };
        o.insert(k.to_string(), value);
    }
    // cargo bench runs with cwd = the package root (rust/); anchor the
    // report at the workspace root where DESIGN.md documents it.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_hotpath.json");
    match std::fs::write(&path, Json::Obj(o).to_string()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}

fn main() {
    println!("ppa-edge hot-path benchmarks");
    bench_event_queue();
    bench_scheduler();
    let (scrape_ns, legacy_ns, city_ns) = bench_scrape();
    bench_forecasters();
    let events_per_sec = bench_end_to_end();
    let cells_per_sec = bench_sweep_cells();
    write_bench_json(&[
        ("events_per_sec", events_per_sec),
        ("ns_per_scrape", scrape_ns),
        ("ns_per_scrape_legacy", legacy_ns),
        ("ns_per_scrape_city50", city_ns),
        ("scrape_speedup_vs_legacy", legacy_ns / scrape_ns),
        ("sweep_cells_per_sec", cells_per_sec),
    ]);
}

//! Generational slab arena for in-flight requests.
//!
//! The arrival→complete hot path used to go through a
//! `HashMap<u64, Request>`: a hash + probe per lookup and re-hashing
//! growth pauses at city scale. [`RequestArena`] replaces it with a
//! slab indexed directly by [`RequestId::index`] — no hashing, no
//! per-request allocation at steady state (freed slots are recycled
//! through a LIFO free list, so the slab grows only to the peak
//! in-flight count).
//!
//! # Generation rules
//!
//! * A slot's `generation` counts how many requests have *completed* in
//!   it: it starts at 0 and is bumped once on every [`RequestArena::remove`].
//! * [`RequestArena::insert`] stamps the slot's current generation into
//!   the returned [`RequestId`]; lookups succeed only while the handle's
//!   generation matches the slot's.
//! * A stale handle (its request completed, slot possibly reused)
//!   therefore resolves to `None` — it can never alias a newer request.
//!
//! The free list is deterministic (LIFO), so identical event sequences
//! produce identical `RequestId` streams — part of the simulator's
//! bit-reproducibility contract.

use super::Request;
use crate::sim::RequestId;

#[derive(Debug)]
struct Slot {
    generation: u32,
    value: Option<Request>,
}

/// Generational slab of in-flight [`Request`]s (see the module docs).
#[derive(Debug, Default)]
pub struct RequestArena {
    slots: Vec<Slot>,
    /// Free slot indices, reused LIFO.
    free: Vec<u32>,
}

impl RequestArena {
    pub fn new() -> Self {
        RequestArena {
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Number of live (in-flight) requests.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total slots ever allocated (== peak in-flight count).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Store `req`, returning its generational handle.
    pub fn insert(&mut self, req: Request) -> RequestId {
        match self.free.pop() {
            Some(index) => {
                let slot = &mut self.slots[index as usize];
                debug_assert!(slot.value.is_none(), "free list pointed at a live slot");
                slot.value = Some(req);
                RequestId::new(index, slot.generation)
            }
            None => {
                let index = self.slots.len() as u32;
                self.slots.push(Slot {
                    generation: 0,
                    value: Some(req),
                });
                RequestId::new(index, 0)
            }
        }
    }

    /// Look up a live request; `None` for stale or unknown handles.
    pub fn get(&self, id: RequestId) -> Option<&Request> {
        self.slots
            .get(id.index as usize)
            .filter(|s| s.generation == id.generation)
            .and_then(|s| s.value.as_ref())
    }

    /// Remove a live request, bumping the slot's generation so the
    /// handle (and any copies of it) goes stale.
    pub fn remove(&mut self, id: RequestId) -> Option<Request> {
        let slot = self.slots.get_mut(id.index as usize)?;
        if slot.generation != id.generation {
            return None;
        }
        let req = slot.value.take()?;
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(id.index);
        Some(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::TaskType;
    use crate::sim::ServiceId;

    fn req(zone: u32) -> Request {
        Request {
            task: TaskType::Sort,
            origin_zone: zone,
            service: ServiceId(0),
            created: 0,
        }
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut a = RequestArena::new();
        let id = a.insert(req(1));
        assert_eq!(id, RequestId::new(0, 0));
        assert_eq!(a.len(), 1);
        assert_eq!(a.get(id).unwrap().origin_zone, 1);
        let out = a.remove(id).unwrap();
        assert_eq!(out.origin_zone, 1);
        assert!(a.is_empty());
        assert_eq!(a.get(id), None, "handle is stale after remove");
        assert_eq!(a.remove(id), None, "double-remove misses");
    }

    #[test]
    fn slot_reuse_bumps_generation() {
        let mut a = RequestArena::new();
        let first = a.insert(req(1));
        a.remove(first).unwrap();
        let second = a.insert(req(2));
        // Same slot, next generation: the stale handle cannot alias it.
        assert_eq!(second.index, first.index);
        assert_eq!(second.generation, first.generation + 1);
        assert_eq!(a.get(first), None);
        assert_eq!(a.get(second).unwrap().origin_zone, 2);
        assert_eq!(a.capacity(), 1, "slot recycled, not grown");
    }

    #[test]
    fn free_list_is_lifo_and_deterministic() {
        let mut a = RequestArena::new();
        let ids: Vec<RequestId> = (0..4).map(|z| a.insert(req(z))).collect();
        a.remove(ids[1]).unwrap();
        a.remove(ids[3]).unwrap();
        // LIFO: slot 3 comes back first, then slot 1.
        assert_eq!(a.insert(req(10)).index, 3);
        assert_eq!(a.insert(req(11)).index, 1);
        assert_eq!(a.len(), 4);
        assert_eq!(a.capacity(), 4);
    }

    #[test]
    fn steady_state_never_grows() {
        let mut a = RequestArena::new();
        for round in 0..100u32 {
            let id = a.insert(req(round));
            assert_eq!(id.index, 0);
            assert_eq!(id.generation, round);
            a.remove(id).unwrap();
        }
        assert_eq!(a.capacity(), 1);
    }
}

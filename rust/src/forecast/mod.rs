//! Time-series forecasters for the PPA (paper §4.2.2 model protocol).
//!
//! Every model consumes the 5-metric protocol vector history and predicts
//! the next control-loop's full vector (the protocol: "the model should
//! predict all input variables"). Implementations:
//!
//! * [`LstmForecaster`] — the paper's optimal model: the AOT-compiled
//!   JAX/Pallas LSTM executed via PJRT ([`crate::runtime`]).
//! * [`ArmaForecaster`] — the paper's baseline: per-series ARMA(1,1)
//!   fitted from scratch by conditional-sum-of-squares (what statsmodels
//!   did in the paper's stack).
//! * [`NaiveForecaster`] — last-value persistence (sanity floor).
//!
//! The zoo beyond the paper (all pure Rust, `Send`, shard-safe):
//!
//! * [`HoltWintersForecaster`] — additive-seasonal triple exponential
//!   smoothing, the cheap strong baseline.
//! * [`TcnForecaster`] — dilated causal conv1d over the protocol
//!   window, fitted gradient-free by greedy SPSA.
//! * [`LstmCellForecaster`] — pure-Rust LSTM *inference* over the PJRT
//!   artifact's weight layout, without the non-`Send` runtime handle.
//! * [`ChampionChallenger`] — online champion–challenger selection over
//!   K wrapped models ([`selector`]).
//!
//! [`ForecasterKind`] names the CLI-buildable axis
//! (`--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K`).

pub mod arma;
pub mod holt_winters;
pub mod lstm;
pub mod lstm_cell;
pub mod scaler;
pub mod selector;
pub mod tcn;
pub mod window;

pub use arma::ArmaForecaster;
pub use holt_winters::HoltWintersForecaster;
pub use lstm::LstmForecaster;
pub use lstm_cell::LstmCellForecaster;
pub use scaler::{MinMaxScaler, Scaler, StandardScaler};
pub use selector::{ChampionChallenger, SelectionSummary, SelectorConfig};
pub use tcn::TcnForecaster;

use crate::metrics::METRIC_DIM;

/// The paper's three model-update policies (§4.2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdatePolicy {
    /// Policy 1: never retrain; the seed model runs forever.
    KeepSeed,
    /// Policy 2: drop the model, retrain from scratch on the history file.
    RetrainScratch,
    /// Policy 3: fine-tune the current model for extra epochs on the
    /// history file (paper's winner).
    FineTune,
}

impl UpdatePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            UpdatePolicy::KeepSeed => "policy1-keep-seed",
            UpdatePolicy::RetrainScratch => "policy2-retrain-scratch",
            UpdatePolicy::FineTune => "policy3-fine-tune",
        }
    }
}

/// A one-step-ahead multivariate forecaster.
pub trait Forecaster {
    fn name(&self) -> &str;

    /// Predict the next protocol vector from chronological `history`
    /// (most recent last). `None` when the model cannot predict (not
    /// enough history, invalid model file) — Algorithm 1 then falls back
    /// to the current metric ("Robust" property).
    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]>;

    /// Apply a model-update-loop step with the given policy over the
    /// metrics-history file contents.
    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()>;

    /// Feed back the realized vector for the instant the last prediction
    /// targeted (confidence calibration; default no-op).
    fn observe(&mut self, _actual: &[f64; METRIC_DIM]) {}

    /// Whether the model produces calibrated uncertainty (Algorithm 1's
    /// confidence gate).
    fn is_bayesian(&self) -> bool {
        false
    }

    /// Confidence of the last prediction in [0, 1] (only meaningful when
    /// `is_bayesian`).
    fn confidence(&self) -> f64 {
        1.0
    }

    /// Champion–challenger state, when this forecaster is a selection
    /// wrapper ([`ChampionChallenger`] overrides this; plain models
    /// report `None`).
    fn selection(&self) -> Option<SelectionSummary> {
        None
    }
}

/// The CLI-buildable forecaster axis: every kind here is pure Rust and
/// `Send`-safe, so it runs under the parallel sweep grid and any
/// `--shards` layout. (The PJRT `lstm` model is *not* on this axis —
/// its runtime handle is shared single-threaded state; `lstm-rs` is the
/// sharded alternative.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForecasterKind {
    Naive,
    Arma,
    HoltWinters,
    Tcn,
    LstmRs,
    /// Champion–challenger selection over the first K of [`ROSTER`].
    Auto(u8),
}

/// Roster order for `auto:K`: strongest cheap baselines first, so small
/// K stays useful (`auto:1` wraps Holt-Winters, `auto:3` adds ARMA and
/// naive, `auto:5` the full zoo).
pub const ROSTER: [ForecasterKind; 5] = [
    ForecasterKind::HoltWinters,
    ForecasterKind::Arma,
    ForecasterKind::Naive,
    ForecasterKind::Tcn,
    ForecasterKind::LstmRs,
];

impl ForecasterKind {
    /// Parse a `--forecaster` token.
    pub fn parse(s: &str) -> crate::Result<Self> {
        if let Some(k) = s.strip_prefix("auto:") {
            let k: usize = k
                .parse()
                .map_err(|_| anyhow::anyhow!("bad auto:K forecaster `{s}` (K must be 1..=5)"))?;
            if !(1..=ROSTER.len()).contains(&k) {
                anyhow::bail!("auto:K supports K in 1..={} (got {k})", ROSTER.len());
            }
            return Ok(ForecasterKind::Auto(k as u8));
        }
        match s {
            "naive" => Ok(ForecasterKind::Naive),
            "arma" => Ok(ForecasterKind::Arma),
            "holt-winters" => Ok(ForecasterKind::HoltWinters),
            "tcn" => Ok(ForecasterKind::Tcn),
            "lstm-rs" => Ok(ForecasterKind::LstmRs),
            other => anyhow::bail!(
                "unknown forecaster `{other}` (naive|arma|holt-winters|tcn|lstm-rs|auto:K)"
            ),
        }
    }

    /// The CLI token (and sweep-label suffix) for this kind.
    pub fn name(&self) -> String {
        match self {
            ForecasterKind::Naive => "naive".to_string(),
            ForecasterKind::Arma => "arma".to_string(),
            ForecasterKind::HoltWinters => "holt-winters".to_string(),
            ForecasterKind::Tcn => "tcn".to_string(),
            ForecasterKind::LstmRs => "lstm-rs".to_string(),
            ForecasterKind::Auto(k) => format!("auto:{k}"),
        }
    }

    /// Build the forecaster. `seed` feeds the seeded inits (TCN,
    /// lstm-rs); stateless kinds ignore it. Pure: same kind + seed →
    /// bit-identical model, wherever (and on whichever thread) it is
    /// built.
    pub fn build(&self, seed: u64) -> Box<dyn Forecaster> {
        self.build_send(seed)
    }

    /// [`Self::build`] with the `Send` bound kept visible — every kind
    /// on this axis is `Send`, which is what lets learned models enter
    /// the sharded engine.
    pub fn build_send(&self, seed: u64) -> Box<dyn Forecaster + Send> {
        match self {
            ForecasterKind::Naive => Box::new(NaiveForecaster),
            ForecasterKind::Arma => Box::new(ArmaForecaster::new()),
            ForecasterKind::HoltWinters => Box::new(HoltWintersForecaster::default()),
            ForecasterKind::Tcn => Box::new(TcnForecaster::seeded(seed)),
            ForecasterKind::LstmRs => Box::new(LstmCellForecaster::seeded(seed)),
            ForecasterKind::Auto(k) => Box::new(ChampionChallenger::new(
                ROSTER[..*k as usize]
                    .iter()
                    .map(|m| m.build_send(seed))
                    .collect(),
                SelectorConfig::default(),
            )),
        }
    }
}

/// Last-value persistence baseline.
#[derive(Debug, Default)]
pub struct NaiveForecaster;

impl Forecaster for NaiveForecaster {
    fn name(&self) -> &str {
        "naive-last-value"
    }

    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        history.last().copied()
    }

    fn retrain(
        &mut self,
        _history: &[[f64; METRIC_DIM]],
        _policy: UpdatePolicy,
    ) -> crate::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_predicts_last() {
        let mut f = NaiveForecaster;
        let h = vec![[1.0; METRIC_DIM], [2.0; METRIC_DIM]];
        assert_eq!(f.predict(&h), Some([2.0; METRIC_DIM]));
        assert_eq!(f.predict(&[]), None);
        assert!(f.retrain(&h, UpdatePolicy::FineTune).is_ok());
        assert!(!f.is_bayesian());
    }

    #[test]
    fn policy_names() {
        assert!(UpdatePolicy::KeepSeed.name().contains("policy1"));
        assert!(UpdatePolicy::RetrainScratch.name().contains("policy2"));
        assert!(UpdatePolicy::FineTune.name().contains("policy3"));
    }

    #[test]
    fn forecaster_kind_parse_roundtrip() {
        for token in ["naive", "arma", "holt-winters", "tcn", "lstm-rs", "auto:3"] {
            let kind = ForecasterKind::parse(token).expect(token);
            assert_eq!(kind.name(), token);
        }
        assert_eq!(
            ForecasterKind::parse("auto:1").expect("k=1"),
            ForecasterKind::Auto(1)
        );
        assert!(ForecasterKind::parse("auto:0").is_err());
        assert!(ForecasterKind::parse("auto:6").is_err());
        assert!(ForecasterKind::parse("auto:x").is_err());
        let err = ForecasterKind::parse("lstm").expect_err("PJRT model is off this axis");
        assert!(err.to_string().contains("lstm-rs"), "{err}");
    }

    #[test]
    fn kinds_build_the_named_models() {
        assert_eq!(ForecasterKind::Naive.build(1).name(), "naive-last-value");
        assert_eq!(ForecasterKind::Arma.build(1).name(), "arma(1,1)");
        assert_eq!(
            ForecasterKind::HoltWinters.build(1).name(),
            "holt-winters(30)"
        );
        assert_eq!(ForecasterKind::Tcn.build(1).name(), "tcn");
        assert_eq!(ForecasterKind::LstmRs.build(1).name(), "lstm-rs(50)");
        let auto = ForecasterKind::Auto(3).build(1);
        assert_eq!(auto.name(), "auto:3");
        let summary = auto.selection().expect("selector reports state");
        assert_eq!(summary.champion, "holt-winters(30)", "roster head");
        assert_eq!(summary.models.len(), 3);
        assert!(NaiveForecaster.selection().is_none(), "plain models: None");
    }

    /// The whole CLI axis must stay `Send` so scalers built from it can
    /// enter the sharded engine's worker threads.
    #[test]
    fn zoo_forecasters_are_send() {
        fn assert_send<T: Send>(_: T) {}
        assert_send(HoltWintersForecaster::default());
        assert_send(TcnForecaster::seeded(1));
        assert_send(LstmCellForecaster::seeded(1));
        assert_send(ChampionChallenger::new(
            vec![Box::new(NaiveForecaster)],
            SelectorConfig::default(),
        ));
    }
}

//! Feature scalers — the paper's `ScalerLink` companion to the injected
//! model: metrics are scaled before the LSTM and inverse-scaled after
//! prediction.
//!
//! The LSTM uses [`MinMaxScaler`] (range [0, 1]): its ReLU output head
//! can only produce non-negative values, so targets must live in a
//! non-negative space — standardized (z-score) targets would make every
//! below-mean value unlearnable. [`StandardScaler`] remains for models
//! without output-range constraints.

use crate::metrics::METRIC_DIM;

/// Common scaler interface (the paper's scaler-file protocol).
pub trait Scaler {
    fn transform(&self, row: &[f64; METRIC_DIM]) -> [f64; METRIC_DIM];
    fn inverse(&self, feature: usize, value: f64) -> f64;

    fn inverse_row(&self, row: &[f64; METRIC_DIM]) -> [f64; METRIC_DIM] {
        let mut out = [0.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            out[i] = self.inverse(i, row[i]);
        }
        out
    }
}

/// Per-feature standardization: `x' = (x - mean) / std`.
#[derive(Debug, Clone, PartialEq)]
pub struct StandardScaler {
    pub mean: [f64; METRIC_DIM],
    pub std: [f64; METRIC_DIM],
}

impl StandardScaler {
    /// Identity scaler (mean 0, std 1).
    pub fn identity() -> Self {
        StandardScaler {
            mean: [0.0; METRIC_DIM],
            std: [1.0; METRIC_DIM],
        }
    }

    /// Fit on a history matrix. Features with ~zero variance get std 1 so
    /// transforms stay finite.
    pub fn fit(rows: &[[f64; METRIC_DIM]]) -> Self {
        let n = rows.len().max(1) as f64;
        let mut mean = [0.0; METRIC_DIM];
        for row in rows {
            for (m, x) in mean.iter_mut().zip(row) {
                *m += x / n;
            }
        }
        let mut std = [0.0; METRIC_DIM];
        for row in rows {
            for ((s, x), m) in std.iter_mut().zip(row).zip(&mean) {
                *s += (x - m) * (x - m) / n;
            }
        }
        for s in &mut std {
            *s = s.sqrt();
            if !s.is_finite() || *s < 1e-9 {
                *s = 1.0;
            }
        }
        StandardScaler { mean, std }
    }

    /// Non-trait accessor kept for backwards compatibility in tests.
    pub fn inverse_row(&self, row: &[f64; METRIC_DIM]) -> [f64; METRIC_DIM] {
        Scaler::inverse_row(self, row)
    }
}

impl Scaler for StandardScaler {
    fn transform(&self, row: &[f64; METRIC_DIM]) -> [f64; METRIC_DIM] {
        let mut out = [0.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            out[i] = (row[i] - self.mean[i]) / self.std[i];
        }
        out
    }

    fn inverse(&self, feature: usize, value: f64) -> f64 {
        value * self.std[feature] + self.mean[feature]
    }
}

/// Min-max scaler to [0, 1] — what the LSTM's ReLU head requires.
#[derive(Debug, Clone, PartialEq)]
pub struct MinMaxScaler {
    pub min: [f64; METRIC_DIM],
    /// max - min, floored at a small epsilon for constant features.
    pub range: [f64; METRIC_DIM],
}

impl MinMaxScaler {
    pub fn identity() -> Self {
        MinMaxScaler {
            min: [0.0; METRIC_DIM],
            range: [1.0; METRIC_DIM],
        }
    }

    /// Fit on a history matrix. A 10% headroom margin is added on top so
    /// production values modestly above the training max still map inside
    /// a learnable region.
    pub fn fit(rows: &[[f64; METRIC_DIM]]) -> Self {
        let mut min = [f64::INFINITY; METRIC_DIM];
        let mut max = [f64::NEG_INFINITY; METRIC_DIM];
        for row in rows {
            for i in 0..METRIC_DIM {
                min[i] = min[i].min(row[i]);
                max[i] = max[i].max(row[i]);
            }
        }
        let mut range = [1.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            if !min[i].is_finite() {
                min[i] = 0.0;
            }
            if !max[i].is_finite() {
                max[i] = 1.0;
            }
            let r = (max[i] - min[i]) * 1.1;
            range[i] = if r > 1e-9 { r } else { 1.0 };
        }
        MinMaxScaler { min, range }
    }
}

impl Scaler for MinMaxScaler {
    fn transform(&self, row: &[f64; METRIC_DIM]) -> [f64; METRIC_DIM] {
        let mut out = [0.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            out[i] = (row[i] - self.min[i]) / self.range[i];
        }
        out
    }

    fn inverse(&self, feature: usize, value: f64) -> f64 {
        value * self.range[feature] + self.min[feature]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_transform_roundtrip() {
        let rows = vec![
            [1.0, 10.0, 100.0, 0.0, 5.0],
            [3.0, 30.0, 300.0, 0.0, 15.0],
            [2.0, 20.0, 200.0, 0.0, 10.0],
        ];
        let s = StandardScaler::fit(&rows);
        assert!((s.mean[0] - 2.0).abs() < 1e-12);
        // Constant feature gets std 1.
        assert_eq!(s.std[3], 1.0);
        let t = s.transform(&rows[0]);
        let back = s.inverse_row(&t);
        for (a, b) in back.iter().zip(&rows[0]) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn transformed_data_standardized() {
        let rows: Vec<[f64; METRIC_DIM]> = (0..100)
            .map(|i| {
                let x = i as f64;
                [x, 2.0 * x + 5.0, x * x, 1.0, -x]
            })
            .collect();
        let s = StandardScaler::fit(&rows);
        let transformed: Vec<[f64; METRIC_DIM]> = rows.iter().map(|r| s.transform(r)).collect();
        for f in 0..METRIC_DIM {
            let mean: f64 =
                transformed.iter().map(|r| r[f]).sum::<f64>() / transformed.len() as f64;
            assert!(mean.abs() < 1e-9, "feature {f} mean {mean}");
        }
    }

    #[test]
    fn identity_is_noop() {
        let s = StandardScaler::identity();
        let row = [5.0, -1.0, 2.0, 3.0, 4.0];
        assert_eq!(s.transform(&row), row);
    }

    #[test]
    fn empty_fit_is_finite() {
        let s = StandardScaler::fit(&[]);
        let t = s.transform(&[1.0; METRIC_DIM]);
        assert!(t.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn minmax_roundtrip_and_range() {
        let rows: Vec<[f64; METRIC_DIM]> = (0..50)
            .map(|i| {
                let x = i as f64;
                [x, 2.0 * x + 5.0, 100.0 - x, 7.0, x * x]
            })
            .collect();
        let s = MinMaxScaler::fit(&rows);
        for row in &rows {
            let t = s.transform(row);
            // All features (incl. the constant one) land in [0, ~0.95].
            assert!(t.iter().all(|&v| (-1e-9..=1.0).contains(&v)), "{t:?}");
            let back = Scaler::inverse_row(&s, &t);
            for (a, b) in back.iter().zip(row) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // Headroom: a value 5% above the max still maps below 1.
        let mut above = rows[49];
        above[0] *= 1.05;
        assert!(s.transform(&above)[0] < 1.0);
    }

    #[test]
    fn minmax_constant_feature_safe() {
        let rows = vec![[3.0; METRIC_DIM]; 10];
        let s = MinMaxScaler::fit(&rows);
        let t = s.transform(&rows[0]);
        assert!(t.iter().all(|v| v.is_finite()));
        assert!((Scaler::inverse(&s, 0, t[0]) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn minmax_empty_fit_is_finite() {
        let s = MinMaxScaler::fit(&[]);
        assert!(s.transform(&[1.0; METRIC_DIM]).iter().all(|x| x.is_finite()));
    }
}

//! Request and response-record types, plus the resilience-plane
//! descriptors: per-request [`Priority`] classes, the configurable
//! arrival [`PriorityMix`], and the per-service [`SlaPolicy`]
//! (deadline / retry budget / backoff / shed depth).

use crate::sim::{ServiceId, Time};
use crate::util::rng::Pcg64;

/// The two task classes of the example application (paper §5.1.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskType {
    /// Sort a 3000-element array — `n log n ≈ 1e4` ops; handled at the edge.
    Sort,
    /// Eigenvalues of a 1000x1000 matrix — `n³ = 1e9` ops; forwarded to cloud.
    Eigen,
}

impl TaskType {
    pub fn name(&self) -> &'static str {
        match self {
            TaskType::Sort => "sort",
            TaskType::Eigen => "eigen",
        }
    }
}

/// Request priority class, drawn per request from the seed-derived SLA
/// stream (see [`super::sla_stream`]) via a configurable [`PriorityMix`].
/// Ordering is severity-descending: `Critical` is never load-shed;
/// `Batch` is the first (and only) class admission control drops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    Critical,
    Standard,
    Batch,
}

impl Priority {
    /// Number of priority classes (per-class stat array length).
    pub const COUNT: usize = 3;

    pub fn name(self) -> &'static str {
        match self {
            Priority::Critical => "critical",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Dense index into per-class arrays (severity-descending order).
    pub fn index(self) -> usize {
        match self {
            Priority::Critical => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }
}

/// Arrival priority mix: relative weights of the three classes. The
/// weights need not sum to 1 — the draw normalizes. Exactly one RNG
/// draw per submitted request, so the SLA stream advance schedule is
/// independent of the mix values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriorityMix {
    pub critical: f64,
    pub standard: f64,
    pub batch: f64,
}

impl Default for PriorityMix {
    /// The stock mix: mostly standard traffic with a critical head and
    /// a batch tail.
    fn default() -> Self {
        PriorityMix {
            critical: 0.1,
            standard: 0.7,
            batch: 0.2,
        }
    }
}

impl PriorityMix {
    /// Draw one priority class (single `f64` draw, weight-normalized).
    pub fn draw(&self, rng: &mut Pcg64) -> Priority {
        let total = (self.critical + self.standard + self.batch).max(f64::MIN_POSITIVE);
        let x = rng.f64() * total;
        if x < self.critical {
            Priority::Critical
        } else if x < self.critical + self.standard {
            Priority::Standard
        } else {
            Priority::Batch
        }
    }

    /// `c:s:b` label for reports/JSON, e.g. `0.1:0.7:0.2`.
    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.critical, self.standard, self.batch)
    }
}

/// Per-service SLA policy — the resilience plane's contract terms. A
/// request older than `deadline` is retried (exponential backoff with
/// seeded jitter) until its `max_retries` budget is spent, then counted
/// as an SLA violation and dropped; `Batch` arrivals are shed when the
/// service queue is deeper than `shed_queue_depth`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaPolicy {
    /// Per-attempt response deadline (µs, like all sim [`Time`]s).
    pub deadline: Time,
    /// Retry budget per request (0 = violate on the first timeout).
    pub max_retries: u32,
    /// Base backoff: attempt `k` retries after
    /// `backoff_base * 2^(k-1) + jitter`, jitter uniform in
    /// `[0, backoff_base)` from the dedicated SLA stream.
    pub backoff_base: Time,
    /// Admission-control threshold: `Batch` arrivals are shed while the
    /// target service queue holds more than this many requests
    /// (`Critical`/`Standard` are never shed).
    pub shed_queue_depth: usize,
}

impl SlaPolicy {
    /// Compact report/JSON label, e.g. `d500ms:r2:b100ms:q64`.
    pub fn label(&self) -> String {
        format!(
            "d{}ms:r{}:b{}ms:q{}",
            self.deadline / crate::sim::MS,
            self.max_retries,
            self.backoff_base / crate::sim::MS,
            self.shed_queue_depth
        )
    }
}

/// The full resilience-plane configuration of one world: the SLA policy
/// plus the arrival priority mix. Plain `Copy` data — rides inside
/// `ShardSpec`/`SweepConfig` like `FaultPlan` does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaConfig {
    pub policy: SlaPolicy,
    pub mix: PriorityMix,
}

impl SlaConfig {
    pub fn new(policy: SlaPolicy) -> Self {
        SlaConfig {
            policy,
            mix: PriorityMix::default(),
        }
    }

    /// Combined report/JSON label, e.g. `d500ms:r2:b100ms:q64@0.1:0.7:0.2`.
    pub fn label(&self) -> String {
        format!("{}@{}", self.policy.label(), self.mix.label())
    }
}

/// An in-flight request, stored in the app's
/// [`RequestArena`](super::RequestArena) and addressed by the
/// generational [`crate::sim::RequestId`] (the handle *is* the
/// identity — the payload carries no id of its own).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub task: TaskType,
    pub origin_zone: u32,
    pub service: ServiceId,
    pub created: Time,
    /// Priority class (always `Standard` when no SLA policy is
    /// installed — drawn from the SLA stream otherwise).
    pub priority: Priority,
    /// Completed retry count: 0 on the first attempt, incremented each
    /// time the deadline passes and the retry budget allows another go.
    pub attempts: u32,
}

/// A completed request (the experiments' unit of observation).
#[derive(Debug, Clone, Copy)]
pub struct ResponseRecord {
    pub task: TaskType,
    pub origin_zone: u32,
    pub created: Time,
    pub completed: Time,
}

impl ResponseRecord {
    /// End-to-end response time in seconds (what Figs 9, 11, 12 plot).
    pub fn response_secs(&self) -> f64 {
        crate::sim::to_secs(self.completed.saturating_sub(self.created))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    #[test]
    fn response_secs_computed() {
        let r = ResponseRecord {
            task: TaskType::Sort,
            origin_zone: 1,
            created: 2 * SEC,
            completed: 3 * SEC + SEC / 2,
        };
        assert!((r.response_secs() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn task_names() {
        assert_eq!(TaskType::Sort.name(), "sort");
        assert_eq!(TaskType::Eigen.name(), "eigen");
    }

    #[test]
    fn priority_index_and_names() {
        assert_eq!(Priority::Critical.index(), 0);
        assert_eq!(Priority::Standard.index(), 1);
        assert_eq!(Priority::Batch.index(), 2);
        assert_eq!(Priority::Batch.name(), "batch");
        assert_eq!(Priority::COUNT, 3);
    }

    #[test]
    fn priority_mix_draw_is_deterministic_and_respects_weights() {
        let mix = PriorityMix {
            critical: 0.2,
            standard: 0.5,
            batch: 0.3,
        };
        let mut a = Pcg64::new(9, 4_000_000);
        let mut b = Pcg64::new(9, 4_000_000);
        let mut counts = [0usize; Priority::COUNT];
        for _ in 0..10_000 {
            let p = mix.draw(&mut a);
            assert_eq!(p, mix.draw(&mut b), "same stream, same draws");
            counts[p.index()] += 1;
        }
        assert!(counts[0] > 1_500 && counts[0] < 2_500, "critical {counts:?}");
        assert!(counts[1] > 4_400 && counts[1] < 5_600, "standard {counts:?}");
        assert!(counts[2] > 2_400 && counts[2] < 3_600, "batch {counts:?}");
    }

    #[test]
    fn degenerate_mix_always_draws_the_only_class() {
        let mix = PriorityMix {
            critical: 1.0,
            standard: 0.0,
            batch: 0.0,
        };
        let mut rng = Pcg64::new(1, 1);
        for _ in 0..100 {
            assert_eq!(mix.draw(&mut rng), Priority::Critical);
        }
    }

    #[test]
    fn sla_policy_label_is_compact() {
        let p = SlaPolicy {
            deadline: 500 * crate::sim::MS,
            max_retries: 2,
            backoff_base: 100 * crate::sim::MS,
            shed_queue_depth: 64,
        };
        assert_eq!(p.label(), "d500ms:r2:b100ms:q64");
        assert_eq!(SlaConfig::new(p).mix, PriorityMix::default());
    }
}

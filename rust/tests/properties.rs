//! Property-based tests (hand-rolled: the offline crate set has no
//! proptest). Each property runs hundreds of seeded random cases; a
//! failure prints the case seed for reproduction.

use ppa_edge::cluster::{
    Cluster, Deployment, NodeSpec, PodPhase, PodSpec, Selector, Tier,
};
use ppa_edge::forecast::{Scaler, StandardScaler};
use ppa_edge::metrics::METRIC_DIM;
use ppa_edge::sim::{CoreKind, Event, EventQueue, HOUR, MIN, SEC};
use ppa_edge::util::json::Json;
use ppa_edge::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Event queue: pops are globally time-ordered, FIFO within a timestamp.
// ---------------------------------------------------------------------------

#[test]
fn prop_event_queue_total_order() {
    for seed in 0..200 {
        let mut rng = Pcg64::new(seed, 0);
        let mut q = EventQueue::new();
        let n = 1 + rng.below(500) as usize;
        for i in 0..n {
            q.schedule_at(rng.below(1000), Event::WorkloadTick { generator: i as u32 });
        }
        let mut last_t = 0;
        let mut seen_at_t: Vec<u32> = Vec::new();
        while let Some((t, ev)) = q.pop() {
            assert!(t >= last_t, "seed {seed}: time went backwards");
            let Event::WorkloadTick { generator } = ev else { unreachable!() };
            if t != last_t {
                seen_at_t.clear();
            }
            // FIFO within equal timestamps == strictly increasing ids
            // among same-time events (they were scheduled in id order).
            if let Some(&prev) = seen_at_t.last() {
                assert!(generator > prev, "seed {seed}: FIFO violated at t={t}");
            }
            seen_at_t.push(generator);
            last_t = t;
        }
    }
}

// ---------------------------------------------------------------------------
// Calendar queue vs the BinaryHeap reference: random interleaved
// schedule/pop sequences — past-time clamping, same-timestamp bursts,
// beyond-horizon (overflow) schedules, bounded pops — produce identical
// pop order, lengths, and peek times on both cores.
// ---------------------------------------------------------------------------

#[test]
fn prop_calendar_queue_matches_heap_reference() {
    for seed in 0..120 {
        let mut rng = Pcg64::new(seed, 7);
        let mut cal = EventQueue::with_core(CoreKind::Calendar);
        let mut heap = EventQueue::with_core(CoreKind::Heap);
        let ops = 200 + rng.below(400);
        let mut next_id = 0u32;
        for step in 0..ops {
            let roll = rng.below(100);
            if roll < 55 {
                // Schedule a burst at one target time drawn from a mix of
                // regimes (past times clamp to `now` on both cores).
                let at = match rng.below(6) {
                    0 => cal.now().saturating_sub(rng.below(10 * SEC)),
                    1 => cal.now(), // same-timestamp burst at the clock
                    2 => cal.now() + rng.below(2 * SEC),
                    3 => cal.now() + rng.below(5 * MIN),
                    4 => cal.now() + rng.below(60 * MIN), // around the wheel horizon
                    _ => cal.now() + 2 * HOUR + rng.below(HOUR), // deep overflow
                };
                for _ in 0..1 + rng.below(4) {
                    let ev = Event::WorkloadTick { generator: next_id };
                    next_id += 1;
                    cal.schedule_at(at, ev.clone());
                    heap.schedule_at(at, ev);
                }
            } else if roll < 85 {
                let (a, b) = (cal.pop(), heap.pop());
                assert_eq!(a, b, "seed {seed} step {step}: pop order diverged");
            } else {
                // Bounded pop: both cores must agree on due-ness too.
                let limit = cal.now() + rng.below(10 * MIN);
                let (a, b) = (cal.pop_due(limit), heap.pop_due(limit));
                assert_eq!(a, b, "seed {seed} step {step}: pop_due diverged");
            }
            assert_eq!(cal.len(), heap.len(), "seed {seed} step {step}: len");
            assert_eq!(cal.now(), heap.now(), "seed {seed} step {step}: now");
            assert_eq!(
                cal.peek_time(),
                heap.peek_time(),
                "seed {seed} step {step}: peek_time"
            );
        }
        // Drain to exhaustion: the full remaining streams must match.
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b, "seed {seed}: drain diverged");
            if a.is_none() {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cluster: resource accounting stays consistent under random scaling.
// ---------------------------------------------------------------------------

fn check_invariants(c: &Cluster, seed: u64) {
    // Node allocations equal the sum of bound, non-Gone pod requests.
    for (ni, node) in c.nodes.iter().enumerate() {
        let mut cpu = 0u32;
        let mut ram = 0u32;
        for &pid in &node.pods {
            let p = c.pod(pid);
            assert_ne!(p.phase, PodPhase::Gone, "seed {seed}: Gone pod bound to node {ni}");
            cpu += p.spec.cpu_millis;
            ram += p.spec.ram_mb;
        }
        assert_eq!(node.alloc_cpu, cpu, "seed {seed}: node {ni} cpu accounting");
        assert_eq!(node.alloc_ram, ram, "seed {seed}: node {ni} ram accounting");
        assert!(
            node.alloc_cpu <= node.spec.allocatable_cpu(),
            "seed {seed}: node {ni} over-allocated"
        );
    }
    // Deployment pod lists contain no Gone pods and every non-Gone pod is
    // listed exactly once.
    for (di, dep) in c.deployments.iter().enumerate() {
        for &pid in &dep.pods {
            assert_ne!(
                c.pod(pid).phase,
                PodPhase::Gone,
                "seed {seed}: dep {di} lists a Gone pod"
            );
        }
    }
    for pod in &c.pods {
        if pod.phase != PodPhase::Gone {
            let listed = c.deployments[pod.deployment.0 as usize]
                .pods
                .iter()
                .filter(|&&p| p == pod.id)
                .count();
            assert_eq!(listed, 1, "seed {seed}: pod listed {listed} times");
        }
    }
}

#[test]
fn prop_cluster_accounting_under_random_scaling() {
    for seed in 0..60 {
        let mut rng = Pcg64::new(seed, 1);
        let mut c = Cluster::new();
        for z in 1..=2 {
            for i in 0..2 {
                c.add_node(NodeSpec::new(
                    &format!("e{z}-{i}"),
                    Tier::Edge,
                    z,
                    1000 + 500 * rng.below(4) as u32,
                    2048,
                ));
            }
        }
        let dep_a = c.add_deployment(Deployment::new(
            "a",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(300, 128),
            0,
            50,
        ));
        let dep_b = c.add_deployment(Deployment::new(
            "b",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            0,
            50,
        ));
        let mut q = EventQueue::new();
        for _step in 0..40 {
            let dep = if rng.chance(0.5) { dep_a } else { dep_b };
            let desired = rng.below(10) as usize;
            c.reconcile(dep, desired, &mut q, &mut rng);
            // Randomly deliver some pending lifecycle events.
            for _ in 0..rng.below(6) {
                match q.pop() {
                    Some((_, Event::PodRunning { pod })) => {
                        c.on_pod_running(pod);
                    }
                    Some((_, Event::PodTerminated { pod })) => c.on_pod_terminated(pod),
                    Some(_) => {}
                    None => break,
                }
            }
            check_invariants(&c, seed);
        }
        // Drain and re-check.
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::PodRunning { pod } => {
                    c.on_pod_running(pod);
                }
                Event::PodTerminated { pod } => c.on_pod_terminated(pod),
                _ => {}
            }
        }
        check_invariants(&c, seed);
    }
}

#[test]
fn prop_max_replicas_is_schedulable() {
    // Whatever max_replicas claims must actually schedule.
    for seed in 0..40 {
        let mut rng = Pcg64::new(seed, 2);
        let mut c = Cluster::new();
        let n_nodes = 1 + rng.below(4) as usize;
        for i in 0..n_nodes {
            c.add_node(NodeSpec::new(
                &format!("n{i}"),
                Tier::Edge,
                1,
                800 + 400 * rng.below(6) as u32,
                1024 + 512 * rng.below(4) as u32,
            ));
        }
        let dep = c.add_deployment(Deployment::new(
            "d",
            Selector::new(Tier::Edge, None),
            PodSpec::new(
                200 + 100 * rng.below(5) as u32,
                128 + 64 * rng.below(4) as u32,
            ),
            0,
            1000,
        ));
        let cap = c.max_replicas(dep);
        let mut q = EventQueue::new();
        c.reconcile(dep, cap, &mut q, &mut rng);
        let pending = c.count_phase(dep, PodPhase::Pending);
        assert_eq!(
            pending, 0,
            "seed {seed}: max_replicas={cap} but {pending} unschedulable"
        );
        // And one more must NOT fit.
        if cap > 0 {
            c.reconcile(dep, cap + 1, &mut q, &mut rng);
            assert_eq!(
                c.count_phase(dep, PodPhase::Pending),
                1,
                "seed {seed}: cap={cap} not tight"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Cluster index plane: after randomized reconcile/dispatch/terminate
// interleavings, every index (idle-pod sets, phase counters, free-slot
// list, capacity ledgers, matching-node caches) must equal a
// from-scratch scan — `Cluster::verify_indices` rebuilds and compares.
// ---------------------------------------------------------------------------

/// Deliver up to `limit` pending events through the app/cluster
/// handlers (the driver's event loop, minus the periodic ticks).
fn deliver_events(
    app: &mut ppa_edge::app::App,
    cluster: &mut Cluster,
    q: &mut EventQueue,
    rng: &mut Pcg64,
    limit: u64,
) {
    for _ in 0..limit {
        match q.pop() {
            Some((_, Event::RequestArrival { request_id })) => {
                app.on_arrival(request_id, cluster, q, rng)
            }
            Some((_, Event::ServiceComplete { pod, request_id })) => {
                app.on_complete(pod, request_id, cluster, q, rng)
            }
            Some((_, Event::PodRunning { pod })) => {
                cluster.on_pod_running(pod);
            }
            Some((_, Event::PodTerminated { pod })) => cluster.on_pod_terminated(pod),
            Some(_) => {}
            None => break,
        }
    }
}

#[test]
fn prop_cluster_indices_match_scan_after_interleavings() {
    use ppa_edge::app::{App, TaskCosts, TaskType};
    use ppa_edge::config::{paper_cluster, Topology};

    for seed in 0..64u64 {
        // Alternate the paper topology with a city-8 cell.
        let cfg = if seed % 2 == 0 {
            paper_cluster()
        } else {
            Topology::EdgeCity {
                zones: 8,
                workers_per_zone: 2,
                mix: Default::default(),
            }
            .cluster()
        };
        let (mut cluster, dep_ids) = cfg.build();
        let edge: Vec<(u32, _)> = cfg.deployments[..dep_ids.len() - 1]
            .iter()
            .zip(&dep_ids)
            .map(|(d, &id)| (d.zone.expect("edge deployments set a zone"), id))
            .collect();
        let cloud = *dep_ids.last().unwrap();
        let n_zones = edge.len() as u64;
        let mut app = App::new(TaskCosts::default(), &edge, cloud);
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(seed, 9);

        for step in 0..60 {
            match rng.below(10) {
                // Reconcile a random deployment to a random size
                // (drives spawn, surplus-victim selection, drains).
                0..=3 => {
                    let di = rng.below(dep_ids.len() as u64) as usize;
                    let desired = rng.below(7) as usize;
                    cluster.reconcile(dep_ids[di], desired, &mut q, &mut rng);
                }
                4 => cluster.retry_pending(&mut q, &mut rng),
                // Submit a burst of tasks (drives dispatch).
                5..=7 => {
                    for _ in 0..1 + rng.below(5) {
                        let task = if rng.chance(0.8) {
                            TaskType::Sort
                        } else {
                            TaskType::Eigen
                        };
                        let zone = 1 + rng.below(n_zones) as u32;
                        app.submit(task, zone, q.now(), &mut q);
                    }
                }
                // Deliver a slice of pending events out of order with
                // the control actions above.
                _ => {
                    let limit = rng.below(12);
                    deliver_events(&mut app, &mut cluster, &mut q, &mut rng, limit);
                }
            }
            if step % 6 == 0 {
                cluster.verify_indices();
            }
        }
        // Drain to exhaustion; the indices must still mirror a scan.
        deliver_events(&mut app, &mut cluster, &mut q, &mut rng, u64::MAX);
        assert!(q.is_empty(), "seed {seed}: queue drained");
        cluster.verify_indices();
    }
}

// ---------------------------------------------------------------------------
// Chaos plane: node crashes and rejoins woven into the same randomized
// interleavings. After EVERY fault the index plane must still mirror a
// from-scratch scan, resource accounting must balance, and no request
// may be lost — a crash's orphans are requeued, never dropped.
// ---------------------------------------------------------------------------

#[test]
fn prop_cluster_indices_survive_random_faults() {
    use ppa_edge::app::{App, TaskCosts, TaskType};
    use ppa_edge::config::{paper_cluster, Topology};
    use ppa_edge::sim::NodeId;

    for seed in 0..64u64 {
        let cfg = if seed % 2 == 0 {
            paper_cluster()
        } else {
            Topology::EdgeCity {
                zones: 4,
                workers_per_zone: 2,
                mix: ppa_edge::config::ClassMix::parse("small,large").unwrap(),
            }
            .cluster()
        };
        let (mut cluster, dep_ids) = cfg.build();
        let edge: Vec<(u32, _)> = cfg.deployments[..dep_ids.len() - 1]
            .iter()
            .zip(&dep_ids)
            .map(|(d, &id)| (d.zone.expect("edge deployments set a zone"), id))
            .collect();
        let cloud = *dep_ids.last().unwrap();
        let n_zones = edge.len() as u64;
        let mut app = App::new(TaskCosts::default(), &edge, cloud);
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(seed, 9);
        let n_nodes = cluster.nodes.len() as u64;
        let mut submitted = 0usize;

        for step in 0..80 {
            match rng.below(12) {
                0..=2 => {
                    let di = rng.below(dep_ids.len() as u64) as usize;
                    let desired = 1 + rng.below(6) as usize;
                    cluster.reconcile(dep_ids[di], desired, &mut q, &mut rng);
                }
                3 => cluster.retry_pending(&mut q, &mut rng),
                4..=6 => {
                    for _ in 0..1 + rng.below(5) {
                        let task = if rng.chance(0.8) {
                            TaskType::Sort
                        } else {
                            TaskType::Eigen
                        };
                        let zone = 1 + rng.below(n_zones) as u32;
                        app.submit(task, zone, q.now(), &mut q);
                        submitted += 1;
                    }
                }
                // Crash a random node: indices must survive the mass
                // eviction, and every in-flight request on the node must
                // come back as a queued orphan.
                7..=8 => {
                    let nid = NodeId(rng.below(n_nodes) as u32);
                    if let Some(out) = cluster.crash_node(nid) {
                        cluster.verify_indices();
                        for &dep in &out.deployments {
                            let desired = cluster.deployments[dep.0 as usize].desired_replicas;
                            cluster.reconcile(dep, desired, &mut q, &mut rng);
                        }
                        app.requeue_orphans(&out.orphans, &mut cluster, &mut q, &mut rng);
                        cluster.verify_indices();
                    }
                }
                // Rejoin a random node (no-op on up nodes).
                9 => {
                    let nid = NodeId(rng.below(n_nodes) as u32);
                    if cluster.rejoin_node(nid) {
                        cluster.retry_pending(&mut q, &mut rng);
                        cluster.verify_indices();
                    }
                }
                _ => {
                    let limit = rng.below(12);
                    deliver_events(&mut app, &mut cluster, &mut q, &mut rng, limit);
                }
            }
            if step % 8 == 0 {
                cluster.verify_indices();
                check_invariants(&cluster, seed);
            }
        }
        // Rejoin everything, drain to exhaustion: indices and resource
        // accounting must balance, and every submitted request must be
        // accounted for — completed or still queued, never vanished.
        for i in 0..n_nodes {
            if cluster.rejoin_node(NodeId(i as u32)) {
                cluster.retry_pending(&mut q, &mut rng);
            }
        }
        deliver_events(&mut app, &mut cluster, &mut q, &mut rng, u64::MAX);
        assert!(q.is_empty(), "seed {seed}: queue drained");
        cluster.verify_indices();
        check_invariants(&cluster, seed);
        let accounted = app.completed() + app.in_flight_len();
        assert_eq!(
            accounted, submitted,
            "seed {seed}: {submitted} submitted but only {accounted} accounted for"
        );
    }
}

// ---------------------------------------------------------------------------
// Scaler: transform/inverse roundtrip on arbitrary data.
// ---------------------------------------------------------------------------

#[test]
fn prop_scaler_roundtrip() {
    for seed in 0..100 {
        let mut rng = Pcg64::new(seed, 3);
        let n = 2 + rng.below(100) as usize;
        let rows: Vec<[f64; METRIC_DIM]> = (0..n)
            .map(|_| {
                let mut r = [0.0; METRIC_DIM];
                for v in &mut r {
                    let mean = rng.range(-100.0, 100.0);
                    let std = rng.range(0.0, 50.0);
                    *v = rng.normal_ms(mean, std);
                }
                r
            })
            .collect();
        let s = StandardScaler::fit(&rows);
        for row in &rows {
            let back = s.inverse_row(&s.transform(row));
            for (a, b) in back.iter().zip(row) {
                assert!(
                    (a - b).abs() < 1e-6 * (1.0 + b.abs()),
                    "seed {seed}: roundtrip {a} vs {b}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// JSON: print→parse roundtrip over random documents.
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    if depth == 0 {
        return match rng.below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            _ => Json::Str(random_string(rng)),
        };
    }
    match rng.below(6) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => Json::Num((rng.normal() * 1e6).round() / 16.0),
        3 => Json::Str(random_string(rng)),
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}_{}", random_string(rng)), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

fn random_string(rng: &mut Pcg64) -> String {
    let chars = ['a', 'Z', '9', ' ', '"', '\\', '\n', '\t', 'é', '日', '😀', '\u{7}'];
    (0..rng.below(10)).map(|_| *rng.pick(&chars)).collect()
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..300 {
        let mut rng = Pcg64::new(seed, 4);
        let doc = random_json(&mut rng, 3);
        let text = doc.to_string();
        let parsed = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: reparse failed: {e}\n{text}"));
        assert_eq!(parsed, doc, "seed {seed}: roundtrip mismatch");
    }
}

// ---------------------------------------------------------------------------
// Eq 1 / HPA bounds.
// ---------------------------------------------------------------------------

#[test]
fn prop_eq1_monotone_and_bounded() {
    use ppa_edge::autoscaler::eq1_replicas;
    for seed in 0..100 {
        let mut rng = Pcg64::new(seed, 5);
        let threshold = rng.range(1.0, 200.0);
        let a = rng.range(0.0, 1000.0);
        let b = a + rng.range(0.0, 1000.0);
        assert!(
            eq1_replicas(a, threshold) <= eq1_replicas(b, threshold),
            "seed {seed}: monotonicity"
        );
        let r = eq1_replicas(a, threshold) as f64;
        assert!(r * threshold >= a, "seed {seed}: enough capacity");
        assert!((r - 1.0) * threshold < a || r == 0.0, "seed {seed}: no overshoot");
    }
}

// ---------------------------------------------------------------------------
// Statistics: Welch p-value sanity across random same/different samples.
// ---------------------------------------------------------------------------

#[test]
fn prop_welch_p_uniform_under_null() {
    // Under H0, p-values should be roughly uniform: count p<0.05 ≈ 5%.
    let mut rejections = 0;
    let trials = 400;
    for seed in 0..trials {
        let mut rng = Pcg64::new(seed as u64, 6);
        let a: Vec<f64> = (0..80).map(|_| rng.normal_ms(10.0, 2.0)).collect();
        let b: Vec<f64> = (0..80).map(|_| rng.normal_ms(10.0, 2.0)).collect();
        if ppa_edge::stats::welch_t_test(&a, &b).p < 0.05 {
            rejections += 1;
        }
    }
    let rate = rejections as f64 / trials as f64;
    assert!(rate > 0.01 && rate < 0.12, "null rejection rate {rate}");
}

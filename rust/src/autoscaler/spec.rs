//! Metric specs and per-metric recommendations — the first stage of the
//! scaling-decision pipeline.
//!
//! The paper's headline property is that the PPA "forecasts workloads in
//! advance with multiple user-defined/customized metrics". A
//! [`MetricSpec`] is one such user-defined metric target (the analogue of
//! one `metrics:` entry of a Kubernetes HPA object): *which* protocol-
//! vector metric, the Eq-1 target value, and whether the value feeding
//! Eq 1 is the current scrape or the model's forecast. An autoscaler
//! evaluates every spec into a [`Recommendation`] and combines them
//! K8s-style — the **max** desired count across metrics wins — before the
//! shared [`super::ScalingBehavior`] stage clamps the result.

use crate::metrics::{parse_metric, METRIC_NAMES};
use anyhow::Context;

/// Where the metric value feeding Eq 1 comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricSource {
    /// The latest scraped value (reactive — what the stock HPA uses).
    Current,
    /// The forecaster's one-step-ahead prediction (proactive). Falls
    /// back to `Current` when the model is invalid or under-confident
    /// (Algorithm 1's "Robust" property).
    Forecast,
}

/// One user-defined metric target: Eq 1 is evaluated per spec as
/// `ceil(value / target)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricSpec {
    /// Protocol-vector index (see [`crate::metrics::METRIC_NAMES`]).
    pub metric: usize,
    /// Eq-1 denominator (the paper's `Threashold`, Table 4).
    pub target: f64,
    /// Requested value source. Reactive autoscalers (HPA) always read
    /// `Current` regardless; the PPA honours the request per spec.
    pub source: MetricSource,
}

impl MetricSpec {
    /// A reactive spec on the current metric value.
    pub fn current(metric: usize, target: f64) -> Self {
        MetricSpec {
            metric,
            target,
            source: MetricSource::Current,
        }
    }

    /// A proactive spec on the forecast metric value.
    pub fn forecast(metric: usize, target: f64) -> Self {
        MetricSpec {
            metric,
            target,
            source: MetricSource::Forecast,
        }
    }

    /// Parse `name:target[:current|:forecast]` where `name` is a metric
    /// name or index ([`crate::metrics::parse_metric`]) — the CLI
    /// `--metric` syntax, e.g. `cpu:70`, `req_rate:150:current`, `0:80`.
    /// `default_source` applies when the third segment is omitted.
    pub fn parse(s: &str, default_source: MetricSource) -> crate::Result<Self> {
        let mut parts = s.splitn(3, ':');
        let name = parts.next().unwrap_or("");
        let target_str = parts
            .next()
            .with_context(|| format!("metric spec '{s}' needs a target, e.g. cpu:70"))?;
        let metric = parse_metric(name)?;
        let target: f64 = target_str
            .trim()
            .parse()
            .ok()
            .filter(|t: &f64| t.is_finite() && *t > 0.0)
            .with_context(|| format!("metric spec '{s}': target must be a positive number"))?;
        let source = match parts.next() {
            None => default_source,
            Some("current") => MetricSource::Current,
            Some("forecast") => MetricSource::Forecast,
            Some(other) => anyhow::bail!(
                "metric spec '{s}': unknown source '{other}' (current|forecast)"
            ),
        };
        Ok(MetricSpec {
            metric,
            target,
            source,
        })
    }

    /// The metric's protocol-vector name.
    pub fn name(&self) -> &'static str {
        METRIC_NAMES[self.metric]
    }

    /// Compact `name:target` label (report/JSON form).
    pub fn label(&self) -> String {
        format!("{}:{}", self.name(), self.target)
    }
}

/// Compact label of a whole spec set: `cpu:70+req_rate:150` (the sweep
/// JSON `"specs"` entries).
pub fn specs_label(specs: &[MetricSpec]) -> String {
    if specs.is_empty() {
        return "none".to_string();
    }
    specs
        .iter()
        .map(MetricSpec::label)
        .collect::<Vec<_>>()
        .join("+")
}

/// One spec's evaluated outcome: the per-metric desired replica count
/// plus full provenance — what the combine stage and the structured
/// decision logs consume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Recommendation {
    /// Protocol-vector index of the spec's metric.
    pub metric: usize,
    /// The spec's Eq-1 target.
    pub target: f64,
    /// The value Eq 1 was actually fed (current or forecast).
    pub value: f64,
    /// The source actually used — `Current` when a `Forecast` spec fell
    /// back (invalid model / low confidence).
    pub source: MetricSource,
    /// The model's prediction for this metric, when one was made (kept
    /// even under fallback, for the prediction logs).
    pub predicted: Option<f64>,
    /// Desired replicas from this metric alone (pre-combine, unclamped).
    pub desired: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{M_CPU, M_REQ_RATE};

    #[test]
    fn parse_name_and_index_forms() {
        let s = MetricSpec::parse("cpu:70", MetricSource::Current).unwrap();
        assert_eq!(s.metric, M_CPU);
        assert!((s.target - 70.0).abs() < 1e-12);
        assert_eq!(s.source, MetricSource::Current);
        let s = MetricSpec::parse("4:1.5", MetricSource::Forecast).unwrap();
        assert_eq!(s.metric, M_REQ_RATE);
        assert_eq!(s.source, MetricSource::Forecast);
    }

    #[test]
    fn parse_explicit_source_overrides_default() {
        let s = MetricSpec::parse("req_rate:150:current", MetricSource::Forecast).unwrap();
        assert_eq!(s.source, MetricSource::Current);
        let s = MetricSpec::parse("cpu:70:forecast", MetricSource::Current).unwrap();
        assert_eq!(s.source, MetricSource::Forecast);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(MetricSpec::parse("cpu", MetricSource::Current).is_err());
        assert!(MetricSpec::parse("cpu:-3", MetricSource::Current).is_err());
        assert!(MetricSpec::parse("cpu:NaN", MetricSource::Current).is_err());
        assert!(MetricSpec::parse("bogus:70", MetricSource::Current).is_err());
        assert!(MetricSpec::parse("cpu:70:psychic", MetricSource::Current).is_err());
        let err = format!(
            "{:#}",
            MetricSpec::parse("watts:70", MetricSource::Current).unwrap_err()
        );
        assert!(err.contains("req_rate"), "error lists metric names: {err}");
    }

    #[test]
    fn labels_compact() {
        let a = MetricSpec::current(M_CPU, 70.0);
        let b = MetricSpec::forecast(M_REQ_RATE, 1.5);
        assert_eq!(a.label(), "cpu:70");
        assert_eq!(b.label(), "req_rate:1.5");
        assert_eq!(specs_label(&[a, b]), "cpu:70+req_rate:1.5");
        assert_eq!(specs_label(&[]), "none");
    }
}

//! Static policies: key-metric value → replica count. The default is a
//! conservative variant of the paper's Eq 1 (HPA ceil rule); users can
//! inject custom policies (§4.2.1 "Static Policies are customizable").

/// A pluggable replica policy.
///
/// `key_value` is the (possibly predicted) key metric Algorithm 1
/// selected; `current_key` is the currently measured key metric — kept
/// available so policies can be conservative about scale-down.
pub trait StaticPolicy {
    fn name(&self) -> &str;

    /// Desired replicas.
    fn replicas(
        &self,
        key_value: f64,
        current_key: f64,
        threshold: f64,
        current_replicas: usize,
    ) -> usize;
}

/// Eq 1 on the selected key metric only: `ceil(key / threshold)` — the
/// paper's literal default static policy.
#[derive(Debug, Default, Clone, Copy)]
pub struct HpaCeilPolicy;

impl StaticPolicy for HpaCeilPolicy {
    fn name(&self) -> &str {
        "hpa-ceil"
    }

    fn replicas(
        &self,
        key_value: f64,
        _current_key: f64,
        threshold: f64,
        _current: usize,
    ) -> usize {
        super::super::eq1_replicas(key_value, threshold).max(1)
    }
}

/// Eq 1 on `max(predicted, current)`: scale up as soon as either the
/// model or the live metric demands it, scale down only when both agree.
/// This keeps the proactive ramp-up benefit while preventing a transient
/// prediction dip from killing pods that a 10–20 s init delay would make
/// expensive to get back — the PPA's default.
#[derive(Debug, Default, Clone, Copy)]
pub struct ConservativeCeilPolicy;

impl StaticPolicy for ConservativeCeilPolicy {
    fn name(&self) -> &str {
        "conservative-ceil"
    }

    fn replicas(
        &self,
        key_value: f64,
        current_key: f64,
        threshold: f64,
        _current: usize,
    ) -> usize {
        super::super::eq1_replicas(key_value.max(current_key), threshold).max(1)
    }
}

/// A damped policy that moves at most `max_step` replicas per decision —
/// an example custom policy (used by `examples/custom_policy.rs` to
/// demonstrate injection, and by the ablation bench).
#[derive(Debug, Clone, Copy)]
pub struct StepPolicy {
    pub max_step: usize,
}

impl StaticPolicy for StepPolicy {
    fn name(&self) -> &str {
        "damped-step"
    }

    fn replicas(
        &self,
        key_value: f64,
        _current_key: f64,
        threshold: f64,
        current: usize,
    ) -> usize {
        let target = super::super::eq1_replicas(key_value, threshold).max(1);
        if target > current {
            target.min(current + self.max_step)
        } else {
            target.max(current.saturating_sub(self.max_step))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_policy_eq1() {
        let p = HpaCeilPolicy;
        assert_eq!(p.replicas(140.1, 0.0, 70.0, 1), 3);
        assert_eq!(p.replicas(0.0, 500.0, 70.0, 5), 1, "floor of 1, ignores current");
    }

    #[test]
    fn conservative_policy_uses_max() {
        let p = ConservativeCeilPolicy;
        // Predicted spike, current low: scale up proactively.
        assert_eq!(p.replicas(210.0, 70.0, 70.0, 1), 3);
        // Predicted dip, current high: hold.
        assert_eq!(p.replicas(10.0, 210.0, 70.0, 3), 3);
        // Both low: scale down.
        assert_eq!(p.replicas(10.0, 60.0, 70.0, 3), 1);
    }

    #[test]
    fn step_policy_damps_both_directions() {
        let p = StepPolicy { max_step: 2 };
        assert_eq!(p.replicas(700.0, 0.0, 70.0, 1), 3, "up capped at +2");
        assert_eq!(p.replicas(70.0, 0.0, 70.0, 8), 6, "down capped at -2");
        assert_eq!(p.replicas(140.0, 0.0, 70.0, 1), 2, "small moves unaffected");
    }
}

//! The Evaluator — paper Algorithm 1, generalized to multiple
//! user-defined metrics.
//!
//! ```text
//! Get current_metrics;
//! Calculate max_replicas limited by system resources;
//! model <- Load(model_file);
//! if model.isValid():
//!     prediction <- Predict(model, current_metrics)
//!     if model.isBayesian() and confidence < threshold:
//!         prediction <- invalid            // fall back to current
//! for spec in metric_specs:               // multi-metric extension
//!     value <- prediction[spec] if spec.source = Forecast and valid
//!              else current[spec]
//!     replicas[spec] <- Static_Policies(value)
//! num_replicas <- max(replicas)           // K8s combine
//! num_replicas <- min(num_replicas, max_replicas)
//! ```
//!
//! The model predicts the whole protocol vector once per loop (the §4.2.2
//! protocol: "the model should predict all input variables"); each
//! `Forecast` spec reads its own component of that prediction.

use super::super::{combine_recommendations, ScaleDecision};
use super::policy::{ConservativeCeilPolicy, StaticPolicy};
use crate::autoscaler::spec::{MetricSource, MetricSpec, Recommendation};
use crate::cluster::{Cluster, DeploymentId};
use crate::forecast::Forecaster;
use crate::metrics::METRIC_DIM;

/// The Evaluator: injected model + static policy + confidence gate.
pub struct Evaluator {
    forecaster: Box<dyn Forecaster>,
    policy: Box<dyn StaticPolicy>,
    confidence_threshold: f64,
}

impl Evaluator {
    pub fn new(forecaster: Box<dyn Forecaster>, confidence_threshold: f64) -> Self {
        Evaluator {
            forecaster,
            policy: Box::new(ConservativeCeilPolicy),
            confidence_threshold,
        }
    }

    pub fn set_policy(&mut self, policy: Box<dyn StaticPolicy>) {
        self.policy = policy;
    }

    pub fn forecaster_mut(&mut self) -> &mut dyn Forecaster {
        self.forecaster.as_mut()
    }

    pub fn forecaster(&self) -> &dyn Forecaster {
        self.forecaster.as_ref()
    }

    pub fn forecaster_name(&self) -> &str {
        self.forecaster.name()
    }

    /// Feed the realized vector back to confidence-tracking models.
    pub fn observe_actual(&mut self, actual: &[f64; METRIC_DIM]) {
        self.forecaster.observe(actual);
    }

    /// Algorithm 1 over the spec set: one [`Recommendation`] per spec,
    /// combined max-wins and capped at the resource-limited maximum.
    /// (The behavior stage in [`super::Ppa`] runs after this.)
    pub fn evaluate(
        &mut self,
        specs: &[MetricSpec],
        current: &[f64; METRIC_DIM],
        history: &[[f64; METRIC_DIM]],
        target: DeploymentId,
        cluster: &Cluster,
    ) -> ScaleDecision {
        assert!(!specs.is_empty(), "Algorithm 1 needs >= 1 metric spec");
        // "Calculate max_replicas limited by system resources": the total
        // replica count the matching nodes can host (other deployments'
        // usage subtracted; this deployment's own pods are part of the
        // total, not additional load).
        let max_replicas = cluster.max_replicas(target);
        let current_replicas = cluster.live_replicas(target);

        // One whole-vector prediction per loop; the confidence gate and
        // the invalid-model fallback are model-global ("Robust").
        let raw_prediction = self.forecaster.predict(history);
        let mut used_fallback = false;
        let usable_prediction = match raw_prediction {
            Some(vector) => {
                if self.forecaster.is_bayesian()
                    && self.forecaster.confidence() < self.confidence_threshold
                {
                    // Confident-only proactivity: fall back to reactive.
                    used_fallback = true;
                    None
                } else {
                    Some(vector)
                }
            }
            None => {
                // Invalid/missing model file — robust fallback.
                used_fallback = true;
                None
            }
        };

        let mut recommendations = Vec::with_capacity(specs.len());
        for spec in specs {
            let current_value = current[spec.metric];
            let predicted = raw_prediction.map(|v| v[spec.metric]);
            let (value, source) = match (spec.source, usable_prediction) {
                (MetricSource::Forecast, Some(vector)) => {
                    (vector[spec.metric], MetricSource::Forecast)
                }
                // Forecast requested but unavailable → reactive fallback.
                (MetricSource::Forecast, None) => (current_value, MetricSource::Current),
                (MetricSource::Current, _) => (current_value, MetricSource::Current),
            };
            let desired = self
                .policy
                .replicas(value, current_value, spec.target, current_replicas);
            recommendations.push(Recommendation {
                metric: spec.metric,
                target: spec.target,
                value,
                source,
                predicted,
                desired,
            });
        }

        let desired = combine_recommendations(
            &recommendations,
            cluster.min_replicas(target),
            Some(max_replicas),
        );

        ScaleDecision {
            desired,
            key_value: recommendations[0].value,
            predicted: recommendations[0].predicted,
            used_fallback,
            recommendations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector, Tier};
    use crate::forecast::{NaiveForecaster, UpdatePolicy};
    use crate::metrics::{M_CPU, M_REQ_RATE};
    use crate::sim::EventQueue;
    use crate::util::rng::Pcg64;

    struct FailingModel;
    impl Forecaster for FailingModel {
        fn name(&self) -> &str {
            "failing"
        }
        fn predict(&mut self, _h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
            None
        }
        fn retrain(
            &mut self,
            _h: &[[f64; METRIC_DIM]],
            _p: UpdatePolicy,
        ) -> crate::Result<()> {
            Ok(())
        }
    }

    struct UnderConfidentModel;
    impl Forecaster for UnderConfidentModel {
        fn name(&self) -> &str {
            "shaky"
        }
        fn predict(&mut self, _h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
            Some([999.0; METRIC_DIM])
        }
        fn retrain(
            &mut self,
            _h: &[[f64; METRIC_DIM]],
            _p: UpdatePolicy,
        ) -> crate::Result<()> {
            Ok(())
        }
        fn is_bayesian(&self) -> bool {
            true
        }
        fn confidence(&self) -> f64 {
            0.1
        }
    }

    fn fixture() -> Cluster {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e", Tier::Edge, 1, 2000, 2048));
        let dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1, 0);
        cluster.reconcile(dep, 1, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let crate::sim::Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
            }
        }
        cluster
    }

    fn vec_with_cpu(cpu: f64) -> [f64; METRIC_DIM] {
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = cpu;
        v
    }

    fn cpu_specs(target: f64) -> Vec<MetricSpec> {
        vec![MetricSpec::forecast(M_CPU, target)]
    }

    #[test]
    fn invalid_model_falls_back_to_current() {
        let cluster = fixture();
        let mut e = Evaluator::new(Box::new(FailingModel), 0.5);
        let d = e.evaluate(
            &cpu_specs(70.0),
            &vec_with_cpu(150.0),
            &[],
            DeploymentId(0),
            &cluster,
        );
        assert!(d.used_fallback);
        assert_eq!(d.predicted, None);
        assert_eq!(d.desired, 3); // ceil(150/70) from CURRENT metric
        assert_eq!(d.recommendations[0].source, MetricSource::Current);
    }

    #[test]
    fn low_confidence_bayesian_falls_back() {
        let cluster = fixture();
        let mut e = Evaluator::new(Box::new(UnderConfidentModel), 0.5);
        let d = e.evaluate(
            &cpu_specs(70.0),
            &vec_with_cpu(70.0),
            &[],
            DeploymentId(0),
            &cluster,
        );
        assert!(d.used_fallback, "confidence 0.1 < threshold 0.5");
        assert_eq!(d.desired, 1, "uses current 70, not predicted 999");
        assert_eq!(d.predicted, Some(999.0), "raw prediction still logged");
    }

    #[test]
    fn valid_model_prediction_used() {
        let cluster = fixture();
        let mut e = Evaluator::new(Box::new(NaiveForecaster), 0.5);
        let history = vec![vec_with_cpu(200.0)];
        let d = e.evaluate(
            &cpu_specs(70.0),
            &vec_with_cpu(50.0),
            &history,
            DeploymentId(0),
            &cluster,
        );
        assert!(!d.used_fallback);
        // Naive predicts the last history row (200) → ceil(200/70)=3.
        assert_eq!(d.desired, 3);
        assert_eq!(d.recommendations[0].source, MetricSource::Forecast);
    }

    #[test]
    fn limitation_aware_cap() {
        let cluster = fixture();
        // Node allows 1800/500 = 3 pods total.
        let mut e = Evaluator::new(Box::new(NaiveForecaster), 0.5);
        let history = vec![vec_with_cpu(100_000.0)];
        let d = e.evaluate(
            &cpu_specs(70.0),
            &vec_with_cpu(1.0),
            &history,
            DeploymentId(0),
            &cluster,
        );
        assert_eq!(d.desired, 3, "never overscale past physical limits");
    }

    #[test]
    fn floor_of_one_replica() {
        let cluster = fixture();
        let mut e = Evaluator::new(Box::new(NaiveForecaster), 0.5);
        let history = vec![vec_with_cpu(0.0)];
        let d = e.evaluate(
            &cpu_specs(70.0),
            &vec_with_cpu(0.0),
            &history,
            DeploymentId(0),
            &cluster,
        );
        assert_eq!(d.desired, 1);
    }

    #[test]
    fn mixed_sources_per_spec() {
        // cpu is forecast (naive → last history row = 210 → 3 replicas);
        // req_rate is pinned to Current (4.0 → 2 replicas at target 2).
        let cluster = fixture();
        let mut e = Evaluator::new(Box::new(NaiveForecaster), 0.5);
        let mut hist_row = vec_with_cpu(210.0);
        hist_row[M_REQ_RATE] = 100.0; // would demand 50 replicas if forecast
        let history = vec![hist_row];
        let mut current = vec_with_cpu(50.0);
        current[M_REQ_RATE] = 4.0;
        let specs = vec![
            MetricSpec::forecast(M_CPU, 70.0),
            MetricSpec::current(M_REQ_RATE, 2.0),
        ];
        let d = e.evaluate(&specs, &current, &history, DeploymentId(0), &cluster);
        assert_eq!(d.recommendations[0].desired, 3, "forecast cpu");
        assert_eq!(d.recommendations[0].source, MetricSource::Forecast);
        assert_eq!(d.recommendations[1].source, MetricSource::Current);
        // Conservative policy: max(current 4, …) — value is current 4.
        assert_eq!(d.recommendations[1].value, 4.0);
        assert_eq!(d.recommendations[1].desired, 2, "current req_rate only");
        assert_eq!(d.desired, 3, "combined max, capped at node capacity");
    }
}

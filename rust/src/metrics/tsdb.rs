//! Ring-buffer time-series store (the Prometheus TSDB stand-in).

use crate::sim::Time;
use std::collections::{HashMap, VecDeque};

/// Default per-series retention cap (samples). At a 10 s scrape interval
/// this holds > 48 h of history — enough for the NASA evaluation runs.
const DEFAULT_CAPACITY: usize = 20_000;

/// One named series: a bounded deque of (time, value).
#[derive(Debug)]
pub struct Series {
    samples: VecDeque<(Time, f64)>,
    capacity: usize,
}

impl Series {
    fn new(capacity: usize) -> Self {
        Series {
            samples: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
        }
    }

    fn push(&mut self, t: Time, v: f64) {
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
        }
        self.samples.push_back((t, v));
    }

    pub fn latest(&self) -> Option<(Time, f64)> {
        self.samples.back().copied()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples with `from < t <= to` (inclusive upper bound).
    pub fn range(&self, from: Time, to: Time) -> Vec<(Time, f64)> {
        self.samples
            .iter()
            .copied()
            .filter(|&(t, _)| t > from && t <= to)
            .collect()
    }
}

/// The store: series by name.
#[derive(Debug, Default)]
pub struct Tsdb {
    series: HashMap<String, Series>,
}

impl Tsdb {
    pub fn new() -> Self {
        Tsdb::default()
    }

    pub fn insert(&mut self, name: &str, t: Time, v: f64) {
        self.series
            .entry(name.to_string())
            .or_insert_with(|| Series::new(DEFAULT_CAPACITY))
            .push(t, v);
    }

    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    pub fn latest(&self, name: &str) -> Option<(Time, f64)> {
        self.series.get(name).and_then(|s| s.latest())
    }

    pub fn range(&self, name: &str, from: Time, to: Time) -> Vec<(Time, f64)> {
        self.series
            .get(name)
            .map(|s| s.range(from, to))
            .unwrap_or_default()
    }

    pub fn series_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.series.keys().map(|s| s.as_str()).collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_query() {
        let mut db = Tsdb::new();
        for t in 1..=5u64 {
            db.insert("a.cpu", t * 10, t as f64);
        }
        assert_eq!(db.latest("a.cpu"), Some((50, 5.0)));
        assert_eq!(db.range("a.cpu", 10, 40), vec![(20, 2.0), (30, 3.0), (40, 4.0)]);
        assert!(db.range("missing", 0, 100).is_empty());
        assert_eq!(db.latest("missing"), None);
    }

    #[test]
    fn ring_buffer_caps() {
        let mut s = Series::new(3);
        for t in 0..10u64 {
            s.push(t, t as f64);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest(), Some((9, 9.0)));
        assert_eq!(s.range(0, 100).len(), 3);
    }

    #[test]
    fn series_names_sorted() {
        let mut db = Tsdb::new();
        db.insert("b", 1, 0.0);
        db.insert("a", 1, 0.0);
        assert_eq!(db.series_names(), vec!["a", "b"]);
    }
}

//! Request and response-record types.

use crate::sim::{ServiceId, Time};

/// The two task classes of the example application (paper §5.1.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskType {
    /// Sort a 3000-element array — `n log n ≈ 1e4` ops; handled at the edge.
    Sort,
    /// Eigenvalues of a 1000x1000 matrix — `n³ = 1e9` ops; forwarded to cloud.
    Eigen,
}

impl TaskType {
    pub fn name(&self) -> &'static str {
        match self {
            TaskType::Sort => "sort",
            TaskType::Eigen => "eigen",
        }
    }
}

/// An in-flight request, stored in the app's
/// [`RequestArena`](super::RequestArena) and addressed by the
/// generational [`crate::sim::RequestId`] (the handle *is* the
/// identity — the payload carries no id of its own).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub task: TaskType,
    pub origin_zone: u32,
    pub service: ServiceId,
    pub created: Time,
}

/// A completed request (the experiments' unit of observation).
#[derive(Debug, Clone, Copy)]
pub struct ResponseRecord {
    pub task: TaskType,
    pub origin_zone: u32,
    pub created: Time,
    pub completed: Time,
}

impl ResponseRecord {
    /// End-to-end response time in seconds (what Figs 9, 11, 12 plot).
    pub fn response_secs(&self) -> f64 {
        crate::sim::to_secs(self.completed.saturating_sub(self.created))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    #[test]
    fn response_secs_computed() {
        let r = ResponseRecord {
            task: TaskType::Sort,
            origin_zone: 1,
            created: 2 * SEC,
            completed: 3 * SEC + SEC / 2,
        };
        assert!((r.response_secs() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn task_names() {
        assert_eq!(TaskType::Sort.name(), "sort");
        assert_eq!(TaskType::Eigen.name(), "eigen");
    }
}

//! Tiny benchmarking harness shared by the bench binaries (criterion is
//! not in the offline crate set). Reports mean / p50 / p95 over timed
//! iterations after warmup.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

/// Time `f` for `iters` iterations (after `warmup` untimed ones).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: pct(0.50),
        p95_us: pct(0.95),
    }
}

pub fn print_header(title: &str) {
    println!("\n### {title}");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "p50", "p95"
    );
    println!("{}", "-".repeat(92));
}

fn fmt_us(us: f64) -> String {
    if us < 1000.0 {
        format!("{us:.2} µs")
    } else if us < 1_000_000.0 {
        format!("{:.2} ms", us / 1000.0)
    } else {
        format!("{:.2} s", us / 1_000_000.0)
    }
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        r.name,
        r.iters,
        fmt_us(r.mean_us),
        fmt_us(r.p50_us),
        fmt_us(r.p95_us)
    );
}

/// Convenience: bench + print.
pub fn run<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    print_result(&r);
    r
}

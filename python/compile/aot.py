"""AOT pipeline: lower the L2 model to HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Run once via ``make artifacts``; emits into ``artifacts/``:
  lstm_init.hlo.txt         (seed:u32) -> (w, b, wd, bd)
  lstm_predict.hlo.txt      (w, b, wd, bd, x[1,T,I]) -> (y[1,O],)
  lstm_train_step.hlo.txt   (params, m, v, t, xb[B,T,I], yb[B,O]) -> (params', m', v', t', loss)
  lstm_train_epoch.hlo.txt  (params, m, v, t, xs[K,B,T,I], ys[K,B,O]) -> same
  manifest.json             shapes + Adam constants for the rust runtime
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs():
    return [_spec(model.PARAM_SHAPES[n]) for n in model.PARAM_NAMES]


def _opt_specs():
    # m then v, one per param, then the scalar step count.
    return _param_specs() + _param_specs() + [_spec(())]


def build_artifacts():
    """Lower all entry points. Returns {filename: hlo_text} plus manifest."""
    t, b, k = model.SEQ_LEN, model.BATCH, model.EPOCH_BATCHES
    i_dim, o_dim = model.INPUT_DIM, model.OUTPUT_DIM

    artifacts = {}

    lowered = jax.jit(model.init_entry).lower(_spec((), jnp.uint32))
    artifacts["lstm_init.hlo.txt"] = to_hlo_text(lowered)

    lowered = jax.jit(model.predict_entry).lower(
        *_param_specs(), _spec((1, t, i_dim))
    )
    artifacts["lstm_predict.hlo.txt"] = to_hlo_text(lowered)

    lowered = jax.jit(model.train_step_entry).lower(
        *_param_specs(), *_opt_specs(), _spec((b, t, i_dim)), _spec((b, o_dim))
    )
    artifacts["lstm_train_step.hlo.txt"] = to_hlo_text(lowered)

    lowered = jax.jit(model.train_epoch_entry).lower(
        *_param_specs(), *_opt_specs(), _spec((k, b, t, i_dim)), _spec((k, b, o_dim))
    )
    artifacts["lstm_train_epoch.hlo.txt"] = to_hlo_text(lowered)

    manifest = {
        "input_dim": i_dim,
        "hidden_dim": model.HIDDEN_DIM,
        "output_dim": o_dim,
        "seq_len": t,
        "batch": b,
        "epoch_batches": k,
        "adam": {
            "lr": model.ADAM_LR,
            "beta1": model.ADAM_B1,
            "beta2": model.ADAM_B2,
            "eps": model.ADAM_EPS,
        },
        "param_shapes": {n: list(model.PARAM_SHAPES[n]) for n in model.PARAM_NAMES},
        "artifacts": sorted(artifacts),
    }
    return artifacts, manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()

    os.makedirs(args.out, exist_ok=True)
    artifacts, manifest = build_artifacts()
    for name, text in artifacts.items():
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()

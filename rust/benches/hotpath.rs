//! Hot-path micro-benchmarks (L3 perf deliverable): the DES event loop,
//! scheduler, metrics scrape, forecaster dispatches, and end-to-end
//! simulation rate. Run with `cargo bench --bench hotpath`.

#[path = "bench_common.rs"]
mod bench_common;
use bench_common::{print_header, run};

use ppa_edge::app::{TaskCosts, TaskType};
use ppa_edge::autoscaler::Hpa;
use ppa_edge::cluster::{Cluster, Deployment, NodeSpec, PodSpec, Selector, Tier};
use ppa_edge::config::{paper_cluster, quickstart_cluster};
use ppa_edge::experiments::SimWorld;
use ppa_edge::forecast::{arma::fit_arma, Forecaster, LstmForecaster};
use ppa_edge::metrics::METRIC_DIM;
use ppa_edge::sim::{Event, EventQueue, MIN, SEC};
use ppa_edge::util::rng::Pcg64;
use ppa_edge::workload::{Generator, RandomAccessGen};
use std::rc::Rc;

fn bench_event_queue() {
    print_header("DES event queue");
    let mut rng = Pcg64::new(1, 0);
    run("queue push+pop, 10k events", 3, 30, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at(
                rng.below(1_000_000),
                Event::WorkloadTick { generator: i as u32 },
            );
        }
        while q.pop().is_some() {}
    });
}

fn bench_scheduler() {
    print_header("pod scheduler (filter+score over 7 nodes)");
    let cfg = paper_cluster();
    let (mut cluster, ids) = cfg.build();
    let mut q = EventQueue::new();
    let mut rng = Pcg64::new(2, 0);
    run("reconcile 0->6->0 replicas", 3, 200, || {
        cluster.reconcile(ids[0], 6, &mut q, &mut rng);
        cluster.reconcile(ids[0], 0, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::PodRunning { pod } => {
                    cluster.on_pod_running(pod);
                }
                Event::PodTerminated { pod } => cluster.on_pod_terminated(pod),
                _ => {}
            }
        }
    });
}

fn bench_scrape() {
    print_header("metrics pipeline scrape (3 services, 12 pods)");
    let cfg = paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 3);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    world.run_until(5 * MIN);
    let mut t = 5 * MIN;
    run("scrape tick", 5, 500, || {
        t += 10 * SEC;
        world.metrics.scrape(t, &mut world.cluster, &mut world.app);
    });
}

fn bench_forecasters() {
    print_header("forecaster hot path");
    // ARMA fit on a 200-row history (every update loop).
    let mut rng = Pcg64::new(5, 0);
    let series: Vec<f64> = (0..200)
        .map(|i| 100.0 + 30.0 * ((i as f64) / 12.0).sin() + rng.normal() * 4.0)
        .collect();
    run("ARMA(1,1) CSS fit, 200 points", 2, 20, || {
        let _ = fit_arma(&series);
    });

    // LSTM dispatches (the PJRT path) — only with artifacts.
    if let Some(rt) = ppa_edge::experiments::try_runtime() {
        let rt: Rc<_> = rt;
        let mut f = LstmForecaster::new(rt.clone(), 1).unwrap();
        let history: Vec<[f64; METRIC_DIM]> = (0..300)
            .map(|i| {
                let v = 100.0 + 50.0 * ((i as f64) / 20.0).sin();
                [v; METRIC_DIM]
            })
            .collect();
        f.pretrain_on(&history).unwrap();
        run("LSTM predict dispatch (PJRT)", 5, 200, || {
            let _ = f.predict(&history);
        });
        run("LSTM fine-tune (6 train_epoch dispatches)", 1, 5, || {
            f.retrain(&history, ppa_edge::forecast::UpdatePolicy::FineTune)
                .unwrap();
        });
    } else {
        println!("(LSTM benches skipped: run `make artifacts`)");
    }
}

fn bench_end_to_end() {
    print_header("end-to-end simulation rate");
    let r = run("quickstart world, 60 sim-minutes (HPA)", 1, 5, || {
        let cfg = quickstart_cluster();
        let mut world = SimWorld::build(&cfg, TaskCosts::default(), 9);
        world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
        for svc in 0..world.app.services.len() {
            world.add_scaler(Box::new(Hpa::with_defaults()), svc);
        }
        world.run_until(60 * MIN);
    });
    let speedup = 3600.0 / (r.mean_us / 1e6);
    println!("  -> simulation speed ~{speedup:.0}x real time");

    // Request-to-completion throughput of the app model itself.
    let mut cluster = Cluster::new();
    cluster.add_node(NodeSpec::new("e", Tier::Edge, 1, 8000, 8192));
    let edge = cluster.add_deployment(Deployment::new(
        "edge",
        Selector::new(Tier::Edge, None),
        PodSpec::new(500, 256),
        1,
        8,
    ));
    let cloud = cluster.add_deployment(Deployment::new(
        "cloud",
        Selector::new(Tier::Edge, None),
        PodSpec::new(500, 256),
        1,
        8,
    ));
    let mut q = EventQueue::new();
    let mut rng = Pcg64::new(11, 0);
    cluster.reconcile(edge, 4, &mut q, &mut rng);
    while let Some((_, ev)) = q.pop() {
        if let Event::PodRunning { pod } = ev {
            cluster.on_pod_running(pod);
        }
    }
    let mut app = ppa_edge::app::App::new(TaskCosts::default(), &[(1, edge)], cloud);
    run("submit+serve 100 sort requests", 2, 50, || {
        for _ in 0..100 {
            app.submit(TaskType::Sort, 1, q.now(), &mut q);
        }
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::RequestArrival { request_id } => {
                    app.on_arrival(request_id, &mut cluster, &mut q, &mut rng)
                }
                Event::ServiceComplete { pod, request_id } => {
                    app.on_complete(pod, request_id, &mut cluster, &mut q, &mut rng)
                }
                _ => {}
            }
        }
    });
}

fn main() {
    println!("ppa-edge hot-path benchmarks");
    bench_event_queue();
    bench_scheduler();
    bench_scrape();
    bench_forecasters();
    bench_end_to_end();
}

//! The fleet registry: many `(metric-spec set, behavior, target)`
//! bindings under one roof, so a single sweep cell (or CLI run) can
//! drive a whole city-topology fleet with heterogeneous scaling
//! policies — e.g. the cloud pool on `cpu:70` while a downtown edge
//! zone runs `cpu:70+req_rate:150` under tighter rate limits.

use super::behavior::ScalingBehavior;
use super::spec::{specs_label, MetricSource, MetricSpec};
use crate::forecast::ForecasterKind;
use crate::metrics::M_CPU;

/// One scaling policy — the spec set plus behavior a Kubernetes HPA
/// object would carry. Plain data: clonable, `Send + Sync`, shared
/// read-only across sweep workers.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalerPolicy {
    pub specs: Vec<MetricSpec>,
    /// Behavior override. `None` keeps the scaler kind's stock default
    /// (HPA: 5-min downscale stabilization; PPA: 2-min) — so a fleet
    /// that only customizes metrics never silently changes the
    /// baseline's stabilization dynamics.
    pub behavior: Option<ScalingBehavior>,
    /// Forecaster override for PPA-family scalers — the per-service
    /// forecaster axis (`--forecaster`). `None` keeps the scaler kind's
    /// stock model (ppa-naive: last value; ppa-arma: online ARMA).
    /// HPA-family scalers ignore it.
    pub forecaster: Option<ForecasterKind>,
}

impl Default for ScalerPolicy {
    /// The paper's single-metric policy: cpu:70, kind-default behavior.
    fn default() -> Self {
        ScalerPolicy {
            specs: vec![MetricSpec {
                metric: M_CPU,
                target: 70.0,
                source: MetricSource::Forecast,
            }],
            behavior: None,
            forecaster: None,
        }
    }
}

impl ScalerPolicy {
    /// A policy with an explicit behavior override.
    pub fn new(specs: Vec<MetricSpec>, behavior: ScalingBehavior) -> Self {
        assert!(!specs.is_empty(), "a scaling policy needs >= 1 metric spec");
        ScalerPolicy {
            specs,
            behavior: Some(behavior),
            forecaster: None,
        }
    }

    /// A policy that only customizes metrics (kind-default behavior).
    pub fn from_specs(specs: Vec<MetricSpec>) -> Self {
        assert!(!specs.is_empty(), "a scaling policy needs >= 1 metric spec");
        ScalerPolicy {
            specs,
            behavior: None,
            forecaster: None,
        }
    }

    /// Builder form of the forecaster axis.
    pub fn with_forecaster(mut self, kind: ForecasterKind) -> Self {
        self.forecaster = Some(kind);
        self
    }

    /// Compact report/JSON label, e.g. `cpu:70+req_rate:150`.
    pub fn label(&self) -> String {
        specs_label(&self.specs)
    }
}

/// Binds policies to scaler targets by service index (== deployment
/// order in the cluster config): a default policy for the fleet plus
/// per-target overrides.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScalerRegistry {
    default: ScalerPolicy,
    overrides: Vec<(usize, ScalerPolicy)>,
}

impl ScalerRegistry {
    /// Every target runs `policy`.
    pub fn uniform(policy: ScalerPolicy) -> Self {
        ScalerRegistry {
            default: policy,
            overrides: Vec::new(),
        }
    }

    /// Override the policy of one target (builder form). Re-binding a
    /// service replaces its previous override.
    ///
    /// (Named `with_policy`, not `bind`: `bind`/`unbind` are reserved
    /// for the `Node` capacity-ledger nexus — detlint rule N1 flags the
    /// bare method name outside `cluster/`.)
    pub fn with_policy(mut self, service_idx: usize, policy: ScalerPolicy) -> Self {
        self.overrides.retain(|&(idx, _)| idx != service_idx);
        self.overrides.push((service_idx, policy));
        self
    }

    /// The policy bound to a service index. (The sweep JSON `"specs"`
    /// array is derived from the *live* scalers after a run —
    /// `specs_label(autoscaler.specs())` — not from here, so there is
    /// exactly one label path.)
    pub fn policy_for(&self, service_idx: usize) -> &ScalerPolicy {
        self.overrides
            .iter()
            .find(|&&(idx, _)| idx == service_idx)
            .map(|(_, p)| p)
            .unwrap_or(&self.default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::M_REQ_RATE;
    use crate::sim::MIN;

    #[test]
    fn default_policy_is_paper_single_metric() {
        let p = ScalerPolicy::default();
        assert_eq!(p.label(), "cpu:70");
        assert_eq!(p.behavior, None, "kind-default behavior");
        let q = ScalerPolicy::from_specs(vec![MetricSpec::current(M_CPU, 50.0)]);
        assert_eq!(q.behavior, None);
        assert_eq!(q.label(), "cpu:50");
    }

    #[test]
    fn registry_binds_and_falls_back() {
        let hot = ScalerPolicy::new(
            vec![
                MetricSpec::forecast(M_CPU, 70.0),
                MetricSpec::forecast(M_REQ_RATE, 150.0),
            ],
            ScalingBehavior::stabilize_down(MIN),
        );
        let reg = ScalerRegistry::uniform(ScalerPolicy::default()).with_policy(1, hot.clone());
        assert_eq!(reg.policy_for(0).label(), "cpu:70");
        assert_eq!(reg.policy_for(1).label(), "cpu:70+req_rate:150");
        assert_eq!(reg.policy_for(2).label(), "cpu:70", "fallback to default");
        // Re-binding replaces.
        let reg = reg.with_policy(1, ScalerPolicy::default());
        assert_eq!(reg.policy_for(1).label(), "cpu:70");
        assert_eq!(reg.overrides.len(), 1);
    }

    #[test]
    #[should_panic(expected = "needs >= 1 metric spec")]
    fn empty_spec_set_rejected() {
        let _ = ScalerPolicy::new(vec![], ScalingBehavior::stabilize_down(0));
    }

    #[test]
    fn forecaster_axis_defaults_off_and_builds_on() {
        assert_eq!(ScalerPolicy::default().forecaster, None);
        let from_specs = ScalerPolicy::from_specs(vec![MetricSpec::forecast(M_CPU, 70.0)]);
        assert_eq!(from_specs.forecaster, None);
        let p = ScalerPolicy::default().with_forecaster(ForecasterKind::Auto(3));
        assert_eq!(p.forecaster, Some(ForecasterKind::Auto(3)));
        assert_eq!(p.label(), "cpu:70", "label stays specs-only");
        // Registry plumbs the axis per service like any other field.
        let reg = ScalerRegistry::uniform(ScalerPolicy::default())
            .with_policy(1, ScalerPolicy::default().with_forecaster(ForecasterKind::HoltWinters));
        assert_eq!(reg.policy_for(0).forecaster, None);
        assert_eq!(reg.policy_for(1).forecaster, Some(ForecasterKind::HoltWinters));
    }
}

//! Streaming (single-pass, bounded-memory) statistics: Welford moments
//! plus a fixed-bin log-scale histogram for quantile estimates.
//!
//! The DES used to keep every completed request in an unbounded
//! `Vec<ResponseRecord>` and re-collect/sort it for each summary.
//! [`StreamingStats`] replaces that on the hot path: O(1) state per
//! sample, constant memory, and deterministic results — recording the
//! same value sequence always produces bit-identical state, which the
//! core-equivalence tests lean on via [`StreamingStats::fingerprint`].
//!
//! # Histogram binning
//!
//! [`LogHistogram`] covers `[LOG_HIST_MIN, LOG_HIST_MIN * 2^LOG_HIST_OCTAVES)`
//! (1 ms to ~1049 s with the defaults) with
//! [`LOG_HIST_BINS_PER_OCTAVE`] bins per octave: bin `i` spans
//! `[MIN * 2^(i/BPO), MIN * 2^((i+1)/BPO))`. With 32 bins/octave each
//! bin is a factor of `2^(1/32) ≈ 1.022` wide, so quantile estimates
//! (reported at the geometric bin center) carry ≤ ~1.1% relative error.
//! Samples below the range (or non-finite) count in an underflow
//! bucket, samples at/above the top in an overflow bucket; totals are
//! never lost.

use super::Summary;
use std::fmt::Write as _;

/// Lower edge of the histogram range (seconds): 1 ms.
pub const LOG_HIST_MIN: f64 = 1e-3;
/// Bins per octave (factor-of-two span).
pub const LOG_HIST_BINS_PER_OCTAVE: usize = 32;
/// Octaves covered: `1e-3 * 2^20 ≈ 1049` seconds at the top.
pub const LOG_HIST_OCTAVES: usize = 20;
const NUM_BINS: usize = LOG_HIST_BINS_PER_OCTAVE * LOG_HIST_OCTAVES;

/// Fixed-bin log-scale histogram (see the module docs for the binning).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    total: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; NUM_BINS],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    /// Record one sample.
    pub fn record(&mut self, x: f64) {
        self.total += 1;
        // NaN and sub-range values (including 0 and negatives) land in
        // the underflow bucket.
        if x.is_nan() || x < LOG_HIST_MIN {
            self.underflow += 1;
            return;
        }
        let idx = ((x / LOG_HIST_MIN).log2() * LOG_HIST_BINS_PER_OCTAVE as f64) as usize;
        if idx >= NUM_BINS {
            self.overflow += 1;
        } else {
            self.counts[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Raw bin counts (for reports and fingerprints).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// `[lower, upper)` edges of bin `i`.
    pub fn bin_bounds(i: usize) -> (f64, f64) {
        let lo = LOG_HIST_MIN * 2f64.powf(i as f64 / LOG_HIST_BINS_PER_OCTAVE as f64);
        let hi = LOG_HIST_MIN * 2f64.powf((i + 1) as f64 / LOG_HIST_BINS_PER_OCTAVE as f64);
        (lo, hi)
    }

    /// Estimated p-th percentile, `p` in `[0, 100]` (nearest-rank over
    /// the bins, reported at the geometric bin center — ≤ ~1.1%
    /// relative error with the default 32 bins/octave). NaN when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = self.underflow;
        if rank <= cum {
            return LOG_HIST_MIN;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank <= cum {
                let center = (i as f64 + 0.5) / LOG_HIST_BINS_PER_OCTAVE as f64;
                return LOG_HIST_MIN * 2f64.powf(center);
            }
        }
        // Overflow bucket: report the range's upper edge.
        LOG_HIST_MIN * 2f64.powf(LOG_HIST_OCTAVES as f64)
    }
}

/// Single-pass count / mean / std / extrema (Welford) plus a
/// [`LogHistogram`] for quantiles. The streaming replacement for
/// collecting samples into a `Vec` and calling [`super::summarize`] /
/// [`super::percentile`].
#[derive(Debug, Clone)]
pub struct StreamingStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    hist: LogHistogram,
}

impl Default for StreamingStats {
    fn default() -> Self {
        StreamingStats::new()
    }
}

impl StreamingStats {
    pub fn new() -> Self {
        StreamingStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            hist: LogHistogram::new(),
        }
    }

    /// Record one sample (Welford update + histogram).
    pub fn record(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        self.hist.record(x);
    }

    /// Fold `other` into `self` (Chan et al. parallel-Welford merge).
    pub fn merge(&mut self, other: &StreamingStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let d = other.mean - self.mean;
        let n = na + nb;
        self.mean += d * nb / n;
        self.m2 += other.m2 + d * d * na * nb / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.hist.counts.iter_mut().zip(&other.hist.counts) {
            *a += b;
        }
        self.hist.underflow += other.hist.underflow;
        self.hist.overflow += other.hist.overflow;
        self.hist.total += other.hist.total;
    }

    pub fn n(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation, n-1 denominator (0 for n = 1, NaN when
    /// empty) — the same conventions as [`super::summarize`].
    pub fn std(&self) -> f64 {
        match self.n {
            0 => f64::NAN,
            1 => 0.0,
            n => (self.m2 / (n - 1) as f64).sqrt(),
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Estimated p-th percentile from the log histogram, `p` in
    /// `[0, 100]`.
    pub fn quantile(&self, p: f64) -> f64 {
        self.hist.quantile(p)
    }

    pub fn histogram(&self) -> &LogHistogram {
        &self.hist
    }

    /// The [`Summary`] view (for reports that already speak `Summary`).
    pub fn summary(&self) -> Summary {
        Summary {
            n: self.n(),
            mean: self.mean(),
            std: self.std(),
            min: self.min(),
            max: self.max(),
        }
    }

    /// Bit-exact digest of the full state (floats rendered as raw bits,
    /// plus every non-empty histogram bin). Two runs are event-for-event
    /// identical iff their digests match — the comparison primitive for
    /// determinism and core-equivalence tests.
    pub fn fingerprint(&self) -> String {
        let mut s = format!(
            "n={} mean={:016x} m2={:016x} min={:016x} max={:016x} under={} over={}",
            self.n,
            self.mean.to_bits(),
            self.m2.to_bits(),
            self.min.to_bits(),
            self.max.to_bits(),
            self.hist.underflow,
            self.hist.overflow,
        );
        for (i, &c) in self.hist.counts.iter().enumerate() {
            if c != 0 {
                let _ = write!(s, " b{i}={c}");
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::summarize;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_summarize_on_small_sample() {
        let xs = [0.5, 1.25, 0.75, 2.0, 0.5];
        let mut s = StreamingStats::new();
        for &x in &xs {
            s.record(x);
        }
        let batch = summarize(&xs);
        assert_eq!(s.n(), batch.n);
        assert!((s.mean() - batch.mean).abs() < 1e-12);
        assert!((s.std() - batch.std).abs() < 1e-12);
        assert_eq!(s.min(), batch.min);
        assert_eq!(s.max(), batch.max);
        let sum = s.summary();
        assert_eq!(sum.n, 5);
        assert!((sum.mean - batch.mean).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_nan_like_summarize() {
        let s = StreamingStats::new();
        assert_eq!(s.n(), 0);
        assert!(s.is_empty());
        assert!(s.mean().is_nan());
        assert!(s.std().is_nan());
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        assert!(s.quantile(50.0).is_nan());
    }

    #[test]
    fn single_sample_std_is_zero() {
        let mut s = StreamingStats::new();
        s.record(3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.mean(), 3.0);
    }

    #[test]
    fn histogram_quantiles_approximate_exact_percentiles() {
        let mut rng = Pcg64::new(9, 0);
        // Lognormal-ish response times around 0.5 s.
        let xs: Vec<f64> = (0..20_000)
            .map(|_| (0.5 * (1.0 + 0.3 * rng.normal()).abs()).max(1e-3))
            .collect();
        let mut s = StreamingStats::new();
        for &x in &xs {
            s.record(x);
        }
        for p in [50.0, 95.0, 99.0] {
            let exact = crate::stats::percentile(&xs, p);
            let est = s.quantile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.03, "p{p}: est {est} vs exact {exact} (rel {rel})");
        }
    }

    #[test]
    fn bin_bounds_partition_the_range() {
        let (lo0, hi0) = LogHistogram::bin_bounds(0);
        assert!((lo0 - LOG_HIST_MIN).abs() < 1e-15);
        let (lo1, _) = LogHistogram::bin_bounds(1);
        assert_eq!(hi0, lo1);
        // One octave = LOG_HIST_BINS_PER_OCTAVE bins = a factor of 2.
        let (lo32, _) = LogHistogram::bin_bounds(LOG_HIST_BINS_PER_OCTAVE);
        assert!((lo32 / lo0 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn under_and_overflow_are_counted() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(1e9);
        h.record(0.5);
        assert_eq!(h.total(), 5);
        assert_eq!(h.underflow(), 3);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.counts().iter().sum::<u64>(), 1);
        // All-underflow quantile pins to the range floor.
        assert!(h.quantile(10.0) >= LOG_HIST_MIN);
    }

    #[test]
    fn merge_equals_sequential_recording() {
        let mut rng = Pcg64::new(4, 2);
        let xs: Vec<f64> = (0..500).map(|_| rng.range(0.01, 30.0)).collect();
        let mut whole = StreamingStats::new();
        let mut a = StreamingStats::new();
        let mut b = StreamingStats::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i < 200 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.n(), whole.n());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.quantile(95.0), whole.quantile(95.0));
    }

    #[test]
    fn fingerprint_is_sequence_sensitive() {
        let mut a = StreamingStats::new();
        let mut b = StreamingStats::new();
        for x in [0.5, 0.7, 0.9] {
            a.record(x);
            b.record(x);
        }
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.record(0.9000001);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}

//! Quickstart: build a small edge cluster, generate Random-Access load,
//! autoscale with the PPA (naive model — no artifacts needed), and print
//! what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::{Hpa, MetricSpec, Ppa, PpaConfig};
use ppa_edge::config::quickstart_cluster;
use ppa_edge::experiments::SimWorld;
use ppa_edge::forecast::NaiveForecaster;
use ppa_edge::metrics::{M_CPU, M_REQ_RATE};
use ppa_edge::sim::MIN;
use ppa_edge::stats::summarize;
use ppa_edge::workload::{Generator, RandomAccessGen};

fn main() -> anyhow::Result<()> {
    // 1. A two-node cluster (one edge zone + one cloud node).
    let cfg = quickstart_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 42);

    // 2. Clients at edge zone 1 follow the paper's Random Access pattern.
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));

    // 3. Autoscalers: a multi-metric PPA (naive last-value model — see
    //    examples/model_comparison.rs for the LSTM) on the edge pool and
    //    the stock HPA on the cloud pool. The PPA scales on whichever
    //    metric demands more pods: forecast CPU at the paper's 70%
    //    target, or forecast arrival rate at 1.5 req/s per pod.
    let ppa = Ppa::new(
        PpaConfig {
            specs: vec![
                MetricSpec::forecast(M_CPU, 70.0),
                MetricSpec::forecast(M_REQ_RATE, 1.5),
            ],
            ..PpaConfig::default()
        },
        Box::new(NaiveForecaster),
    );
    world.add_scaler(Box::new(ppa), 0);
    world.add_scaler(Box::new(Hpa::with_defaults()), 1);

    // 4. Run 30 simulated minutes, retaining the structured decision
    //    log (opt-in, like the exact response log).
    world.record_decisions();
    let events = world.run_until(30 * MIN);

    // 5. Report — straight from the app's streaming response stats
    //    (constant-memory Welford moments + log-histogram percentiles;
    //    no per-request log is kept).
    let sort = world.app.stats.sort.summary();
    let eigen = world.app.stats.eigen.summary();
    let rirs: Vec<f64> = world.rir_log.iter().map(|s| s.rir).collect();
    println!("events processed : {events}");
    println!("requests served  : {}", world.app.completed());
    println!(
        "sort  response   : {:.3} ± {:.3} s (n={}, p95 ≈ {:.3})",
        sort.mean,
        sort.std,
        sort.n,
        world.app.stats.sort.quantile(95.0)
    );
    println!(
        "eigen response   : {:.2} ± {:.2} s (n={}, p95 ≈ {:.2})",
        eigen.mean,
        eigen.std,
        eigen.n,
        world.app.stats.eigen.quantile(95.0)
    );
    println!("mean RIR         : {:.3}", summarize(&rirs).mean);
    let max_replicas = world
        .replica_log
        .iter()
        .map(|&(_, _, r)| r)
        .max()
        .unwrap_or(0);
    println!("max replicas seen: {max_replicas}");

    // 6. The structured decision log records every scaler decision with
    //    per-metric provenance — which spec drove each scale-up.
    let driven_by_rate = world
        .decision_log
        .iter()
        .filter(|d| {
            d.recommendations.len() == 2
                && d.recommendations[1].desired > d.recommendations[0].desired
        })
        .count();
    println!(
        "decisions        : {} total, {driven_by_rate} led by the req_rate spec",
        world.decision_log.len()
    );
    Ok(())
}

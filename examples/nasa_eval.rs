//! End-to-end driver: the paper's full evaluation (Figs 11–14).
//!
//! Replays the scaled 2-day NASA trace against the Table-2 cluster twice —
//! once autoscaled by the default HPA, once by the optimally configured
//! PPA (LSTM seed model pretrained on 10 h of Random Access, update
//! policy 3, key metric = CPU) — and prints the paper's comparison rows
//! with Welch p-values. Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example nasa_eval            # full 48 h run
//! cargo run --release --example nasa_eval -- 6       # shortened (hours)
//! ```

use ppa_edge::experiments::{nasa_eval, NasaParams};
use ppa_edge::report;

fn main() -> anyhow::Result<()> {
    let hours: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(48.0);
    let pretrain_hours: f64 = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(10.0);

    let params = NasaParams {
        hours,
        pretrain_hours,
        ..NasaParams::default()
    };
    println!(
        "NASA evaluation: {hours} simulated hours, {pretrain_hours} h pretraining (paper: 48 / 10)"
    );

    let wall = ppa_edge::util::wallclock();
    let eval = nasa_eval(&params)?;
    report::print_nasa_eval(&eval);
    println!(
        "\nwall time: {:.1}s for {:.0} simulated hours ({:.0}x real time)",
        wall.elapsed().as_secs_f64(),
        2.0 * hours,
        2.0 * hours * 3600.0 / wall.elapsed().as_secs_f64()
    );
    println!("CSV dumps: target/experiments/fig11..14*.csv");
    Ok(())
}

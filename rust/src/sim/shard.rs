//! Sharded conservative-parallel execution of a city world.
//!
//! A city topology is nearly embarrassingly parallel: each edge zone has
//! its own nodes, its own deployment, its own workload and its own
//! autoscaler, and the only cross-zone coupling is the ~10% Eigen
//! forward from every edge zone to the shared cloud pool (one-directional
//! — the cloud never sends anything back). This module exploits that
//! structure with a classic conservative parallel-DES scheme:
//!
//! * The config is partitioned into **zone worlds** — one per edge
//!   deployment (its matching nodes, a single-service app, its own
//!   [`EventQueue`], metrics pipeline, autoscaler and RNG streams) plus
//!   one cloud world holding every remaining node and the cloud pool.
//! * Worlds are grouped onto `S` worker threads and advance in lockstep
//!   **windows** of width `Δ = network_latency + forward_latency` — the
//!   minimum edge→cloud event delay, i.e. the conservative lookahead.
//!   An Eigen forward submitted during window `k` (at `τ ≤ T_k`) arrives
//!   at `τ + Δ ≤ T_k + Δ = T_{k+1}`, and strictly after `T_k`, so it is
//!   always in the cloud world's future when exchanged at the barrier
//!   ending window `k` and always due within the very next window.
//! * At each barrier, per-world forward batches are concatenated in
//!   world order and stable-sorted by `(submitted, origin_zone)` before
//!   delivery, so the cloud queue's `(time, seq)` order — and with it
//!   every downstream bit — is independent of the shard count.
//!
//! # Determinism argument
//!
//! The unit of state is the zone world, not the shard: every world owns
//! RNG streams keyed by its *world index*, its event `seq` counter, and
//! its whole app/cluster/metrics state. Shards are only a thread-
//! ownership grouping of worlds, and the barrier merge order is a pure
//! function of the batches' contents — so a run is bit-identical for
//! `--shards 1|2|4|8` (asserted by the in-module tests here and by
//! `tests/shard_identity.rs`). This is the same invariant the sweep
//! harness pins across worker-thread counts, extended inward.
//!
//! The monolithic [`crate::experiments::SimWorld`] remains the golden
//! single-threaded reference. A sharded run is *not* bit-identical to a
//! monolith run of the same seed: worlds draw from per-world RNG streams
//! (`Pcg64::new(seed, 10 + 3·w + k)`) where the monolith interleaves
//! three global streams, and cloud traffic counters attribute a forward
//! at its delivery barrier (≤ Δ = 60 ms after the monolith's submit-time
//! attribution, far inside one 10 s scrape). Both schedules are valid
//! discretizations of the same system; each is bit-reproducible.
//!
//! A worker that panics mid-window would leave its peers blocked on the
//! barrier; the engine itself is panic-free (no `unwrap`/`expect` — the
//! determinism lint P1 covers this file) and maps worker panics from
//! app code to an error after the join.

use std::sync::{Barrier, Mutex};

use super::{CoreKind, Event, EventQueue, ServiceId, Time};
use crate::app::{App, ForwardedTask, ResponseStats, SlaConfig, SlaSummary, TaskCosts};
use crate::autoscaler::{specs_label, Autoscaler, Hybrid, Ppa};
use crate::cluster::{
    chaos_net_stream, chaos_pod_stream, chaos_schedule_stream, schedule_node_faults,
    ChaosCounters, Cluster, DeploymentId, FaultPlan, NetChaos, NodeSpec, PodChaos, Selector,
};
use crate::config::{ClusterConfig, NodeConfig};
use crate::experiments::{DecisionRecord, RirSample};
use crate::forecast::SelectionSummary;
use crate::metrics::{MetricsPipeline, DEFAULT_SCRAPE_INTERVAL};
use crate::stats::StreamingStats;
use crate::util::rng::Pcg64;
use crate::workload::{start_all, Generator};
use anyhow::bail;

/// Per-world RNG stream id: disjoint from the monolith's streams 1–3
/// and from the scenario/test streams, unique per `(world, role)`.
fn shard_stream(world: usize, role: u64) -> u64 {
    10 + 3 * world as u64 + role
}

/// How to run a sharded world.
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    /// Worker threads the zone worlds are grouped onto (≥ 1). The
    /// results are bit-identical for every value.
    pub shards: usize,
    /// Event-queue core every world runs on.
    pub core: CoreKind,
    pub seed: u64,
    pub costs: TaskCosts,
    /// Simulated end time.
    pub end: Time,
    /// Populate per-world [`DecisionRecord`] logs (opt-in, unbounded).
    pub record_decisions: bool,
    /// Fault plan (see `cluster::chaos`). [`FaultPlan::none`] is a
    /// strict no-op: no chaos RNGs are built and no fault events are
    /// enqueued, so the run is bit-identical to one without the chaos
    /// plane. Each world draws its faults from chaos streams keyed by
    /// its *world index*, so faulted runs stay bit-identical for every
    /// shard count. The network-delay perturbation is installed only in
    /// the cloud world (edge worlds hand Eigen forwards to the barrier
    /// without a delay draw; the cloud draws at delivery, in the
    /// shard-count-invariant barrier merge order). The extra delay is
    /// non-negative, so it only pushes forward arrivals later and the
    /// conservative-lookahead argument is untouched.
    pub chaos: FaultPlan,
    /// Resilience plane (see `app::SlaConfig`). `None` is a strict
    /// no-op — no SLA RNG, no timeout events, no priority draws — so
    /// SLA-free runs stay bit-identical to pre-resilience builds. Each
    /// world draws from its own `sla_stream(world)`, so SLA'd runs stay
    /// bit-identical for every shard count: timeout/retry events are
    /// strictly intra-world (a retry re-arrives in the same world's
    /// queue), leaving the conservative lookahead untouched. Edge worlds
    /// draw each request's priority at submit (carried inside
    /// [`ForwardedTask`]); the cloud world sheds `Batch` forwards and
    /// arms deadlines at delivery, in the shard-count-invariant barrier
    /// merge order.
    pub sla: Option<SlaConfig>,
}

/// One zone world's slice of the topology: its nodes plus its single
/// deployment (`zone: None` marks the cloud world).
#[derive(Debug, Clone)]
pub struct WorldPlan {
    pub cfg: ClusterConfig,
    pub zone: Option<u32>,
}

/// Partition a city config into per-zone worlds plus the cloud world.
///
/// Every edge deployment (all but the last) claims the nodes its
/// selector matches; the cloud world gets the last deployment plus every
/// unclaimed node (cloud workers and the reserved control node). The
/// split must be exact: a node matching two edge deployments has no
/// single owner and is rejected.
pub fn partition_worlds(cfg: &ClusterConfig) -> crate::Result<Vec<WorldPlan>> {
    if cfg.deployments.len() < 2 {
        bail!("sharded mode needs at least one edge and one cloud deployment");
    }
    let (edge_deps, cloud_dep) = cfg.deployments.split_at(cfg.deployments.len() - 1);
    let mut owner: Vec<Option<usize>> = vec![None; cfg.nodes.len()];
    for (w, d) in edge_deps.iter().enumerate() {
        if d.zone.is_none() {
            bail!("edge deployment '{}' has no zone — cannot shard", d.name);
        }
        let sel = Selector::new(d.tier, d.zone);
        for (i, n) in cfg.nodes.iter().enumerate() {
            if sel.matches(&NodeSpec::new(&n.name, n.tier, n.zone, n.cpu_millis, n.ram_mb)) {
                if let Some(prev) = owner[i] {
                    bail!(
                        "node '{}' matches deployments '{}' and '{}' — zones must \
                         partition the edge nodes",
                        n.name,
                        edge_deps[prev].name,
                        d.name
                    );
                }
                owner[i] = Some(w);
            }
        }
    }
    let mut plans = Vec::with_capacity(edge_deps.len() + 1);
    for (w, d) in edge_deps.iter().enumerate() {
        let nodes: Vec<NodeConfig> = cfg
            .nodes
            .iter()
            .enumerate()
            .filter(|&(i, _)| owner[i] == Some(w))
            .map(|(_, n)| n.clone())
            .collect();
        if nodes.is_empty() {
            bail!("deployment '{}' matches no node", d.name);
        }
        plans.push(WorldPlan {
            cfg: ClusterConfig {
                nodes,
                deployments: vec![d.clone()],
            },
            zone: d.zone,
        });
    }
    let cloud_nodes: Vec<NodeConfig> = cfg
        .nodes
        .iter()
        .enumerate()
        .filter(|&(i, _)| owner[i].is_none())
        .map(|(_, n)| n.clone())
        .collect();
    let d = &cloud_dep[0];
    let sel = Selector::new(d.tier, d.zone);
    if !cloud_nodes
        .iter()
        .any(|n| sel.matches(&NodeSpec::new(&n.name, n.tier, n.zone, n.cpu_millis, n.ram_mb)))
    {
        bail!("cloud deployment '{}' matches no unclaimed node", d.name);
    }
    plans.push(WorldPlan {
        cfg: ClusterConfig {
            nodes: cloud_nodes,
            deployments: vec![d.clone()],
        },
        zone: None,
    });
    Ok(plans)
}

/// One zone (or the cloud pool) as a self-contained world: the same
/// event loop as [`crate::experiments::SimWorld`], specialized to a
/// single service. Owned entirely by one worker thread — the autoscaler
/// trait object never crosses threads.
struct ZoneWorld {
    /// Global world index == global service index (edge zones in config
    /// order, cloud last — matching the monolith's service order).
    world: usize,
    zone: Option<u32>,
    queue: EventQueue,
    cluster: Cluster,
    app: App,
    metrics: MetricsPipeline,
    generators: Vec<Generator>,
    scaler: Box<dyn Autoscaler>,
    dep: DeploymentId,
    rir_log: Vec<RirSample>,
    replica_log: Vec<(Time, ServiceId, usize)>,
    decision_log: Vec<DecisionRecord>,
    log_decisions: bool,
    rng_cluster: Pcg64,
    rng_service: Pcg64,
    rng_workload: Pcg64,
    scrape_interval: Time,
    /// Fault counters for this world (pod-chaos stats folded in by
    /// [`Self::finish`]).
    chaos: ChaosCounters,
    /// Crash time per node index while it is down (downtime accounting).
    crashed_at: Vec<Option<Time>>,
    events: u64,
    started: bool,
}

impl ZoneWorld {
    fn build(
        plan: &WorldPlan,
        world: usize,
        generators: Vec<Generator>,
        scaler: Box<dyn Autoscaler>,
        spec: &ShardSpec,
    ) -> Self {
        let (mut cluster, dep_ids) = plan.cfg.build();
        let dep = dep_ids[0];
        let mut app = match plan.zone {
            Some(z) => App::new_edge_shard(spec.costs, z, dep),
            None => App::new_cloud_shard(spec.costs, dep),
        };
        let metrics =
            MetricsPipeline::for_app(DEFAULT_SCRAPE_INTERVAL, &app, spec.costs.base_burn_frac);
        let mut queue = EventQueue::with_core(spec.core);
        let mut rng_cluster = Pcg64::new(spec.seed, shard_stream(world, 0));
        for (dcfg, &id) in plan.cfg.deployments.iter().zip(&dep_ids) {
            cluster.reconcile(id, dcfg.initial_replicas, &mut queue, &mut rng_cluster);
        }
        // Install the fault plan. Empty plan ⇒ zero RNG construction,
        // zero events — bit-identity with pre-chaos builds. The streams
        // are keyed by world index, so the fault schedule of a world is
        // independent of the shard grouping.
        if let Some(nc) = &spec.chaos.node_crash {
            let mut rng = Pcg64::new(spec.seed, chaos_schedule_stream(world));
            schedule_node_faults(&cluster, nc, spec.end, &mut rng, &mut queue);
        }
        if spec.chaos.cold_start.is_some() || spec.chaos.crash_loop.is_some() {
            cluster.set_pod_chaos(Some(PodChaos::new(
                Pcg64::new(spec.seed, chaos_pod_stream(world)),
                spec.chaos.cold_start,
                spec.chaos.crash_loop,
            )));
        }
        if let Some(nd) = &spec.chaos.net_delay {
            // Cloud world only: edge worlds push Eigen forwards to the
            // barrier without a delay draw; the cloud perturbs each
            // forward at delivery (barrier merge order — shard-invariant).
            if plan.zone.is_none() {
                app.set_net_chaos(Some(NetChaos::new(
                    Pcg64::new(spec.seed, chaos_net_stream(world)),
                    nd,
                )));
            }
        }
        // Resilience plane: per-world SLA stream, so priority draws and
        // backoff jitter are independent of the shard grouping. Absent
        // policy ⇒ strict no-op (bit-identity with pre-resilience runs).
        if let Some(sla) = &spec.sla {
            app.install_sla(sla, spec.seed, world as u32);
        }
        let crashed_at = vec![None; cluster.nodes.len()];
        ZoneWorld {
            world,
            zone: plan.zone,
            queue,
            cluster,
            app,
            metrics,
            generators,
            scaler,
            dep,
            rir_log: Vec::new(),
            replica_log: Vec::new(),
            decision_log: Vec::new(),
            log_decisions: false,
            rng_cluster,
            rng_service: Pcg64::new(spec.seed, shard_stream(world, 1)),
            rng_workload: Pcg64::new(spec.seed, shard_stream(world, 2)),
            scrape_interval: DEFAULT_SCRAPE_INTERVAL,
            chaos: ChaosCounters::default(),
            crashed_at,
            events: 0,
            started: false,
        }
    }

    fn schedule_initial(&mut self) {
        start_all(&self.generators, &mut self.queue);
        self.queue.schedule_in(self.scrape_interval, Event::Scrape);
        self.queue.schedule_in(
            self.scaler.control_interval(),
            Event::AutoscaleTick { scaler: 0 },
        );
        if let Some(u) = self.scaler.update_interval() {
            self.queue
                .schedule_in(u, Event::ModelUpdateTick { scaler: 0 });
        }
    }

    /// Advance to `end` (a barrier tick — `pop_due` is inclusive, so a
    /// forward arrival landing exactly on the tick runs in this window).
    fn run_window(&mut self, end: Time) {
        if !self.started {
            self.started = true;
            self.schedule_initial();
        }
        // The global service id this world's samples are logged under.
        let service = ServiceId(self.world as u32);
        while let Some((now, event)) = self.queue.pop_due(end) {
            self.events += 1;
            match event {
                Event::RequestArrival { request_id } => {
                    self.app.on_arrival(
                        request_id,
                        &mut self.cluster,
                        &mut self.queue,
                        &mut self.rng_service,
                    );
                }
                Event::ServiceComplete { pod, request_id } => {
                    self.app.on_complete(
                        pod,
                        request_id,
                        &mut self.cluster,
                        &mut self.queue,
                        &mut self.rng_service,
                    );
                }
                Event::PodRunning { pod } => {
                    // Single-deployment world: any running pod belongs to
                    // this world's one service.
                    if self.cluster.on_pod_running(pod) {
                        self.app.dispatch(
                            ServiceId(0),
                            &mut self.cluster,
                            &mut self.queue,
                            &mut self.rng_service,
                        );
                    }
                }
                Event::PodTerminated { pod } => {
                    self.cluster.on_pod_terminated(pod);
                }
                Event::Scrape => {
                    self.metrics.scrape(now, &mut self.cluster, &mut self.app);
                    let snap = self.metrics.latest_snapshot(ServiceId(0));
                    if let Some(rir) = snap.rir() {
                        self.rir_log.push(RirSample { time: now, service, rir });
                    }
                    self.replica_log.push((now, service, snap.replicas));
                    self.queue.schedule_in(self.scrape_interval, Event::Scrape);
                }
                Event::AutoscaleTick { scaler } => {
                    let decision = self.scaler.evaluate(
                        now,
                        ServiceId(0),
                        self.dep,
                        &self.metrics,
                        &self.cluster,
                    );
                    self.cluster.reconcile(
                        self.dep,
                        decision.desired,
                        &mut self.queue,
                        &mut self.rng_cluster,
                    );
                    self.cluster
                        .retry_pending(&mut self.queue, &mut self.rng_cluster);
                    if self.log_decisions {
                        self.decision_log.push(DecisionRecord {
                            time: now,
                            service,
                            desired: decision.desired,
                            used_fallback: decision.used_fallback,
                            recommendations: decision.recommendations,
                        });
                    }
                    self.queue
                        .schedule_in(self.scaler.control_interval(), Event::AutoscaleTick {
                            scaler,
                        });
                }
                Event::ModelUpdateTick { scaler } => {
                    if let Err(e) = self.scaler.model_update(now) {
                        eprintln!("[t={now}] model update failed: {e:#}");
                    }
                    if let Some(u) = self.scaler.update_interval() {
                        self.queue
                            .schedule_in(u, Event::ModelUpdateTick { scaler });
                    }
                }
                Event::WorkloadTick { generator } => {
                    if let Some(g) = self.generators.get_mut(generator as usize) {
                        let _alive = g.on_tick(
                            generator,
                            &mut self.app,
                            &mut self.queue,
                            &mut self.rng_workload,
                        );
                    }
                }
                Event::NodeCrash { node } => {
                    if let Some(out) = self.cluster.crash_node(node) {
                        self.chaos.crashes += 1;
                        self.chaos.pods_killed += out.pods_killed as u64;
                        self.crashed_at[node.0 as usize] = Some(now);
                        // Replace lost capacity immediately (ReplicaSet
                        // reaction, not the next autoscale tick).
                        for &dep in &out.deployments {
                            let desired =
                                self.cluster.deployments[dep.0 as usize].desired_replicas;
                            let before = self.cluster.live_replicas(dep);
                            self.cluster.reconcile(
                                dep,
                                desired,
                                &mut self.queue,
                                &mut self.rng_cluster,
                            );
                            let after = self.cluster.live_replicas(dep);
                            self.chaos.pods_rescheduled +=
                                after.saturating_sub(before) as u64;
                        }
                        self.app.requeue_orphans(
                            &out.orphans,
                            &mut self.cluster,
                            &mut self.queue,
                            &mut self.rng_service,
                        );
                    }
                }
                Event::NodeRejoin { node } => {
                    if self.cluster.rejoin_node(node) {
                        self.chaos.rejoins += 1;
                        if let Some(t) = self.crashed_at[node.0 as usize].take() {
                            self.chaos.downtime += now.saturating_sub(t);
                        }
                        self.cluster
                            .retry_pending(&mut self.queue, &mut self.rng_cluster);
                    }
                }
                Event::RequestTimeout { request_id } => {
                    // Intra-world: retries re-arrive in this world's own
                    // queue, so the lookahead argument is untouched.
                    self.app.on_timeout(request_id, &mut self.queue);
                }
            }
        }
    }

    /// Plain-data summary — the only thing that leaves the worker
    /// thread. `end` finalizes downtime for nodes still down at the end
    /// of the run.
    fn finish(mut self, end: Time) -> WorldOutcome {
        let ppa = self.scaler.as_any().downcast_ref::<Ppa>();
        let hybrid = self.scaler.as_any().downcast_ref::<Hybrid>();
        let prediction_mse = ppa
            .filter(|p| p.prediction_count() > 0)
            .map(|p| p.prediction_mse())
            .or_else(|| {
                hybrid
                    .filter(|h| h.prediction_count() > 0)
                    .map(|h| h.prediction_mse())
            });
        let selection = ppa
            .and_then(|p| p.selection())
            .or_else(|| hybrid.and_then(|h| h.selection()));
        let hybrid_trips = hybrid.map(|h| h.trips());
        let hybrid_override_ticks = hybrid.map(|h| h.override_ticks());
        let mut chaos = self.chaos.clone();
        for t in self.crashed_at.iter().flatten() {
            chaos.downtime += end.saturating_sub(*t);
        }
        if let Some(pc) = self.cluster.pod_chaos() {
            chaos.crash_loops += pc.crash_loops;
            chaos.init_delays.merge(&pc.init_delays);
        }
        // Cost ledger: node-hours billed while up (downtime excluded),
        // plus total pod spawns.
        let gross = self.cluster.nodes.len() as u64 * end;
        let cost_node_hours =
            crate::sim::to_secs(gross.saturating_sub(chaos.downtime)) / 3600.0;
        WorldOutcome {
            world: self.world,
            zone: self.zone,
            spec_label: specs_label(self.scaler.specs()),
            events: self.events,
            completed: self.app.completed(),
            stats: self.app.stats.clone(),
            rir_log: std::mem::take(&mut self.rir_log),
            replica_log: std::mem::take(&mut self.replica_log),
            decision_log: std::mem::take(&mut self.decision_log),
            prediction_mse,
            selection,
            hybrid_trips,
            hybrid_override_ticks,
            chaos,
            sla: self.app.sla_summary(),
            cost_node_hours,
            pod_churn: self.cluster.pod_churn,
        }
    }
}

/// One world's deterministic results (plain data: safe to send).
#[derive(Debug, Clone)]
pub struct WorldOutcome {
    pub world: usize,
    pub zone: Option<u32>,
    /// Metric-spec label of the scaler this world ran (`cpu:70`, …).
    pub spec_label: String,
    pub events: u64,
    pub completed: usize,
    pub stats: ResponseStats,
    pub rir_log: Vec<RirSample>,
    pub replica_log: Vec<(Time, ServiceId, usize)>,
    pub decision_log: Vec<DecisionRecord>,
    pub prediction_mse: Option<f64>,
    /// Champion–challenger state of this world's scaler, when it ran a
    /// selecting forecaster (`--forecaster auto:K`).
    pub selection: Option<SelectionSummary>,
    /// Reactive-override trips of this world's scaler, when it is a
    /// [`Hybrid`] (`None` for every other scaler kind).
    pub hybrid_trips: Option<u64>,
    /// Control ticks this world's [`Hybrid`] decided under the
    /// reactive override (`None` for other scaler kinds).
    pub hybrid_override_ticks: Option<u64>,
    /// This world's fault counters (all-zero on fault-free runs).
    pub chaos: ChaosCounters,
    /// This world's resilience-plane summary (all-zero without an
    /// installed `SlaPolicy`).
    pub sla: SlaSummary,
    /// Node-hours billed while up (downtime excluded) over this world's
    /// nodes.
    pub cost_node_hours: f64,
    /// Total pods ever spawned in this world (cost-ledger churn).
    pub pod_churn: u64,
}

/// A finished sharded run: per-world outcomes in world order (edge zones
/// in config order, cloud last) plus merge helpers. Every accessor is a
/// pure function of the outcomes, so the aggregate views inherit the
/// shard-count invariance of the per-world results.
#[derive(Debug, Clone)]
pub struct ShardedRun {
    pub outcomes: Vec<WorldOutcome>,
    /// The conservative lookahead the run advanced in.
    pub window: Time,
}

impl ShardedRun {
    pub fn events(&self) -> u64 {
        self.outcomes.iter().map(|o| o.events).sum()
    }

    pub fn completed(&self) -> usize {
        self.outcomes.iter().map(|o| o.completed).sum()
    }

    /// Bit-exact digest of every world's response stream, in world
    /// order — the shard-identity comparison key.
    pub fn fingerprint(&self) -> String {
        let parts: Vec<String> = self.outcomes.iter().map(|o| o.stats.fingerprint()).collect();
        parts.join("|")
    }

    /// All Sort response moments merged across worlds (exact Chan/Welford
    /// combination — see [`StreamingStats::merge`]).
    pub fn sort_stats(&self) -> StreamingStats {
        let mut acc = StreamingStats::default();
        for o in &self.outcomes {
            acc.merge(&o.stats.sort);
        }
        acc
    }

    /// All Eigen response moments merged across worlds.
    pub fn eigen_stats(&self) -> StreamingStats {
        let mut acc = StreamingStats::default();
        for o in &self.outcomes {
            acc.merge(&o.stats.eigen);
        }
        acc
    }

    /// Per-world scaler spec labels in world (== service) order.
    pub fn spec_labels(&self) -> Vec<String> {
        self.outcomes.iter().map(|o| o.spec_label.clone()).collect()
    }

    /// Prediction MSEs of the PPA worlds that made predictions.
    pub fn prediction_mses(&self) -> Vec<f64> {
        self.outcomes.iter().filter_map(|o| o.prediction_mse).collect()
    }

    /// Champion–challenger summaries of the worlds whose scaler ran a
    /// selecting forecaster, in world (== service) order — the same
    /// order the monolith visits its scaler bindings.
    pub fn selections(&self) -> Vec<SelectionSummary> {
        self.outcomes.iter().filter_map(|o| o.selection.clone()).collect()
    }

    /// Every world's fault counters merged, in world order (all-zero on
    /// fault-free runs). Shard-count invariant like every other view.
    pub fn chaos_counters(&self) -> ChaosCounters {
        let mut acc = ChaosCounters::default();
        for o in &self.outcomes {
            acc.merge(&o.chaos);
        }
        acc
    }

    /// Every world's resilience-plane summary merged in deterministic
    /// world (== service) order: counters sum, per-class response
    /// moments combine exactly (Chan/Welford). All-zero without an
    /// installed `SlaPolicy`.
    pub fn sla_summary(&self) -> SlaSummary {
        let mut acc = SlaSummary::default();
        for o in &self.outcomes {
            acc.merge(&o.sla);
        }
        acc
    }

    /// Total node-hours billed across worlds (downtime excluded).
    pub fn cost_node_hours(&self) -> f64 {
        self.outcomes.iter().map(|o| o.cost_node_hours).sum()
    }

    /// Total pods ever spawned across worlds (cost-ledger churn).
    pub fn pod_churn(&self) -> u64 {
        self.outcomes.iter().map(|o| o.pod_churn).sum()
    }

    /// Reactive-override trips summed over the worlds whose scaler is a
    /// [`Hybrid`] (`None` when no world ran one).
    pub fn hybrid_trips(&self) -> Option<u64> {
        let trips: Vec<u64> = self.outcomes.iter().filter_map(|o| o.hybrid_trips).collect();
        if trips.is_empty() {
            None
        } else {
            Some(trips.iter().sum())
        }
    }

    /// Ticks decided under the reactive override, summed like
    /// [`Self::hybrid_trips`] (`None` when no world ran a hybrid).
    pub fn hybrid_override_ticks(&self) -> Option<u64> {
        let ticks: Vec<u64> =
            self.outcomes.iter().filter_map(|o| o.hybrid_override_ticks).collect();
        if ticks.is_empty() {
            None
        } else {
            Some(ticks.iter().sum())
        }
    }

    /// All RIR samples merged by time (stable: equal-time samples keep
    /// world order, matching the monolith's per-scrape service order).
    pub fn rir_log(&self) -> Vec<RirSample> {
        let mut all: Vec<RirSample> = self
            .outcomes
            .iter()
            .flat_map(|o| o.rir_log.iter().copied())
            .collect();
        all.sort_by_key(|s| s.time);
        all
    }

    /// All replica samples merged by time (stable, world order on ties).
    pub fn replica_log(&self) -> Vec<(Time, ServiceId, usize)> {
        let mut all: Vec<(Time, ServiceId, usize)> = self
            .outcomes
            .iter()
            .flat_map(|o| o.replica_log.iter().copied())
            .collect();
        all.sort_by_key(|&(t, _, _)| t);
        all
    }

    /// All autoscaler decisions merged by time (stable, world order on
    /// ties). Empty unless the run had `record_decisions`.
    pub fn decision_log(&self) -> Vec<DecisionRecord> {
        let mut all: Vec<DecisionRecord> = self
            .outcomes
            .iter()
            .flat_map(|o| o.decision_log.iter().cloned())
            .collect();
        all.sort_by_key(|d| d.time);
        all
    }
}

/// Run `cfg` sharded: partition into zone worlds, group them onto
/// `spec.shards` workers, and advance everything in lockstep windows of
/// the conservative lookahead, exchanging edge→cloud forwards at the
/// barriers. `make_scaler` is called once per world with the *global*
/// service index (== world index) and runs entirely on that world's
/// thread, so non-`Send` autoscalers are fine.
pub fn run_sharded(
    cfg: &ClusterConfig,
    generators: Vec<Generator>,
    make_scaler: &(dyn Fn(usize) -> Box<dyn Autoscaler> + Sync),
    spec: &ShardSpec,
) -> crate::Result<ShardedRun> {
    let plans = partition_worlds(cfg)?;
    let window = spec
        .costs
        .network_latency
        .saturating_add(spec.costs.forward_latency);
    if window == 0 {
        bail!(
            "sharded mode needs network_latency + forward_latency > 0 \
             (the conservative lookahead window)"
        );
    }
    let shards = spec.shards.max(1);
    let n_worlds = plans.len();
    let cloud_world = n_worlds - 1;

    // Bucket generators per zone world, preserving their relative order
    // (the bucketing depends only on zones, never on the shard count).
    let mut gen_buckets: Vec<Vec<Generator>> = (0..n_worlds).map(|_| Vec::new()).collect();
    for g in generators {
        match plans.iter().position(|p| p.zone == Some(g.zone())) {
            Some(w) => gen_buckets[w].push(g),
            None => bail!(
                "generator targets zone {} but no edge deployment covers it",
                g.zone()
            ),
        }
    }

    // Round-robin the edge worlds over workers; the cloud world lives on
    // worker 0, which also owns the barrier merge. The grouping affects
    // only load balance — results are grouping-independent.
    let mut ingredients: Vec<Option<(WorldPlan, Vec<Generator>)>> =
        plans.into_iter().zip(gen_buckets).map(Some).collect();
    let mut bundles: Vec<Vec<(usize, WorldPlan, Vec<Generator>)>> =
        (0..shards).map(|_| Vec::new()).collect();
    for w in 0..cloud_world {
        if let Some((plan, gens)) = ingredients[w].take() {
            bundles[w % shards].push((w, plan, gens));
        }
    }
    if let Some((plan, gens)) = ingredients[cloud_world].take() {
        bundles[0].push((cloud_world, plan, gens));
    }

    let end = spec.end;
    let barrier = Barrier::new(shards);
    // One forward slot per edge world, written by its owning worker
    // during the window, drained by worker 0 between the two barrier
    // waits — concatenation order is world order, never worker order.
    let slots: Vec<Mutex<Vec<ForwardedTask>>> =
        (0..cloud_world).map(|_| Mutex::new(Vec::new())).collect();

    let per_worker = std::thread::scope(|scope| -> crate::Result<Vec<Vec<WorldOutcome>>> {
        let mut handles = Vec::with_capacity(shards);
        for (worker, bundle) in bundles.into_iter().enumerate() {
            let barrier = &barrier;
            let slots = &slots;
            handles.push(scope.spawn(move || -> Vec<WorldOutcome> {
                // Worlds are built (and dropped) on their own thread.
                let mut worlds: Vec<ZoneWorld> = bundle
                    .into_iter()
                    .map(|(w, plan, gens)| {
                        ZoneWorld::build(&plan, w, gens, make_scaler(w), spec)
                    })
                    .collect();
                if spec.record_decisions {
                    for wld in &mut worlds {
                        wld.log_decisions = true;
                    }
                }
                let mut batch: Vec<ForwardedTask> = Vec::new();
                let mut t: Time = 0;
                while t < end {
                    let t_next = t.saturating_add(window).min(end);
                    for wld in &mut worlds {
                        wld.run_window(t_next);
                        if wld.zone.is_some() {
                            let fwds = wld.app.take_forwards();
                            if !fwds.is_empty() {
                                let mut slot = match slots[wld.world].lock() {
                                    Ok(s) => s,
                                    Err(poisoned) => poisoned.into_inner(),
                                };
                                slot.extend(fwds);
                            }
                        }
                    }
                    barrier.wait();
                    if worker == 0 {
                        for slot in slots.iter() {
                            let mut s = match slot.lock() {
                                Ok(s) => s,
                                Err(poisoned) => poisoned.into_inner(),
                            };
                            batch.append(&mut s);
                        }
                        // Stable: equal (submitted, zone) pairs — always
                        // from the same world — keep their submit order.
                        batch.sort_by_key(|f| (f.submitted, f.origin_zone));
                        if let Some(cloud) =
                            worlds.iter_mut().find(|wld| wld.zone.is_none())
                        {
                            for f in batch.drain(..) {
                                cloud.app.deliver_forward(f, &mut cloud.queue);
                            }
                        }
                    }
                    barrier.wait();
                    t = t_next;
                }
                worlds.into_iter().map(|wld| wld.finish(end)).collect()
            }));
        }
        let mut per_worker = Vec::with_capacity(shards);
        for h in handles {
            match h.join() {
                Ok(v) => per_worker.push(v),
                Err(_) => bail!("a shard worker panicked"),
            }
        }
        Ok(per_worker)
    })?;

    let mut slots_out: Vec<Option<WorldOutcome>> = (0..n_worlds).map(|_| None).collect();
    for outcomes in per_worker {
        for o in outcomes {
            let w = o.world;
            slots_out[w] = Some(o);
        }
    }
    let mut ordered = Vec::with_capacity(n_worlds);
    for (w, o) in slots_out.into_iter().enumerate() {
        match o {
            Some(o) => ordered.push(o),
            None => bail!("world {w} produced no outcome"),
        }
    }
    Ok(ShardedRun {
        outcomes: ordered,
        window,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscaler::Hpa;
    use crate::config::{paper_cluster, quickstart_cluster};
    use crate::sim::MIN;
    use crate::workload::RandomAccessGen;

    #[test]
    fn partition_paper_topology() {
        let cfg = paper_cluster();
        let plans = partition_worlds(&cfg).unwrap();
        assert_eq!(plans.len(), 3, "z1, z2, cloud");
        assert_eq!(plans[0].zone, Some(1));
        assert_eq!(plans[1].zone, Some(2));
        assert_eq!(plans[2].zone, None);
        // 7 nodes: 2 per edge zone, control + 2 cloud workers left over.
        assert_eq!(plans[0].cfg.nodes.len(), 2);
        assert_eq!(plans[1].cfg.nodes.len(), 2);
        assert_eq!(plans[2].cfg.nodes.len(), 3);
        assert!(plans.iter().all(|p| p.cfg.deployments.len() == 1));
        // The split is exact: every node lands in exactly one world.
        let total: usize = plans.iter().map(|p| p.cfg.nodes.len()).sum();
        assert_eq!(total, cfg.nodes.len());
    }

    #[test]
    fn partition_rejects_single_deployment() {
        let mut cfg = quickstart_cluster();
        cfg.deployments.truncate(1);
        assert!(partition_worlds(&cfg).is_err());
    }

    fn spec(shards: usize, seed: u64, end: Time) -> ShardSpec {
        ShardSpec {
            shards,
            core: CoreKind::Calendar,
            seed,
            costs: TaskCosts::default(),
            end,
            record_decisions: true,
            chaos: FaultPlan::none(),
            sla: None,
        }
    }

    /// Satellite: a forward submitted exactly at a barrier tick arrives
    /// exactly ON the next barrier tick and is processed in the window
    /// that tick closes (`pop_due` is inclusive) — the boundary case of
    /// the conservative-lookahead argument.
    #[test]
    fn forward_on_barrier_edge_lands_on_next_barrier_tick() {
        let costs = TaskCosts::default();
        let window = costs.network_latency + costs.forward_latency;
        let cfg = quickstart_cluster();
        let plans = partition_worlds(&cfg).unwrap();
        let sp = spec(1, 9, 10 * window);
        let cloud_plan = plans.last().unwrap();
        let mut cloud = ZoneWorld::build(
            cloud_plan,
            plans.len() - 1,
            Vec::new(),
            Box::new(Hpa::with_defaults()),
            &sp,
        );
        // Window 1 passes with nothing due (first pod/scrape ticks are
        // seconds away; the window is 60 ms).
        cloud.run_window(window);
        assert_eq!(cloud.events, 0);
        // Barrier 1: a forward submitted exactly at T_1 = window (it was
        // popped by its edge world in window 1, whose pop_due(T_1) is
        // inclusive) is delivered...
        cloud.app.deliver_forward(
            ForwardedTask {
                origin_zone: 1,
                submitted: window,
                priority: crate::app::Priority::Standard,
            },
            &mut cloud.queue,
        );
        // ...arriving exactly ON the next barrier tick T_2 = 2·window,
        assert_eq!(cloud.queue.peek_time(), Some(2 * window));
        assert_eq!(cloud.app.services[0].counters.arrivals, 1);
        // ...and window 2 (inclusive of its closing tick) processes it.
        cloud.run_window(2 * window);
        assert_eq!(cloud.events, 1, "arrival must pop in the window its tick closes");
    }

    fn sharded_quickstart(shards: usize, seed: u64) -> ShardedRun {
        let cfg = quickstart_cluster();
        let gens = vec![Generator::RandomAccess(RandomAccessGen::new(1))];
        run_sharded(
            &cfg,
            gens,
            &|_| Box::new(Hpa::with_defaults()),
            &spec(shards, seed, 6 * MIN),
        )
        .unwrap()
    }

    #[test]
    fn shard_counts_are_bit_identical_on_quickstart() {
        let one = sharded_quickstart(1, 42);
        let two = sharded_quickstart(2, 42);
        let four = sharded_quickstart(4, 42);
        assert!(one.events() > 100, "world should be busy: {}", one.events());
        assert!(one.completed() > 10);
        let cloud = one.outcomes.last().unwrap();
        assert!(
            cloud.stats.eigen.n() > 0,
            "cloud pool must serve forwarded Eigen tasks"
        );
        let decisions = |r: &ShardedRun| -> Vec<(Time, ServiceId, usize, bool)> {
            r.decision_log()
                .iter()
                .map(|d| (d.time, d.service, d.desired, d.used_fallback))
                .collect()
        };
        assert!(!decisions(&one).is_empty());
        for other in [&two, &four] {
            assert_eq!(one.fingerprint(), other.fingerprint(), "response streams");
            assert_eq!(one.events(), other.events(), "event counts");
            assert_eq!(one.completed(), other.completed());
            assert_eq!(decisions(&one), decisions(other), "decision logs");
            assert_eq!(one.rir_log().len(), other.rir_log().len());
        }
        // Different seeds must differ (the invariance is not vacuous).
        let other_seed = sharded_quickstart(2, 43);
        assert_ne!(one.fingerprint(), other_seed.fingerprint());
    }

    #[test]
    fn sharded_run_is_core_invariant() {
        let cfg = quickstart_cluster();
        let run_on = |core: CoreKind| {
            let gens = vec![Generator::RandomAccess(RandomAccessGen::new(1))];
            let sp = ShardSpec {
                core,
                ..spec(2, 7, 4 * MIN)
            };
            run_sharded(&cfg, gens, &|_| Box::new(Hpa::with_defaults()), &sp).unwrap()
        };
        let cal = run_on(CoreKind::Calendar);
        let heap = run_on(CoreKind::Heap);
        assert_eq!(cal.fingerprint(), heap.fingerprint());
        assert_eq!(cal.events(), heap.events());
    }

    #[test]
    fn unknown_generator_zone_rejected() {
        let cfg = quickstart_cluster();
        let gens = vec![Generator::RandomAccess(RandomAccessGen::new(9))];
        let err = run_sharded(
            &cfg,
            gens,
            &|_| Box::new(Hpa::with_defaults()),
            &spec(2, 1, MIN),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("zone 9"), "{err}");
    }

    #[test]
    fn zero_lookahead_rejected() {
        let cfg = quickstart_cluster();
        let mut sp = spec(2, 1, MIN);
        sp.costs.network_latency = 0;
        sp.costs.forward_latency = 0;
        let err = run_sharded(&cfg, Vec::new(), &|_| Box::new(Hpa::with_defaults()), &sp)
            .unwrap_err();
        assert!(format!("{err}").contains("lookahead"), "{err}");
    }

    #[test]
    fn more_shards_than_worlds_is_fine() {
        // 2 worlds on 8 workers: idle workers still hit every barrier.
        let eight = sharded_quickstart(8, 42);
        let one = sharded_quickstart(1, 42);
        assert_eq!(one.fingerprint(), eight.fingerprint());
    }

    fn storm() -> FaultPlan {
        use crate::cluster::{ColdStartPlan, CrashLoopPlan, NetDelayPlan, NodeCrashPlan};
        use crate::sim::{MS, SEC};
        FaultPlan {
            node_crash: Some(NodeCrashPlan {
                mean_gap: MIN,
                outage_min: 5 * SEC,
                outage_max: 20 * SEC,
                cloud: false,
            }),
            cold_start: Some(ColdStartPlan {
                slow_prob: 0.5,
                factor_min: 2.0,
                factor_max: 4.0,
            }),
            crash_loop: Some(CrashLoopPlan {
                prob: 0.25,
                max_restarts: 3,
            }),
            net_delay: Some(NetDelayPlan {
                extra_min: MS,
                extra_max: 50 * MS,
            }),
        }
    }

    /// Tentpole invariant: a faulted run — node crashes, cold starts,
    /// crash loops and network jitter all active — is bit-identical for
    /// every shard count, counters included.
    #[test]
    fn faulted_shard_counts_are_bit_identical() {
        let run = |shards| {
            let cfg = quickstart_cluster();
            let gens = vec![Generator::RandomAccess(RandomAccessGen::new(1))];
            let sp = ShardSpec {
                chaos: storm(),
                ..spec(shards, 42, 6 * MIN)
            };
            run_sharded(&cfg, gens, &|_| Box::new(Hpa::with_defaults()), &sp).unwrap()
        };
        let one = run(1);
        let two = run(2);
        let four = run(4);
        let c = one.chaos_counters();
        assert!(c.crashes > 0, "storm plan must crash nodes: {c:?}");
        assert!(c.pods_rescheduled > 0, "kills must trigger reschedules: {c:?}");
        for other in [&two, &four] {
            assert_eq!(one.fingerprint(), other.fingerprint(), "response streams");
            assert_eq!(one.events(), other.events(), "event counts");
            assert_eq!(
                format!("{:?}", one.chaos_counters()),
                format!("{:?}", other.chaos_counters()),
                "fault counters"
            );
        }
        // A faulted run must differ from the fault-free run of the seed.
        assert_ne!(one.fingerprint(), sharded_quickstart(1, 42).fingerprint());
    }

    /// Tentpole invariant: an SLA'd run — deadlines, retries, priority
    /// draws and shedding all active — is bit-identical for every shard
    /// count, resilience counters included; and `sla: None` reproduces
    /// the pre-resilience run of the same seed bit-for-bit.
    #[test]
    fn sla_shard_counts_are_bit_identical_and_none_is_noop() {
        use crate::app::{PriorityMix, SlaConfig, SlaPolicy};
        use crate::sim::{MS, SEC};
        let tight = SlaConfig {
            policy: SlaPolicy {
                deadline: 2 * SEC,
                max_retries: 2,
                backoff_base: 100 * MS,
                shed_queue_depth: 4,
            },
            mix: PriorityMix::default(),
        };
        let run = |shards| {
            let cfg = quickstart_cluster();
            let gens = vec![Generator::RandomAccess(RandomAccessGen::new(1))];
            let sp = ShardSpec {
                sla: Some(tight),
                ..spec(shards, 42, 6 * MIN)
            };
            run_sharded(&cfg, gens, &|_| Box::new(Hpa::with_defaults()), &sp).unwrap()
        };
        let one = run(1);
        let two = run(2);
        let four = run(4);
        let s = one.sla_summary();
        assert!(s.counters.timeouts > 0, "tight deadline must fire: {s:?}");
        for other in [&two, &four] {
            assert_eq!(one.fingerprint(), other.fingerprint(), "response streams");
            assert_eq!(one.events(), other.events(), "event counts");
            assert_eq!(s.counters, other.sla_summary().counters, "sla counters");
        }
        // Per-class moments merge identically across shard counts.
        for (a, b) in s.class_stats.iter().zip(four.sla_summary().class_stats.iter()) {
            assert_eq!(a.fingerprint(), b.fingerprint(), "class stats");
        }
        // `sla: None` is byte-identical to the pre-resilience build (the
        // plain quickstart run) — and distinct from the SLA'd run.
        let plain = sharded_quickstart(1, 42);
        assert!(plain.sla_summary().counters.is_zero());
        assert_ne!(one.fingerprint(), plain.fingerprint());
    }
}

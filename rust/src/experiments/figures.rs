//! Per-figure reproduction harnesses (paper §5–6). See DESIGN.md for the
//! experiment index; each harness prints paper-vs-measured rows via
//! [`crate::report`].

use super::driver::SimWorld;
use super::{make_forecaster, try_runtime, ModelKind};
use crate::app::{TaskCosts, TaskType};
use crate::autoscaler::ppa::PredictionRecord;
use crate::autoscaler::{Hpa, MetricSpec, Ppa, PpaConfig};
use crate::config::paper_cluster;
use crate::forecast::UpdatePolicy;
use crate::metrics::{M_CPU, M_REQ_RATE, METRIC_DIM};
use crate::runtime::LstmRuntime;
use crate::sim::{Time, HOUR, MIN};
use crate::stats::{summarize, welch_t_test, Summary, WelchResult};
use crate::util::csv::CsvWriter;
use crate::workload::{nasa_synthetic, Generator, NasaTraceConfig, RandomAccessGen, TraceGen};
use anyhow::Context;
use std::rc::Rc;
use std::sync::Arc;

/// Parameters for the Random-Access optimization experiments (Figs 7–10).
#[derive(Debug, Clone, Copy)]
pub struct FigParams {
    /// Run length in minutes (paper: 200).
    pub minutes: u64,
    /// Pretraining collection length in hours (paper: 10 → 1800 records).
    pub pretrain_hours: f64,
    pub seed: u64,
}

impl Default for FigParams {
    fn default() -> Self {
        FigParams {
            minutes: 200,
            pretrain_hours: 10.0,
            seed: 2021,
        }
    }
}

/// Parameters for the NASA evaluation (Figs 11–14).
#[derive(Debug, Clone, Copy)]
pub struct NasaParams {
    /// Evaluation length in hours (paper: 48).
    pub hours: f64,
    pub trace: NasaTraceConfig,
    pub pretrain_hours: f64,
    pub seed: u64,
}

impl Default for NasaParams {
    fn default() -> Self {
        NasaParams {
            hours: 48.0,
            trace: NasaTraceConfig::default(),
            pretrain_hours: 10.0,
            seed: 2021,
        }
    }
}

/// Where experiment CSVs land.
pub fn experiments_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("target/experiments")
}

// ---------------------------------------------------------------------------
// Shared builders
// ---------------------------------------------------------------------------

fn world_random_access(seed: u64) -> SimWorld {
    let cfg = paper_cluster();
    let mut w = SimWorld::build(&cfg, TaskCosts::default(), seed);
    // Figure harnesses need exact traces (Welch tests, CSV dumps), so
    // they opt into the full response log on top of the streaming stats.
    w.record_responses();
    w.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    w.add_generator(Generator::RandomAccess(RandomAccessGen::new(2)));
    w
}

fn world_nasa(seed: u64, counts: &Arc<Vec<f64>>) -> SimWorld {
    let cfg = paper_cluster();
    let mut w = SimWorld::build(&cfg, TaskCosts::default(), seed);
    w.record_responses();
    w.add_generator(Generator::Trace(TraceGen::new(1, counts.clone(), 0.5)));
    w.add_generator(Generator::Trace(TraceGen::new(2, counts.clone(), 0.5)));
    w
}

/// Per-service Eq-1 threshold for a key metric: CPU uses the paper's
/// summed-percent target; request-rate uses 70% of one pod's service
/// capacity (req/s) so both keys aim at the same utilization level.
fn threshold_for(key_metric: usize, service_idx: usize, costs: &TaskCosts) -> f64 {
    if key_metric == M_REQ_RATE {
        let (core_secs, pod_cores) = if service_idx <= 1 {
            (costs.sort_core_secs, 0.5) // edge pools: Sort on 500m pods
        } else {
            (costs.eigen_core_secs, 1.0) // cloud pool: Eigen on 1000m pods
        };
        // Per-pod capacity includes the on-pod dispatch overhead.
        let occupancy_secs = crate::sim::to_secs(costs.overhead) + core_secs / pod_cores;
        0.7 / occupancy_secs
    } else {
        70.0
    }
}

/// Construct a pretrained PPA for one service.
#[allow(clippy::too_many_arguments)]
fn ppa_for(
    service_idx: usize,
    model: ModelKind,
    policy: UpdatePolicy,
    key_metric: usize,
    runtime: Option<&Rc<LstmRuntime>>,
    pretrain: &[[f64; METRIC_DIM]],
    update_interval: Time,
    seed: u32,
) -> crate::Result<Ppa> {
    let costs = TaskCosts::default();
    let forecaster = make_forecaster(model, runtime, pretrain, seed)?;
    let cfg = PpaConfig {
        specs: vec![MetricSpec::forecast(
            key_metric,
            threshold_for(key_metric, service_idx, &costs),
        )],
        update_policy: policy,
        update_interval,
        ..PpaConfig::default()
    };
    Ok(Ppa::new(cfg, forecaster))
}

/// Recover the PPA bound to scaler slot `idx` after a run.
fn ppa_at(world: &SimWorld, idx: usize) -> &Ppa {
    world.scalers[idx]
        .autoscaler
        .as_any()
        .downcast_ref::<Ppa>()
        .expect("scaler is a PPA")
}

/// Dump every service's replica trajectory straight from the TSDB via the
/// interned [`crate::metrics::ServiceSeries`] handles — the adapter's
/// handle-query path, no string keys.
fn write_replica_csv(name: &str, world: &SimWorld) -> crate::Result<()> {
    let mut w = CsvWriter::create(
        experiments_dir().join(name),
        &["time_s", "service", "replicas"],
    )?;
    for svc_idx in 0..world.app.services.len() {
        let id = world
            .metrics
            .service_series(crate::sim::ServiceId(svc_idx as u32))
            .replicas;
        for (t, v) in world.metrics.tsdb.series_by_id(id).iter() {
            w.row(&[crate::sim::to_secs(t), svc_idx as f64, v])?;
        }
    }
    w.flush()?;
    Ok(())
}

fn write_prediction_csv(name: &str, records: &[PredictionRecord]) -> crate::Result<()> {
    let mut w = CsvWriter::create(
        experiments_dir().join(name),
        &["time_s", "predicted", "actual"],
    )?;
    for r in records {
        w.row(&[crate::sim::to_secs(r.time), r.predicted, r.actual])?;
    }
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 6 — the scaled NASA trace
// ---------------------------------------------------------------------------

/// Generate (and CSV-dump) the scaled NASA request series of Fig 6.
pub fn fig6_trace(cfg: &NasaTraceConfig) -> crate::Result<Vec<f64>> {
    let counts = nasa_synthetic(cfg);
    let mut w = CsvWriter::create(
        experiments_dir().join("fig6_nasa_trace.csv"),
        &["minute", "requests"],
    )?;
    for (m, &c) in counts.iter().enumerate() {
        w.row(&[m as f64, c])?;
    }
    w.flush()?;
    Ok(counts)
}

// ---------------------------------------------------------------------------
// Fig 7 — ARMA vs LSTM prediction quality
// ---------------------------------------------------------------------------

/// One model's prediction outcome on the 200-minute run.
#[derive(Debug)]
pub struct PredictionOutcome {
    pub model: String,
    pub mse: f64,
    pub n: usize,
    pub records: Vec<PredictionRecord>,
}

#[derive(Debug)]
pub struct Fig7 {
    pub lstm: PredictionOutcome,
    pub arma: PredictionOutcome,
}

/// Run one PPA-under-test (service 0 = edge-z1) with HPA on the other
/// services; returns the PPA's prediction log + the world.
fn run_ppa_under_test(
    params: &FigParams,
    model: ModelKind,
    policy: UpdatePolicy,
    key_metric: usize,
    runtime: Option<&Rc<LstmRuntime>>,
    pretrain: &[[f64; METRIC_DIM]],
) -> crate::Result<SimWorld> {
    let mut world = world_random_access(params.seed);
    let n_services = world.app.services.len();
    let mut ppa = ppa_for(
        0,
        model,
        policy,
        key_metric,
        runtime,
        pretrain,
        HOUR,
        params.seed as u32,
    )?;
    // Figure harnesses need the exact (predicted, actual) trace for the
    // CSV dumps — the log is opt-in (sweep cells stay flat-memory).
    ppa.record_logs();
    world.add_scaler(Box::new(ppa), 0);
    for svc in 1..n_services {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    world.run_until(params.minutes * MIN);
    Ok(world)
}

/// Fig 7: compare ARMA and LSTM prediction of the key metric on the
/// running application. Paper: LSTM MSE 53 240.97 < ARMA MSE 96 867.63.
pub fn fig7_model_comparison(params: &FigParams) -> crate::Result<Fig7> {
    let runtime = try_runtime().context(
        "Fig 7 needs the LSTM artifacts — run `make artifacts` first",
    )?;
    let (hist, _) = super::pretrain_histories(params.pretrain_hours, 20, params.seed);
    let pretrain = &hist[0];

    let mut outcomes = Vec::new();
    for model in [ModelKind::Lstm, ModelKind::Arma] {
        let world = run_ppa_under_test(
            params,
            model,
            UpdatePolicy::FineTune,
            M_CPU,
            Some(&runtime),
            pretrain,
        )?;
        let ppa = ppa_at(&world, 0);
        let records = ppa.prediction_log.clone();
        write_prediction_csv(&format!("fig7_{}.csv", model.name()), &records)?;
        outcomes.push(PredictionOutcome {
            model: model.name().to_string(),
            mse: ppa.prediction_mse(),
            n: records.len(),
            records,
        });
    }
    let arma = outcomes.pop().unwrap();
    let lstm = outcomes.pop().unwrap();
    Ok(Fig7 { lstm, arma })
}

// ---------------------------------------------------------------------------
// Fig 8 — update policies
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Fig8 {
    /// Outcomes for policies 1, 2, 3 (in order).
    pub policies: Vec<PredictionOutcome>,
}

/// Fig 8: compare the three model-update policies with the LSTM.
/// Paper MSEs: P1 64 769.88, P2 42 180.44, P3 30 994.45 (P3 best).
pub fn fig8_update_policies(params: &FigParams) -> crate::Result<Fig8> {
    let runtime = try_runtime().context(
        "Fig 8 needs the LSTM artifacts — run `make artifacts` first",
    )?;
    let (hist, _) = super::pretrain_histories(params.pretrain_hours, 20, params.seed);
    let pretrain = &hist[0];

    let mut policies = Vec::new();
    for policy in [
        UpdatePolicy::KeepSeed,
        UpdatePolicy::RetrainScratch,
        UpdatePolicy::FineTune,
    ] {
        let world = run_ppa_under_test(
            params,
            ModelKind::Lstm,
            policy,
            M_CPU,
            Some(&runtime),
            pretrain,
        )?;
        let ppa = ppa_at(&world, 0);
        let records = ppa.prediction_log.clone();
        write_prediction_csv(&format!("fig8_{}.csv", policy.name()), &records)?;
        policies.push(PredictionOutcome {
            model: policy.name().to_string(),
            mse: ppa.prediction_mse(),
            n: records.len(),
            records,
        });
    }
    Ok(Fig8 { policies })
}

// ---------------------------------------------------------------------------
// Figs 9 & 10 — key-metric comparison
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct KeyMetricOutcome {
    pub key: String,
    pub response: Summary,
    pub rir: Summary,
    pub responses: Vec<f64>,
    pub rirs: Vec<f64>,
}

#[derive(Debug)]
pub struct Fig9And10 {
    pub cpu: KeyMetricOutcome,
    pub req_rate: KeyMetricOutcome,
    pub response_welch: WelchResult,
    pub rir_welch: WelchResult,
}

/// Figs 9–10: PPA keyed on CPU vs on request rate. Paper: response times
/// statistically equal (0.5156 s vs 0.5157 s); RIR lower (better) for the
/// CPU key (0.251±0.092 vs 0.317±0.161).
pub fn fig9_fig10_key_metric(params: &FigParams) -> crate::Result<Fig9And10> {
    let runtime = try_runtime().context(
        "Figs 9/10 need the LSTM artifacts — run `make artifacts` first",
    )?;
    let (hist, _) = super::pretrain_histories(params.pretrain_hours, 20, params.seed);

    let mut outcomes = Vec::new();
    for (key_name, key_idx) in [("cpu", M_CPU), ("req_rate", M_REQ_RATE)] {
        let mut world = world_random_access(params.seed);
        let n_services = world.app.services.len();
        for svc in 0..n_services {
            // Edge services pretrain on the edge history, cloud on cloud's.
            let pre = if svc + 1 == n_services {
                hist.last().unwrap()
            } else {
                &hist[0]
            };
            let ppa = ppa_for(
                svc,
                ModelKind::Lstm,
                UpdatePolicy::FineTune,
                key_idx,
                Some(&runtime),
                pre,
                HOUR,
                params.seed as u32 + svc as u32,
            )?;
            world.add_scaler(Box::new(ppa), svc);
        }
        world.run_until(params.minutes * MIN);

        // Sort response times (exact, from the retained log); system-wide
        // RIR across services.
        let responses: Vec<f64> = world.response_times(TaskType::Sort);
        let rirs: Vec<f64> = world.rir_log.iter().map(|s| s.rir).collect();

        let mut w = CsvWriter::create(
            experiments_dir().join(format!("fig9_10_key_{key_name}.csv")),
            &["response_s"],
        )?;
        for &r in &responses {
            w.row(&[r])?;
        }
        w.flush()?;

        outcomes.push(KeyMetricOutcome {
            key: key_name.to_string(),
            response: summarize(&responses),
            rir: summarize(&rirs),
            responses,
            rirs,
        });
    }
    let req_rate = outcomes.pop().unwrap();
    let cpu = outcomes.pop().unwrap();
    let response_welch = welch_t_test(&cpu.responses, &req_rate.responses);
    let rir_welch = welch_t_test(&cpu.rirs, &req_rate.rirs);
    Ok(Fig9And10 {
        cpu,
        req_rate,
        response_welch,
        rir_welch,
    })
}

// ---------------------------------------------------------------------------
// Figs 11–14 — NASA evaluation: PPA vs HPA
// ---------------------------------------------------------------------------

/// One autoscaler's evaluation outcome over the NASA run.
#[derive(Debug)]
pub struct EvalOutcome {
    pub scaler: String,
    pub sort: Summary,
    pub eigen: Summary,
    pub edge_rir: Summary,
    pub cloud_rir: Summary,
    pub sort_responses: Vec<f64>,
    pub eigen_responses: Vec<f64>,
    pub edge_rirs: Vec<f64>,
    pub cloud_rirs: Vec<f64>,
    pub completed: usize,
}

#[derive(Debug)]
pub struct NasaEval {
    pub hpa: EvalOutcome,
    pub ppa: EvalOutcome,
    /// Welch tests: Figs 11, 12, 13, 14 respectively.
    pub sort_welch: WelchResult,
    pub eigen_welch: WelchResult,
    pub edge_rir_welch: WelchResult,
    pub cloud_rir_welch: WelchResult,
}

fn eval_outcome(world: &SimWorld, scaler: &str, n_services: usize) -> EvalOutcome {
    let sort_responses = world.response_times(TaskType::Sort);
    let eigen_responses = world.response_times(TaskType::Eigen);
    // Edge services are all but the last; cloud is the last.
    let mut edge_rirs = Vec::new();
    for svc in 0..n_services - 1 {
        edge_rirs.extend(world.rir_for(svc));
    }
    let cloud_rirs = world.rir_for(n_services - 1);
    EvalOutcome {
        scaler: scaler.to_string(),
        sort: summarize(&sort_responses),
        eigen: summarize(&eigen_responses),
        edge_rir: summarize(&edge_rirs),
        cloud_rir: summarize(&cloud_rirs),
        completed: world.app.completed(),
        sort_responses,
        eigen_responses,
        edge_rirs,
        cloud_rirs,
    }
}

/// Figs 11–14: the 48 h NASA evaluation, HPA vs optimally configured PPA
/// (LSTM, policy 3, key = CPU). Paper: PPA wins all four comparisons with
/// p < 1e-3 (Sort 0.508 vs 0.592 s; Eigen 13.646 vs 14.206 s; edge RIR
/// 0.2988 vs 0.3209; cloud RIR 0.3098 vs 0.3373).
pub fn nasa_eval(params: &NasaParams) -> crate::Result<NasaEval> {
    let runtime = try_runtime().context(
        "the NASA evaluation needs the LSTM artifacts — run `make artifacts` first",
    )?;
    let counts = Arc::new(nasa_synthetic(&params.trace));
    let minutes = (params.hours * 60.0) as usize;
    anyhow::ensure!(
        minutes <= counts.len(),
        "trace shorter than requested evaluation ({} < {} min)",
        counts.len(),
        minutes
    );
    let (hist, _) = super::pretrain_histories(params.pretrain_hours, 20, params.seed);
    let end = (params.hours * HOUR as f64) as Time;

    // Run 1: HPA everywhere (full Kubernetes semantics: tolerance band
    // + 5-min downscale stabilization — the strongest HPA baseline).
    let mut hpa_world = world_nasa(params.seed, &counts);
    let n_services = hpa_world.app.services.len();
    for svc in 0..n_services {
        hpa_world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    hpa_world.run_until(end);
    let hpa = eval_outcome(&hpa_world, "hpa", n_services);

    // Run 2: PPA everywhere (optimal config).
    let mut ppa_world = world_nasa(params.seed, &counts);
    for svc in 0..n_services {
        let pre = if svc + 1 == n_services {
            hist.last().unwrap()
        } else {
            &hist[0]
        };
        let ppa = ppa_for(
            svc,
            ModelKind::Lstm,
            UpdatePolicy::FineTune,
            M_CPU,
            Some(&runtime),
            pre,
            HOUR,
            params.seed as u32 + svc as u32,
        )?;
        ppa_world.add_scaler(Box::new(ppa), svc);
    }
    ppa_world.run_until(end);
    let ppa = eval_outcome(&ppa_world, "ppa", n_services);

    // Replica trajectories (handle-based TSDB reads).
    write_replica_csv("fig11_14_replicas_hpa.csv", &hpa_world)?;
    write_replica_csv("fig11_14_replicas_ppa.csv", &ppa_world)?;

    // CSV dumps per figure.
    for (name, a, b) in [
        ("fig11_sort", &hpa.sort_responses, &ppa.sort_responses),
        ("fig12_eigen", &hpa.eigen_responses, &ppa.eigen_responses),
        ("fig13_edge_rir", &hpa.edge_rirs, &ppa.edge_rirs),
        ("fig14_cloud_rir", &hpa.cloud_rirs, &ppa.cloud_rirs),
    ] {
        let mut w = CsvWriter::create(
            experiments_dir().join(format!("{name}.csv")),
            &["hpa", "ppa"],
        )?;
        for i in 0..a.len().max(b.len()) {
            w.row(&[
                a.get(i).copied().unwrap_or(f64::NAN),
                b.get(i).copied().unwrap_or(f64::NAN),
            ])?;
        }
        w.flush()?;
    }

    Ok(NasaEval {
        sort_welch: welch_t_test(&hpa.sort_responses, &ppa.sort_responses),
        eigen_welch: welch_t_test(&hpa.eigen_responses, &ppa.eigen_responses),
        edge_rir_welch: welch_t_test(&hpa.edge_rirs, &ppa.edge_rirs),
        cloud_rir_welch: welch_t_test(&hpa.cloud_rirs, &ppa.cloud_rirs),
        hpa,
        ppa,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Short-horizon smoke of the full fig7 pipeline (LSTM + ARMA) — only
    /// when artifacts exist.
    #[test]
    fn fig7_smoke_short() {
        if try_runtime().is_none() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let params = FigParams {
            minutes: 15,
            pretrain_hours: 0.5,
            seed: 3,
        };
        let fig = fig7_model_comparison(&params).unwrap();
        assert!(fig.lstm.n > 20, "prediction pairs: {}", fig.lstm.n);
        assert!(fig.lstm.mse.is_finite());
        assert!(fig.arma.mse.is_finite());
    }

    #[test]
    fn nasa_eval_smoke_short() {
        if try_runtime().is_none() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let params = NasaParams {
            hours: 0.5,
            pretrain_hours: 0.4,
            seed: 4,
            trace: NasaTraceConfig {
                minutes: 40,
                ..NasaTraceConfig::default()
            },
        };
        let eval = nasa_eval(&params).unwrap();
        assert!(eval.hpa.completed > 100);
        assert!(eval.ppa.completed > 100);
        assert!(eval.hpa.sort.mean > 0.0);
        assert!(eval.ppa.edge_rir.n > 0);
    }

    #[test]
    fn fig6_trace_written() {
        let counts = fig6_trace(&NasaTraceConfig {
            minutes: 100,
            ..NasaTraceConfig::default()
        })
        .unwrap();
        assert_eq!(counts.len(), 100);
        assert!(experiments_dir().join("fig6_nasa_trace.csv").exists());
    }

    #[test]
    fn thresholds_scale_with_key() {
        let costs = TaskCosts::default();
        assert_eq!(threshold_for(M_CPU, 0, &costs), 70.0);
        let edge = threshold_for(M_REQ_RATE, 0, &costs);
        let cloud = threshold_for(M_REQ_RATE, 2, &costs);
        assert!(edge > 1.0 && edge < 3.0, "edge rate threshold {edge}");
        assert!(cloud < 0.2, "cloud rate threshold {cloud}");
    }
}

//! Autoscalers: the reactive Kubernetes HPA baseline and the paper's
//! Proactive Pod Autoscaler (PPA).
//!
//! Both implement [`Autoscaler`]; the experiment driver ticks them on
//! their control interval and applies the returned desired-replica count
//! through [`crate::cluster::Cluster::reconcile`] — exactly the paper's
//! "make requests for scaling decisions to the Kubernetes master" flow.

pub mod hpa;
pub mod ppa;

pub use hpa::Hpa;
pub use ppa::{Ppa, PpaConfig};

use crate::cluster::{Cluster, DeploymentId};
use crate::metrics::MetricsPipeline;
use crate::sim::{ServiceId, Time};

/// One control-loop decision (with provenance, for the experiment logs).
#[derive(Debug, Clone, Copy)]
pub struct ScaleDecision {
    pub desired: usize,
    /// The key-metric value the decision was computed from.
    pub key_value: f64,
    /// The model's prediction for the *next* interval, if one was made.
    pub predicted: Option<f64>,
    /// True when Algorithm 1 fell back to current metrics (invalid model
    /// or low confidence).
    pub used_fallback: bool,
}

/// A pod autoscaler bound to one target service/deployment.
pub trait Autoscaler {
    fn name(&self) -> &str;

    /// The control-loop period.
    fn control_interval(&self) -> Time;

    /// The model-update-loop period (proactive autoscalers only).
    fn update_interval(&self) -> Option<Time> {
        None
    }

    /// One control-loop evaluation: read metrics via the adapter, decide
    /// the desired replica count for `target`.
    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision;

    /// One model-update-loop step (no-op for reactive autoscalers).
    fn model_update(&mut self, _now: Time) -> crate::Result<()> {
        Ok(())
    }

    /// Downcast hook so experiment harnesses can recover concrete state
    /// (e.g. the PPA's prediction log) after a run.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Eq 1 of the paper (also the K8s HPA rule):
/// `NumOfReplicas = ceil(CurrentMetricValue / PredefinedMetricValue)`.
pub fn eq1_replicas(metric_value: f64, predefined: f64) -> usize {
    if !metric_value.is_finite() || metric_value <= 0.0 {
        return 0;
    }
    (metric_value / predefined).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_matches_paper_rule() {
        assert_eq!(eq1_replicas(0.0, 70.0), 0);
        assert_eq!(eq1_replicas(1.0, 70.0), 1);
        assert_eq!(eq1_replicas(70.0, 70.0), 1);
        assert_eq!(eq1_replicas(70.1, 70.0), 2);
        assert_eq!(eq1_replicas(350.0, 70.0), 5);
        assert_eq!(eq1_replicas(f64::NAN, 70.0), 0);
    }
}

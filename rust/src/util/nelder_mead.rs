//! Derivative-free Nelder–Mead simplex minimizer.
//!
//! Used by [`crate::forecast::arma`] for conditional-sum-of-squares ARMA
//! fitting (statsmodels does the same job in the paper's stack).

/// Minimize `f` starting from `x0`. Returns (argmin, min value).
///
/// Standard coefficients (α=1, γ=2, ρ=0.5, σ=0.5); converges when the
/// simplex's value spread falls below `tol` or `max_iter` is exhausted.
pub fn minimize<F>(f: F, x0: &[f64], step: f64, tol: f64, max_iter: usize) -> (Vec<f64>, f64)
where
    F: Fn(&[f64]) -> f64,
{
    let n = x0.len();
    assert!(n > 0);
    // Initial simplex: x0 plus a perturbation along each axis.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    simplex.push((x0.to_vec(), f(x0)));
    for i in 0..n {
        let mut p = x0.to_vec();
        p[i] += if p[i].abs() > 1e-12 { step * p[i].abs() } else { step };
        let v = f(&p);
        simplex.push((p, v));
    }

    for _ in 0..max_iter {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        let best = simplex[0].1;
        let worst = simplex[n].1;
        if (worst - best).abs() < tol * (1.0 + best.abs()) {
            break;
        }

        // Centroid of all but the worst.
        let mut centroid = vec![0.0; n];
        for (p, _) in &simplex[..n] {
            for (c, x) in centroid.iter_mut().zip(p) {
                *c += x / n as f64;
            }
        }

        let reflect: Vec<f64> = centroid
            .iter()
            .zip(&simplex[n].0)
            .map(|(c, w)| c + (c - w))
            .collect();
        let fr = f(&reflect);

        if fr < simplex[0].1 {
            // Expand.
            let expand: Vec<f64> = centroid
                .iter()
                .zip(&simplex[n].0)
                .map(|(c, w)| c + 2.0 * (c - w))
                .collect();
            let fe = f(&expand);
            simplex[n] = if fe < fr { (expand, fe) } else { (reflect, fr) };
        } else if fr < simplex[n - 1].1 {
            simplex[n] = (reflect, fr);
        } else {
            // Contract (outside if reflection helped over worst, else inside).
            let towards = if fr < simplex[n].1 { &reflect } else { &simplex[n].0.clone() };
            let contract: Vec<f64> = centroid
                .iter()
                .zip(towards)
                .map(|(c, w)| c + 0.5 * (w - c))
                .collect();
            let fc = f(&contract);
            if fc < simplex[n].1.min(fr) {
                simplex[n] = (contract, fc);
            } else {
                // Shrink towards the best point.
                let best_p = simplex[0].0.clone();
                for entry in simplex.iter_mut().skip(1) {
                    let p: Vec<f64> = best_p
                        .iter()
                        .zip(&entry.0)
                        .map(|(b, x)| b + 0.5 * (x - b))
                        .collect();
                    let v = f(&p);
                    *entry = (p, v);
                }
            }
        }
    }

    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
    simplex.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        let (x, v) = minimize(|p| (p[0] - 3.0).powi(2) + (p[1] + 1.0).powi(2), &[0.0, 0.0], 0.5, 1e-12, 500);
        assert!((x[0] - 3.0).abs() < 1e-4, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-4, "{x:?}");
        assert!(v < 1e-7);
    }

    #[test]
    fn minimizes_rosenbrock() {
        let rosen = |p: &[f64]| {
            let (a, b) = (p[0], p[1]);
            (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
        };
        let (x, _v) = minimize(rosen, &[-1.2, 1.0], 0.5, 1e-14, 5000);
        assert!((x[0] - 1.0).abs() < 1e-3, "{x:?}");
        assert!((x[1] - 1.0).abs() < 1e-3, "{x:?}");
    }

    #[test]
    fn one_dimensional() {
        let (x, _) = minimize(|p| (p[0] - 0.25).powi(2), &[10.0], 1.0, 1e-12, 300);
        assert!((x[0] - 0.25).abs() < 1e-5);
    }
}

//! Cross-module integration tests: full worlds, proactive-vs-reactive
//! behaviour, failure injection, and paper-shape checks at reduced scale.

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::{Hpa, Ppa, PpaConfig};
use ppa_edge::config::{paper_cluster, quickstart_cluster};
use ppa_edge::experiments::{self, SimWorld};
use ppa_edge::forecast::{Forecaster, NaiveForecaster, UpdatePolicy};
use ppa_edge::metrics::METRIC_DIM;
use ppa_edge::sim::{ServiceId, MIN};
use ppa_edge::workload::{Generator, NasaTraceConfig, RandomAccessGen, TraceGen};
use std::sync::Arc;

fn hpa_everywhere(world: &mut SimWorld) {
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
}

#[test]
fn paper_cluster_serves_random_access_one_hour() {
    let cfg = paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 101);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(2)));
    hpa_everywhere(&mut world);
    world.run_until(60 * MIN);

    assert!(world.app.completed() > 1000, "{}", world.app.completed());
    let sort = world.app.stats.sort.summary();
    let eigen = world.app.stats.eigen.summary();
    // Calibration shape: Sort sub-second-ish, Eigen >5 s (paper: 0.5/13.6).
    assert!(sort.mean > 0.3 && sort.mean < 3.0, "sort mean {}", sort.mean);
    assert!(eigen.mean > 4.0, "eigen mean {}", eigen.mean);
    assert!(eigen.mean > 5.0 * sort.mean, "eigen must dominate sort");
    // The replica metric counts Pending pods (K8s semantics), so its
    // bound is Eq 1 on the saturated CPU sum, not node capacity: edge
    // pools run at most 2x(1700/500)=6 pods, whose saturated sum 600
    // lets HPA desire up to ceil(600/70)=9; the cloud pool runs at most
    // 2x(2800/1000)=4 pods -> ceil(400/70)=6 desired.
    for &(_, svc, replicas) in &world.replica_log {
        if svc == ServiceId(2) {
            assert!(replicas <= 6, "cloud replica metric above Eq-1 bound: {replicas}");
        } else {
            assert!(replicas <= 9, "edge replica metric above Eq-1 bound: {replicas}");
        }
    }
    // Physically Running pods are within node capacity at end of run
    // (the scheduler's bind-time fit check enforces this throughout; the
    // properties suite covers the over-time invariant).
    use ppa_edge::cluster::{DeploymentId, PodPhase};
    assert!(
        world.cluster.count_phase(DeploymentId(0), PodPhase::Running) <= 6,
        "edge z1 over capacity"
    );
    assert!(
        world.cluster.count_phase(DeploymentId(1), PodPhase::Running) <= 6,
        "edge z2 over capacity"
    );
    assert!(
        world.cluster.count_phase(DeploymentId(2), PodPhase::Running) <= 4,
        "cloud over capacity"
    );
}

#[test]
fn nasa_trace_replay_end_to_end() {
    let counts = Arc::new(ppa_edge::workload::nasa_synthetic(&NasaTraceConfig {
        minutes: 60,
        ..NasaTraceConfig::default()
    }));
    let cfg = paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 55);
    world.add_generator(Generator::Trace(TraceGen::new(1, counts.clone(), 0.5)));
    world.add_generator(Generator::Trace(TraceGen::new(2, counts.clone(), 0.5)));
    hpa_everywhere(&mut world);
    world.run_until(60 * MIN);
    assert!(world.app.completed() > 500);
    // Arrivals should roughly match the trace total.
    let total_trace: f64 = counts.iter().sum();
    let served = world.app.completed() as f64;
    assert!(
        served > total_trace * 0.5 && served < total_trace * 1.3,
        "served {served} vs trace {total_trace}"
    );
}

#[test]
fn ppa_naive_beats_or_matches_hpa_on_bursty_load() {
    // The PPA's proactive scaling (20 s interval + trend following) should
    // at minimum not lose to HPA on the same workload/seed.
    let run = |use_ppa: bool| {
        let cfg = quickstart_cluster();
        let mut world = SimWorld::build(&cfg, TaskCosts::default(), 77);
        world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
        for svc in 0..world.app.services.len() {
            if use_ppa {
                world.add_scaler(
                    Box::new(Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster))),
                    svc,
                );
            } else {
                world.add_scaler(Box::new(Hpa::with_defaults()), svc);
            }
        }
        world.run_until(90 * MIN);
        world.app.stats.sort.mean()
    };
    let hpa_mean = run(false);
    let ppa_mean = run(true);
    assert!(
        ppa_mean < hpa_mean * 1.25,
        "ppa {ppa_mean} should not lose badly to hpa {hpa_mean}"
    );
}

#[test]
fn model_update_failure_does_not_kill_the_world() {
    /// A forecaster whose retrain always fails (corrupt model file).
    struct CorruptModel;
    impl Forecaster for CorruptModel {
        fn name(&self) -> &str {
            "corrupt"
        }
        fn predict(&mut self, h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
            h.last().copied()
        }
        fn retrain(
            &mut self,
            _h: &[[f64; METRIC_DIM]],
            _p: UpdatePolicy,
        ) -> anyhow::Result<()> {
            anyhow::bail!("model file corrupted")
        }
    }

    let cfg = quickstart_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 13);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    let ppa = Ppa::new(
        PpaConfig {
            update_interval: 10 * MIN, // fail repeatedly within the run
            ..PpaConfig::default()
        },
        Box::new(CorruptModel),
    );
    world.add_scaler(Box::new(ppa), 0);
    world.add_scaler(Box::new(Hpa::with_defaults()), 1);
    world.run_until(45 * MIN);
    // The world survived several failed update loops and kept serving.
    assert!(world.app.completed() > 100);
}

#[test]
fn cluster_capacity_saturation_backpressure() {
    // Flood a tiny cluster: queue grows, but completed responses keep
    // flowing and replicas never exceed capacity.
    let cfg = quickstart_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 99);
    // Two generators on the same zone = double load.
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    hpa_everywhere(&mut world);
    world.run_until(30 * MIN);
    // The replica metric counts Pending pods too (K8s semantics); the
    // schedulable bound is 3x500m per node, and HPA's Eq 1 caps desired
    // at ceil(300/70)=5 even when the metric saturates.
    let max_edge_replicas = world
        .replica_log
        .iter()
        .filter(|&&(_, svc, _)| svc == ServiceId(0))
        .map(|&(_, _, r)| r)
        .max()
        .unwrap();
    assert!(max_edge_replicas <= 5, "bounded by Eq 1: {max_edge_replicas}");
    // Physically running pods never exceeded node capacity.
    let running = world
        .cluster
        .pods
        .iter()
        .filter(|p| p.phase == ppa_edge::cluster::PodPhase::Running)
        .count();
    assert!(running <= 6, "3 edge + 2 cloud slots: {running}");
    assert!(world.app.completed() > 200);
}

#[test]
fn pretraining_dataset_statistics() {
    let (hist, _) = experiments::pretrain_histories(0.5, 20, 2021);
    // Protocol vector sanity: CPU in [0, sum-bound], rates non-negative.
    for row in &hist[0] {
        assert!(row.iter().all(|v| v.is_finite() && *v >= 0.0), "{row:?}");
    }
}

#[test]
fn deterministic_nasa_world() {
    let counts = Arc::new(ppa_edge::workload::nasa_synthetic(&NasaTraceConfig {
        minutes: 30,
        ..NasaTraceConfig::default()
    }));
    let run = || {
        let cfg = paper_cluster();
        let mut world = SimWorld::build(&cfg, TaskCosts::default(), 1);
        world.add_generator(Generator::Trace(TraceGen::new(1, counts.clone(), 0.5)));
        hpa_everywhere(&mut world);
        world.run_until(30 * MIN);
        (
            world.app.completed(),
            world.events_processed,
            world.app.stats.fingerprint(),
        )
    };
    assert_eq!(run(), run());
}

//! `ppa-edge` — CLI launcher for the PPA reproduction.
//!
//! ```text
//! ppa-edge experiment <fig6|fig7|fig8|fig9-10|nasa|all> [--minutes N]
//!          [--hours H] [--pretrain-hours H] [--seed S]
//! ppa-edge run [--scaler hpa|ppa] [--model lstm|arma|naive]
//!          [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
//!          [--metric name:target[:src]]... [--behavior rules]
//!          [--minutes N] [--seed S] [--shards S] [--chaos preset]
//! ppa-edge sweep [--minutes N] [--seeds K] [--threads T]
//!          [--topology paper|city-N[xW][:classes]] [--scenarios a,b,..]
//!          [--scalers hpa,ppa-arma,..] [--core calendar|heap]
//!          [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
//!          [--metric name:target[:src]]... [--behavior rules]
//!          [--shards S] [--chaos preset] [--node-classes list]
//!          [--out FILE]
//! ppa-edge info
//! ```
//!
//! Every subcommand and flag is documented in `docs/CLI.md` (repo
//! root); `ppa-edge --help` prints the same usage text.
//!
//! (clap is unavailable in the offline crate set; argument parsing is a
//! small hand-rolled matcher.)

use anyhow::{bail, Context};
use ppa_edge::app::{PriorityMix, SlaConfig, SlaPolicy, TaskCosts};
use ppa_edge::autoscaler::{
    Autoscaler, Hpa, HpaConfig, Hybrid, HybridConfig, MetricSource, MetricSpec, ScalerPolicy,
    ScalerRegistry, ScalingBehavior,
};
use ppa_edge::experiments::{
    self, fig6_trace, fig7_model_comparison, fig8_update_policies, fig9_fig10_key_metric,
    nasa_eval, run_sweep, AutoscalerKind, FigParams, ModelKind, NasaParams, SimWorld,
    SweepConfig,
};
use ppa_edge::forecast::ForecasterKind;
use ppa_edge::report;
use ppa_edge::sim::{MIN, MS};
use ppa_edge::stats::summarize;
use ppa_edge::workload::{
    load_azure_minute_counts, load_minute_counts, Generator, NasaTraceConfig, RandomAccessGen,
    Scenario,
};

/// Minimal flag parser: `--key value` pairs after positional args.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let value = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.push((key.to_string(), value.clone()));
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Every value of a repeatable flag, in order (`--metric cpu:70
    /// --metric req_rate:150`).
    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

const USAGE: &str = "ppa-edge — Proactive Pod Autoscaler reproduction (UCC '21)

USAGE:
  ppa-edge experiment <fig6|fig7|fig8|fig9-10|nasa|all>
           [--minutes N] [--hours H] [--pretrain-hours H] [--seed S]
  ppa-edge run [--scaler hpa|ppa|hybrid] [--model lstm|arma|naive]
           [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
           [--metric name:target[:current|:forecast]]...
           [--behavior rules] [--minutes N] [--seed S] [--shards S]
           [--chaos none|node-outage|flaky-pods|slow-network|full-storm]
           [--sla deadline_ms:retries:backoff_ms[:shed_depth]]
           [--priority-mix c:s:b] [--trace nasa:FILE|azure:FILE]
  ppa-edge sweep [--minutes N] [--seeds K] [--threads T]
           [--topology paper|city-N[xW][:classes]] [--scenarios a,b,..]
           [--scalers hpa,ppa-arma,ppa-naive,hybrid] [--core calendar|heap]
           [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
           [--metric name:target[:current|:forecast]]...
           [--behavior rules] [--shards S] [--out FILE]
           [--chaos preset] [--node-classes small,medium,large]
           [--sla deadline_ms:retries:backoff_ms[:shed_depth]]
           [--priority-mix c:s:b] [--trace nasa:FILE|azure:FILE]
  ppa-edge info
  ppa-edge help | --help | -h

MULTI-METRIC SCALING:
  --metric is repeatable; each spec is name:target (metric names
  cpu|ram|net_in|net_out|req_rate, or an index 0..4) with an optional
  :current|:forecast source (default: forecast under the PPA, and the
  HPA always reads current). Per decision the max desired count across
  metrics wins (K8s HPA combine), e.g.:
    --metric cpu:70 --metric req_rate:150
  --behavior sets the shared scaling-behavior stage, a comma list of
  up-/down- rules: up-window=0s, down-window=5m, up-pods=4/15s,
  up-percent=100/15s, down-select=max|min|disabled, ... ('k8s' as the
  first entry loads the full upstream defaults, later entries override)

EXPERIMENTS (paper figures):
  fig6     scaled NASA trace generation
  fig7     ARMA vs LSTM prediction MSE
  fig8     model-update policies 1/2/3
  fig9-10  key metric: CPU vs request rate
  nasa     the 48 h HPA-vs-PPA evaluation (figs 11-14)
  all      everything above

SWEEP (scenario matrix):
  Fans a (scenario x autoscaler x seed) grid across worker threads and
  writes a JSON report. --topology selects the cluster: 'paper' (Table 2)
  or a generated city, e.g. 'city-50' (50 edge zones x 2 workers) or
  'city-50x4'. Scenarios default to the topology's preset library:
  Table-2 presets (random-access, nasa-trace, diurnal, flash-crowd,
  step-surge, multi-zone-mix) on 'paper'; N-zone composites
  (cityN-diurnal-wave, cityN-flash-mosaic, cityN-step-carpet,
  cityN-rush-hour) on 'city-N'. Autoscalers default to
  hpa,ppa-arma,ppa-naive. --core selects the DES event queue: the fast
  'calendar' bucket queue (default) or the 'heap' reference core —
  results are bit-identical either way. --shards S (run and sweep)
  switches each world onto the sharded engine: zones are split into
  per-zone event cores advancing in conservative lockstep windows
  across S worker threads, and results are bit-identical for any
  S >= 1 (0, the default, keeps the single-queue reference engine).
  City-scale example:
    ppa-edge sweep --topology city-50 --scalers hpa,ppa-arma --seeds 2 --shards 4

FORECASTER ZOO (pure-Rust model axis):
  --forecaster swaps the PPA's prediction model for a zoo member:
  naive | arma | holt-winters (additive-seasonal smoothing) | tcn
  (dilated causal conv, SPSA-fitted) | lstm-rs (pure-Rust LSTM
  inference, no PJRT) | auto:K (online champion–challenger selection
  over the first K of holt-winters, arma, naive, tcn, lstm-rs). Every
  kind is Send, so the whole axis works under --shards and across the
  sweep grid. auto:K shadow-scores every challenger each control tick
  (squared CPU forecast error, streamed), promotes a challenger only
  when it beats the champion by a 10% margin over a 30-tick window
  (hysteresis — no flapping), and reports per-service champions plus
  pooled per-model MSEs in the sweep JSON and report table. Mutually
  exclusive with --model (the paper's axis; the PJRT lstm model stays
  monolith-only — use --forecaster lstm-rs under --shards). Selection
  is deterministic: same cell seed, same champions, any shard count.
  Champion-selection sweep example:
    ppa-edge sweep --topology city-8 --forecaster auto:3 --shards 4

CHAOS (deterministic fault injection):
  --chaos picks a fault-plan preset: none (default), node-outage
  (Poisson node crashes + rejoins), flaky-pods (cold-start latency
  inflation + crash-loops), slow-network (extra edge->cloud delay on
  the Eigen forward path), full-storm (all of the above). Fault
  timings derive from the cell seed on dedicated RNG streams, so a
  faulted run is bit-reproducible across runs, --threads, and
  --shards 1|2|4|8; --chaos none is byte-identical to a build without
  the chaos plane. City workers can be heterogeneous: --node-classes
  small,large cycles hardware classes per zone worker (small =
  1 core/1 GiB, medium = Table-2 worker, large = 4 cores/4 GiB);
  equivalently suffix the topology, e.g. city-8x4:small,large.
  Faulted city sweep example:
    ppa-edge sweep --topology city-8 --node-classes small,large \\
             --chaos full-storm --seeds 2 --shards 4

RESILIENCE (SLA plane + hybrid scaler):
  --sla arms the resilience plane: requests carry a per-attempt
  deadline (ms); a timed-out attempt retries with deterministic
  exponential backoff (base ms, seeded jitter from a dedicated SLA RNG
  stream) until the retry budget is spent, then counts as an SLA
  violation; Batch arrivals are shed while the target queue is deeper
  than shed_depth (default: no shedding). --priority-mix sets the
  Critical:Standard:Batch arrival weights (default 0.1:0.7:0.2; one
  RNG draw per request, so the mix never perturbs the schedule
  shape). Without --sla the plane is a strict no-op — bit-identical
  to a build without it. --scaler hybrid (run) / --scalers ..,hybrid
  (sweep) runs the SLA-guarded hybrid: the proactive PPA baseline
  plus a reactive override that trips on the SLA-violation-rate
  signal or a forecast-error z-spike and releases after consecutive
  clean ticks. Sweeps under --sla add per-class response stats, SLA
  counters, the cost ledger (cost_node_hours, pod_churn) and a
  cost-vs-violation-minutes Pareto table. Faulted SLA example:
    ppa-edge sweep --topology city-8 --chaos full-storm \\
             --sla 500:2:100:64 --scalers ppa-arma,hybrid --shards 4
  --trace replays a request trace on every edge zone instead of the
  preset scenarios: nasa:FILE (one per-minute count per line) or
  azure:FILE (Azure Functions per-minute invocation CSV, summed
  across function rows).

Full flag reference: docs/CLI.md (including the sweep JSON schema).
Artifacts must exist for LSTM experiments: run `make artifacts`.";

/// The repeatable `--metric` flags as a spec set (None when absent).
/// `default_source` follows the scaler: forecast for the PPA, current
/// for the HPA (which reads every spec reactively anyway).
fn metric_flags(
    args: &Args,
    default_source: MetricSource,
) -> anyhow::Result<Option<Vec<MetricSpec>>> {
    let raw = args.get_all("metric");
    if raw.is_empty() {
        return Ok(None);
    }
    raw.iter()
        .map(|s| MetricSpec::parse(s, default_source))
        .collect::<anyhow::Result<Vec<_>>>()
        .map(Some)
}

/// The `--behavior` flag (None when absent); `default_down_window` seeds
/// the unset fields.
fn behavior_flag(
    args: &Args,
    default_down_window: ppa_edge::sim::Time,
) -> anyhow::Result<Option<ScalingBehavior>> {
    args.get("behavior")
        .map(|s| ScalingBehavior::parse(s, default_down_window))
        .transpose()
}

/// `--sla deadline_ms:retries:backoff_ms[:shed_depth]` plus the
/// optional `--priority-mix c:s:b`, as one resilience-plane config
/// (None when `--sla` is absent — the plane stays a strict no-op).
fn sla_flag(args: &Args) -> anyhow::Result<Option<SlaConfig>> {
    let Some(raw) = args.get("sla") else {
        if args.get("priority-mix").is_some() {
            bail!("--priority-mix needs --sla (the resilience plane is off without a policy)");
        }
        return Ok(None);
    };
    let parts: Vec<&str> = raw.split(':').collect();
    if parts.len() < 3 || parts.len() > 4 {
        bail!(
            "--sla must be deadline_ms:retries:backoff_ms[:shed_depth], e.g. 500:2:100:64 \
             (got '{raw}')"
        );
    }
    let deadline_ms: u64 = parts[0]
        .parse()
        .with_context(|| format!("--sla deadline '{}' must be integer ms", parts[0]))?;
    let max_retries: u32 = parts[1]
        .parse()
        .with_context(|| format!("--sla retries '{}' must be an integer", parts[1]))?;
    let backoff_ms: u64 = parts[2]
        .parse()
        .with_context(|| format!("--sla backoff '{}' must be integer ms", parts[2]))?;
    let shed_queue_depth: usize = match parts.get(3) {
        Some(d) => d
            .parse()
            .with_context(|| format!("--sla shed_depth '{d}' must be an integer"))?,
        None => usize::MAX, // no admission control
    };
    if deadline_ms == 0 || backoff_ms == 0 {
        bail!("--sla deadline and backoff must be positive");
    }
    let mut cfg = SlaConfig::new(SlaPolicy {
        deadline: deadline_ms * MS,
        max_retries,
        backoff_base: backoff_ms * MS,
        shed_queue_depth,
    });
    if let Some(mix) = args.get("priority-mix") {
        let w: Vec<f64> = mix
            .split(':')
            .map(|p| p.parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("--priority-mix must be c:s:b numbers (got '{mix}')"))?;
        if w.len() != 3 || w.iter().any(|v| !v.is_finite() || *v < 0.0) || w.iter().sum::<f64>() <= 0.0
        {
            bail!("--priority-mix needs three non-negative weights with a positive sum, e.g. 0.1:0.7:0.2");
        }
        cfg.mix = PriorityMix {
            critical: w[0],
            standard: w[1],
            batch: w[2],
        };
    }
    Ok(Some(cfg))
}

/// `--trace nasa:FILE|azure:FILE` — a per-minute request trace replayed
/// on every edge zone in `zones` (None when the flag is absent).
fn trace_flag(args: &Args, zones: Vec<u32>) -> anyhow::Result<Option<(String, Scenario)>> {
    const ACCEPTED: &str = "accepted trace formats: nasa:<path> (one per-minute count per \
                            line) | azure:<path> (Azure Functions per-minute invocation CSV)";
    let Some(raw) = args.get("trace") else {
        return Ok(None);
    };
    let (format, path) = raw
        .split_once(':')
        .with_context(|| format!("--trace must be <format>:<path>; {ACCEPTED}"))?;
    let counts = match format {
        "nasa" => load_minute_counts(std::path::Path::new(path))?,
        "azure" => load_azure_minute_counts(std::path::Path::new(path))?,
        other => bail!("unknown trace format '{other}'; {ACCEPTED}"),
    };
    let name = format!("{format}-trace");
    let scenario = Scenario::Trace {
        counts: std::sync::Arc::new(counts),
        scale: 1.0,
        zones,
        stagger: 0,
    };
    Ok(Some((name, scenario)))
}

/// One-block SLA + cost-ledger tally for `run` (both engines).
fn print_sla_summary(s: &ppa_edge::app::SlaSummary, cost_node_hours: f64, pod_churn: u64) {
    let c = &s.counters;
    println!(
        "  SLA: {} timeouts, {} retries, {} violations ({} violation-minute(s)), {} shed",
        c.timeouts, c.retries, c.violations, c.violation_minutes, c.shed
    );
    let classes = ["critical", "standard", "batch"];
    let per_class: Vec<String> = classes
        .iter()
        .zip(s.class_stats.iter())
        .map(|(name, st)| {
            if st.n() == 0 {
                format!("{name} -")
            } else {
                format!("{name} {:.3}s (n={})", st.mean(), st.n())
            }
        })
        .collect();
    println!("  per-class resp: {}", per_class.join(", "));
    println!("  cost: {cost_node_hours:.3} node-hours billed, {pod_churn} pod(s) spawned");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    // `--help`/`-h` anywhere prints usage (before flag parsing, which
    // would otherwise demand a value for `--help`).
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.positional.first().map(String::as_str) {
        Some("experiment") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("info") => cmd_info(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_info() -> anyhow::Result<()> {
    println!("ppa-edge {}", env!("CARGO_PKG_VERSION"));
    match ppa_edge::runtime::find_artifacts_dir() {
        Some(dir) => {
            let rt = ppa_edge::runtime::LstmRuntime::load(&dir)?;
            let m = rt.manifest();
            println!("artifacts: {}", dir.display());
            println!(
                "model: LSTM({}) in={} out={} seq_len={} batch={} params={}",
                m.hidden_dim,
                m.input_dim,
                m.output_dim,
                m.seq_len,
                m.batch,
                m.param_count()
            );
        }
        None => println!("artifacts: NOT BUILT (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let params = FigParams {
        minutes: args.get_u64("minutes", 200)?,
        pretrain_hours: args.get_f64("pretrain-hours", 10.0)?,
        seed: args.get_u64("seed", 2021)?,
    };
    let nasa_params = NasaParams {
        hours: args.get_f64("hours", 48.0)?,
        pretrain_hours: params.pretrain_hours,
        seed: params.seed,
        trace: NasaTraceConfig::default(),
    };

    let run_fig6 = || -> anyhow::Result<()> {
        let counts = fig6_trace(&NasaTraceConfig::default())?;
        let s = summarize(&counts);
        println!(
            "\n== Fig 6 — scaled NASA trace ==\n  {} minutes, mean {:.1} req/min, peak {:.0}, csv: target/experiments/fig6_nasa_trace.csv",
            counts.len(),
            s.mean,
            s.max
        );
        Ok(())
    };

    match which {
        "fig6" => run_fig6()?,
        "fig7" => report::print_fig7(&fig7_model_comparison(&params)?),
        "fig8" => report::print_fig8(&fig8_update_policies(&params)?),
        "fig9-10" | "fig9" | "fig10" => {
            report::print_fig9_10(&fig9_fig10_key_metric(&params)?)
        }
        "nasa" | "fig11" | "fig12" | "fig13" | "fig14" => {
            report::print_nasa_eval(&nasa_eval(&nasa_params)?)
        }
        "all" => {
            run_fig6()?;
            report::print_fig7(&fig7_model_comparison(&params)?);
            report::print_fig8(&fig8_update_policies(&params)?);
            report::print_fig9_10(&fig9_fig10_key_metric(&params)?);
            report::print_nasa_eval(&nasa_eval(&nasa_params)?);
        }
        other => bail!("unknown experiment '{other}'\n\n{USAGE}"),
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let minutes = args.get_u64("minutes", 30)?;
    let n_seeds = args.get_u64("seeds", 4)?;
    let threads = args.get_u64("threads", 0)? as usize;
    let out = args.get("out").unwrap_or("target/experiments/sweep.json");
    let mut topology =
        ppa_edge::config::Topology::parse(args.get("topology").unwrap_or("paper"))?;
    // `--node-classes small,large` is sugar for the `city-NxW:small,large`
    // topology suffix; it overrides any suffix already present.
    if let Some(list) = args.get("node-classes") {
        let parsed = ppa_edge::config::ClassMix::parse(list)?;
        match &mut topology {
            ppa_edge::config::Topology::EdgeCity { mix, .. } => *mix = parsed,
            _ => bail!("--node-classes needs a city topology (e.g. --topology city-8x4)"),
        }
    }
    let core = ppa_edge::sim::CoreKind::parse(args.get("core").unwrap_or("calendar"))?;
    let shards = args.get_u64("shards", 0)? as usize;
    let chaos = ppa_edge::config::chaos_preset(args.get("chaos").unwrap_or("none"))?;

    let sla = sla_flag(args)?;

    // The preset library follows the topology: Table-2 scenarios on
    // `paper`, generated N-zone `cityN-*` composites on `city-N[xW]`.
    let presets = topology.scenario_presets();
    let scenarios = match args.get("scenarios") {
        None => presets,
        Some(list) => {
            let names: Vec<String> = presets.iter().map(|(n, _)| n.clone()).collect();
            let mut picked = Vec::new();
            for name in list.split(',') {
                let name = name.trim();
                let found = presets
                    .iter()
                    .find(|(n, _)| n == name)
                    .with_context(|| {
                        format!("unknown scenario '{name}' (available: {})", names.join(", "))
                    })?;
                picked.push(found.clone());
            }
            picked
        }
    };
    // `--trace` replaces the scenario axis with one replayed trace on
    // every edge zone of the chosen topology.
    let edge_zones: Vec<u32> =
        topology.cluster().deployments.iter().filter_map(|d| d.zone).collect();
    let scenarios = match trace_flag(args, edge_zones)? {
        Some((name, scenario)) => {
            if args.get("scenarios").is_some() {
                bail!("--trace and --scenarios are mutually exclusive");
            }
            vec![(name, scenario)]
        }
        None => scenarios,
    };
    // `--forecaster` swaps every PPA cell's model for a zoo member
    // (both PPA kinds honour it; the HPA ignores it). With the flag set
    // and no explicit `--scalers`, the grid drops to hpa + ppa-arma —
    // the two PPA kinds would otherwise run identical cells.
    let forecaster = args.get("forecaster").map(ForecasterKind::parse).transpose()?;
    let scalers = match args.get("scalers") {
        None if forecaster.is_some() => vec![AutoscalerKind::Hpa, AutoscalerKind::PpaArma],
        None => vec![
            AutoscalerKind::Hpa,
            AutoscalerKind::PpaArma,
            AutoscalerKind::PpaNaive,
        ],
        Some(list) => list
            .split(',')
            .map(|s| AutoscalerKind::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    // `--metric`/`--behavior`/`--forecaster` build a uniform fleet
    // policy for every service of every cell (heterogeneous registries
    // are API-level: see `ScalerRegistry::with_policy`). Unset
    // `--behavior` fields default to the stock K8s values (5-min down
    // window) so an up-rule-only flag cannot silently weaken the HPA
    // baseline's stabilization; without the flag each scaler kind keeps
    // its own default (HPA 5 min, PPA 2 min).
    let specs = metric_flags(args, MetricSource::Forecast)?;
    let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
    let fleet = if specs.is_some() || behavior.is_some() || forecaster.is_some() {
        Some(ScalerRegistry::uniform(ScalerPolicy {
            specs: specs.unwrap_or_else(|| ScalerPolicy::default().specs),
            behavior,
            forecaster,
        }))
    } else {
        None
    };

    let cfg = SweepConfig {
        topology,
        scenarios,
        scalers,
        seeds: (0..n_seeds).map(|i| 1000 + i).collect(),
        minutes,
        threads,
        core,
        fleet,
        shards,
        chaos,
        sla,
    };

    println!(
        "sweeping {} scenarios x {} autoscalers x {} seeds on topology {}, \
         {} sim-minutes per cell (chaos: {}, sla: {})...",
        cfg.scenarios.len(),
        cfg.scalers.len(),
        cfg.seeds.len(),
        cfg.topology.label(),
        minutes,
        cfg.chaos.label(),
        cfg.sla.as_ref().map_or_else(|| "none".to_string(), SlaConfig::label)
    );
    let result = run_sweep(&cfg)?;
    report::print_sweep(&result);
    result.write_json(std::path::Path::new(out))?;
    println!("json report: {out}");
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let minutes = args.get_u64("minutes", 30)?;
    let seed = args.get_u64("seed", 7)?;
    let scaler = args.get("scaler").unwrap_or("ppa");
    // Default to ARMA: it works in every build. LSTM additionally needs
    // the `pjrt` cargo feature and `make artifacts`.
    let model = ModelKind::parse(args.get("model").unwrap_or("arma"))?;
    // `--forecaster` (the pure-Rust zoo axis) replaces `--model` (the
    // paper's axis) wholesale — the two would pick the PPA model twice.
    let forecaster = args.get("forecaster").map(ForecasterKind::parse).transpose()?;
    if forecaster.is_some() {
        if args.get("model").is_some() {
            bail!(
                "--forecaster and --model are mutually exclusive: --model picks the \
                 paper's lstm|arma|naive stack, --forecaster a pure-Rust zoo member"
            );
        }
        if scaler != "ppa" && scaler != "hybrid" {
            bail!("--forecaster needs --scaler ppa|hybrid (the HPA runs no prediction model)");
        }
    }
    let shards = args.get_u64("shards", 0)? as usize;
    let chaos = ppa_edge::config::chaos_preset(args.get("chaos").unwrap_or("none"))?;
    let sla = sla_flag(args)?;
    // The paper run drives zones 1 and 2 with random-access clients
    // unless `--trace` replays a file on them instead.
    let generators = match trace_flag(args, vec![1, 2])? {
        Some((name, scenario)) => {
            println!("replaying {name} on zones 1-2");
            scenario.build_generators()
        }
        None => vec![
            Generator::RandomAccess(RandomAccessGen::new(1)),
            Generator::RandomAccess(RandomAccessGen::new(2)),
        ],
    };
    if shards >= 1 {
        return cmd_run_sharded(
            args,
            minutes,
            seed,
            scaler,
            model,
            forecaster,
            shards,
            &chaos,
            sla.as_ref(),
            generators,
        );
    }

    let cfg = ppa_edge::config::paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), seed);
    for gen in generators {
        world.add_generator(gen);
    }
    let n_services = world.app.services.len();

    match scaler {
        "hpa" => {
            let specs = metric_flags(args, MetricSource::Current)?;
            let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
            for svc in 0..n_services {
                let mut cfg = HpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                world.add_scaler(Box::new(Hpa::new(cfg)), svc);
            }
        }
        "ppa" if forecaster.is_some() => {
            // Zoo models train online from the live history file (the
            // update loop fits them mid-run) — no pretraining pass.
            let kind = forecaster.unwrap_or(ForecasterKind::Naive);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            for svc in 0..n_services {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                let ppa = ppa_edge::autoscaler::Ppa::new(cfg, kind.build(seed));
                world.add_scaler(Box::new(ppa), svc);
            }
        }
        "hybrid" => {
            // The SLA-guarded hybrid: proactive baseline (zoo model,
            // ARMA by default) + reactive override. Trains online like
            // the zoo PPAs — no pretraining pass.
            let kind = forecaster.unwrap_or(ForecasterKind::Arma);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            for svc in 0..n_services {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                let hybrid = Hybrid::new(
                    HybridConfig {
                        ppa: cfg,
                        ..HybridConfig::default()
                    },
                    kind.build(seed),
                );
                world.add_scaler(Box::new(hybrid), svc);
            }
        }
        "ppa" => {
            let runtime = if model == ModelKind::Lstm {
                Some(experiments::try_runtime().context(
                    "LSTM needs the PJRT runtime: add the `xla` dependency, \
                     build with `--features pjrt`, and run `make artifacts` \
                     (see rust/Cargo.toml). arma/naive models need neither.",
                )?)
            } else {
                None
            };
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            println!("collecting pretraining data (1 h sim)...");
            let (hist, _) = experiments::pretrain_histories(1.0, 20, seed);
            for svc in 0..n_services {
                let pre = if svc + 1 == n_services {
                    hist.last().unwrap()
                } else {
                    &hist[0]
                };
                let forecaster =
                    experiments::make_forecaster(model, runtime.as_ref(), pre, seed as u32)?;
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                let ppa = ppa_edge::autoscaler::Ppa::new(cfg, forecaster);
                world.add_scaler(Box::new(ppa), svc);
            }
        }
        other => bail!("unknown scaler '{other}' (hpa|ppa|hybrid)"),
    }

    world.install_chaos(&chaos, seed, minutes * MIN);
    if let Some(cfg) = &sla {
        world.install_sla(cfg, seed);
    }
    let model_label = match forecaster {
        Some(kind) => kind.name(),
        None => model.name().to_string(),
    };
    println!(
        "running {minutes} simulated minutes with {scaler} ({model_label}), chaos: {}, sla: {}...",
        chaos.label(),
        sla.as_ref().map_or_else(|| "none".to_string(), SlaConfig::label)
    );
    let wall = ppa_edge::util::wallclock();
    let events = world.run_until(minutes * MIN);
    let elapsed = wall.elapsed();

    // Response stats stream in constant memory (Welford moments +
    // log-histogram percentiles) — no per-request log is retained.
    let stats = &world.app.stats;
    let sort = stats.sort.summary();
    let eigen = stats.eigen.summary();
    let rirs: Vec<f64> = world.rir_log.iter().map(|s| s.rir).collect();
    let rir = summarize(&rirs);
    println!(
        "done: {events} events in {:.2}s ({:.0}x real time)",
        elapsed.as_secs_f64(),
        minutes as f64 * 60.0 / elapsed.as_secs_f64()
    );
    println!(
        "  sort  resp: {:.4} ± {:.4} s (n={}, p95 ≈ {:.4})",
        sort.mean,
        sort.std,
        sort.n,
        stats.sort.quantile(95.0)
    );
    println!(
        "  eigen resp: {:.3} ± {:.3} s (n={}, p95 ≈ {:.3})",
        eigen.mean,
        eigen.std,
        eigen.n,
        stats.eigen.quantile(95.0)
    );
    println!("  RIR: {:.3} ± {:.3}", rir.mean, rir.std);
    for (svc, binding) in world.scalers.iter().enumerate() {
        let ppa = binding.autoscaler.as_any().downcast_ref::<ppa_edge::autoscaler::Ppa>();
        if let Some(selection) = ppa.and_then(|p| p.selection()) {
            print_selection(svc, &selection);
        }
        if let Some(h) = binding.autoscaler.as_any().downcast_ref::<Hybrid>() {
            println!(
                "  service {svc} hybrid: {} override trip(s), {} overridden tick(s)",
                h.trips(),
                h.override_ticks()
            );
        }
    }
    if !chaos.is_empty() {
        print_chaos_summary(&world.chaos_summary(minutes * MIN));
    }
    if world.app.sla_active() {
        print_sla_summary(
            &world.sla_summary(),
            world.cost_node_hours(minutes * MIN),
            world.cluster.pod_churn,
        );
    }
    Ok(())
}

/// One-line champion–challenger tally for one selecting service.
fn print_selection(svc: usize, s: &ppa_edge::forecast::SelectionSummary) {
    let scores: Vec<String> = s
        .models
        .iter()
        .map(|m| match m.mse {
            Some(mse) => format!("{} {mse:.3}", m.name),
            None => format!("{} -", m.name),
        })
        .collect();
    println!(
        "  service {svc} champion: {} ({} promotion(s); shadow MSE: {})",
        s.champion,
        s.promotions.len(),
        scores.join(", ")
    );
}

/// One-line fault tally for faulted runs (both engines).
fn print_chaos_summary(c: &ppa_edge::cluster::ChaosCounters) {
    println!(
        "  faults: {} crashes / {} rejoins, {} pods killed, {} rescheduled, \
         {} crash-loops, {:.1}s downtime",
        c.crashes,
        c.rejoins,
        c.pods_killed,
        c.pods_rescheduled,
        c.crash_loops,
        ppa_edge::sim::to_secs(c.downtime)
    );
}

/// `run --shards S`: the same paper-topology run on the sharded engine
/// (one event core per zone, conservative lockstep windows). Results
/// are bit-identical for any `S >= 1` but intentionally *not* to the
/// monolith engine (different RNG stream layout — see `sim::shard`).
#[allow(clippy::too_many_arguments)]
fn cmd_run_sharded(
    args: &Args,
    minutes: u64,
    seed: u64,
    scaler: &str,
    model: ModelKind,
    forecaster: Option<ForecasterKind>,
    shards: usize,
    chaos: &ppa_edge::cluster::FaultPlan,
    sla: Option<&SlaConfig>,
    generators: Vec<Generator>,
) -> anyhow::Result<()> {
    use ppa_edge::sim::{run_sharded, ShardSpec};

    let cfg = ppa_edge::config::paper_cluster();
    // World order == service order: edge zones in config order, then the
    // cloud pool; the scaler factory sees the global world index.
    let n_services = cfg.deployments.len();
    let spec = ShardSpec {
        shards,
        core: ppa_edge::sim::CoreKind::parse(args.get("core").unwrap_or("calendar"))?,
        seed,
        costs: TaskCosts::default(),
        end: minutes * MIN,
        record_decisions: false,
        chaos: *chaos,
        sla: sla.copied(),
    };

    let model_label = match forecaster {
        Some(kind) => kind.name(),
        None => model.name().to_string(),
    };
    println!(
        "running {minutes} simulated minutes with {scaler} ({model_label}) on {shards} \
         shard(s), chaos: {}, sla: {}...",
        chaos.label(),
        sla.map_or_else(|| "none".to_string(), SlaConfig::label)
    );
    let wall = ppa_edge::util::wallclock();
    let run = match scaler {
        "hpa" => {
            let specs = metric_flags(args, MetricSource::Current)?;
            let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
            let factory = |_svc: usize| -> Box<dyn Autoscaler> {
                let mut cfg = HpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(Hpa::new(cfg))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        "ppa" if forecaster.is_some() => {
            // The whole zoo axis is `Send`, so learned models (tcn,
            // lstm-rs, auto:K) build directly on the worker threads —
            // `ForecasterKind::build` is pure, so every shard layout
            // gets a bit-identical model.
            let kind = forecaster.unwrap_or(ForecasterKind::Naive);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            let factory = |_svc: usize| -> Box<dyn Autoscaler> {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(ppa_edge::autoscaler::Ppa::new(cfg, kind.build(seed)))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        "ppa" => {
            if model == ModelKind::Lstm {
                bail!(
                    "--shards does not support --model lstm: the PJRT runtime is \
                     shared single-threaded state; use --forecaster lstm-rs (the \
                     pure-Rust LSTM), --model arma|naive, or drop --shards"
                );
            }
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            println!("collecting pretraining data (1 h sim)...");
            let (hist, _) = experiments::pretrain_histories(1.0, 20, seed);
            // Fail fast on a bad seed model here, on the main thread —
            // the per-world factory below can then only repeat a fit
            // that already succeeded.
            experiments::make_forecaster(model, None, &hist[0], seed as u32)
                .context("fitting the edge seed model")?;
            experiments::make_forecaster(model, None, hist.last().unwrap(), seed as u32)
                .context("fitting the cloud seed model")?;
            let factory = |svc: usize| -> Box<dyn Autoscaler> {
                let pre = if svc + 1 == n_services {
                    hist.last().unwrap()
                } else {
                    &hist[0]
                };
                let forecaster = experiments::make_forecaster(model, None, pre, seed as u32)
                    .expect("seed-model fit succeeded in the up-front check");
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(ppa_edge::autoscaler::Ppa::new(cfg, forecaster))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        "hybrid" => {
            // Proactive PPA baseline with the reactive SLA guardrail; the
            // forecaster axis is shared with the zoo-ppa arm above.
            let kind = forecaster.unwrap_or(ForecasterKind::Arma);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            let factory = |_svc: usize| -> Box<dyn Autoscaler> {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(Hybrid::new(
                    HybridConfig {
                        ppa: cfg,
                        ..HybridConfig::default()
                    },
                    kind.build(seed),
                ))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        other => bail!("unknown scaler '{other}' (hpa|ppa|hybrid)"),
    };
    let elapsed = wall.elapsed();

    let sort_stats = run.sort_stats();
    let eigen_stats = run.eigen_stats();
    let sort = sort_stats.summary();
    let eigen = eigen_stats.summary();
    let rirs: Vec<f64> = run.rir_log().iter().map(|s| s.rir).collect();
    let rir = summarize(&rirs);
    println!(
        "done: {} events in {:.2}s ({:.0}x real time)",
        run.events(),
        elapsed.as_secs_f64(),
        minutes as f64 * 60.0 / elapsed.as_secs_f64()
    );
    println!(
        "  sort  resp: {:.4} ± {:.4} s (n={}, p95 ≈ {:.4})",
        sort.mean,
        sort.std,
        sort.n,
        sort_stats.quantile(95.0)
    );
    println!(
        "  eigen resp: {:.3} ± {:.3} s (n={}, p95 ≈ {:.3})",
        eigen.mean,
        eigen.std,
        eigen.n,
        eigen_stats.quantile(95.0)
    );
    println!("  RIR: {:.3} ± {:.3}", rir.mean, rir.std);
    for outcome in &run.outcomes {
        if let Some(selection) = &outcome.selection {
            print_selection(outcome.world, selection);
        }
    }
    if !chaos.is_empty() {
        print_chaos_summary(&run.chaos_counters());
    }
    if sla.is_some() {
        print_sla_summary(&run.sla_summary(), run.cost_node_hours(), run.pod_churn());
    }
    if let (Some(trips), Some(ticks)) = (run.hybrid_trips(), run.hybrid_override_ticks()) {
        println!("  hybrid: {trips} override trip(s), {ticks} overridden tick(s)");
    }
    println!("  fingerprint: identical for any --shards >= 1 at this seed");
    Ok(())
}

//! `artifacts/manifest.json` — shapes and optimizer constants emitted by
//! the AOT pipeline so the rust runtime can size buffers without parsing
//! HLO.

use crate::util::json::Json;
use anyhow::{bail, Context};
use std::path::Path;

/// Adam constants baked into the train artifacts (informational on the
/// rust side; the artifact already contains them).
#[derive(Debug, Clone, PartialEq)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub input_dim: usize,
    pub hidden_dim: usize,
    pub output_dim: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub epoch_batches: usize,
    pub adam: AdamConfig,
    /// `(name, shape)` in canonical flat parameter order (w, b, wd, bd).
    pub param_shapes: Vec<(String, Vec<usize>)>,
}

/// Canonical parameter order — must match `model.PARAM_NAMES` in python.
const PARAM_ORDER: [&str; 4] = ["w", "b", "wd", "bd"];

impl Manifest {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> crate::Result<Self> {
        let doc = Json::parse(text)?;
        let usize_field = |key: &str| -> crate::Result<usize> {
            doc.get(key)
                .as_usize()
                .with_context(|| format!("manifest missing integer field '{key}'"))
        };
        let adam = AdamConfig {
            lr: doc.get_path(&["adam", "lr"]).as_f64().context("adam.lr")?,
            beta1: doc.get_path(&["adam", "beta1"]).as_f64().context("adam.beta1")?,
            beta2: doc.get_path(&["adam", "beta2"]).as_f64().context("adam.beta2")?,
            eps: doc.get_path(&["adam", "eps"]).as_f64().context("adam.eps")?,
        };
        let shapes_obj = doc
            .get("param_shapes")
            .as_obj()
            .context("manifest missing param_shapes")?;
        let mut param_shapes = Vec::with_capacity(PARAM_ORDER.len());
        for name in PARAM_ORDER {
            let arr = shapes_obj
                .get(name)
                .and_then(|v| v.as_arr())
                .with_context(|| format!("param_shapes missing '{name}'"))?;
            let shape: Option<Vec<usize>> = arr.iter().map(|d| d.as_usize()).collect();
            let shape = shape.with_context(|| format!("bad shape for '{name}'"))?;
            param_shapes.push((name.to_string(), shape));
        }
        let m = Manifest {
            input_dim: usize_field("input_dim")?,
            hidden_dim: usize_field("hidden_dim")?,
            output_dim: usize_field("output_dim")?,
            seq_len: usize_field("seq_len")?,
            batch: usize_field("batch")?,
            epoch_batches: usize_field("epoch_batches")?,
            adam,
            param_shapes,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> crate::Result<()> {
        if self.input_dim == 0 || self.hidden_dim == 0 || self.output_dim == 0 {
            bail!("manifest has zero model dimension");
        }
        if self.seq_len == 0 || self.batch == 0 || self.epoch_batches == 0 {
            bail!("manifest has zero batch geometry");
        }
        let w = &self.param_shapes[0].1;
        if w != &[self.input_dim + self.hidden_dim, 4 * self.hidden_dim] {
            bail!("w shape {:?} inconsistent with dims", w);
        }
        Ok(())
    }

    /// Total parameter count (for reporting / VMEM estimates).
    pub fn param_count(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "input_dim": 5, "hidden_dim": 50, "output_dim": 5,
      "seq_len": 8, "batch": 32, "epoch_batches": 16,
      "adam": {"lr": 0.001, "beta1": 0.9, "beta2": 0.999, "eps": 1e-08},
      "param_shapes": {"w": [55, 200], "b": [200], "wd": [50, 5], "bd": [5]},
      "artifacts": ["lstm_init.hlo.txt"]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hidden_dim, 50);
        assert_eq!(m.param_shapes[0], ("w".to_string(), vec![55, 200]));
        assert_eq!(m.param_count(), 55 * 200 + 200 + 250 + 5);
        assert!((m.adam.lr - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn rejects_inconsistent_w_shape() {
        let bad = SAMPLE.replace("[55, 200]", "[54, 200]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_field() {
        let bad = SAMPLE.replace("\"seq_len\": 8,", "");
        assert!(Manifest::parse(&bad).is_err());
    }
}

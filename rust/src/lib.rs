//! # ppa-edge — Proactive Pod Autoscaler for edge Kubernetes
//!
//! Full-system reproduction of *"Proactive Autoscaling for Edge Computing
//! Systems with Kubernetes"* (Ju, Singh, Toor — UCC '21 Companion) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination system: a deterministic
//!   discrete-event Kubernetes cluster simulator ([`cluster`], [`sim`]),
//!   a Prometheus-style metrics pipeline ([`metrics`]), the example
//!   two-tier edge application ([`app`]), workload generators
//!   ([`workload`]), and the paper's contribution — the proactive pod
//!   autoscaler ([`autoscaler::ppa`]) next to the reactive HPA baseline
//!   ([`autoscaler::hpa`]).
//! * **L2/L1 (build-time python)** — the LSTM forecaster (Pallas kernel +
//!   JAX model) AOT-lowered to HLO text; loaded and executed from rust via
//!   PJRT by [`runtime`]. Python is never on the control path.
//!
//! See `DESIGN.md` (repo root) for the full module inventory, the
//! per-figure experiment index, and the scenario-sweep subsystem; the
//! experiment harnesses themselves print paper-vs-measured rows (run
//! `ppa-edge experiment all`).

pub mod app;
pub mod autoscaler;
pub mod cluster;
pub mod config;
pub mod experiments;
pub mod forecast;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

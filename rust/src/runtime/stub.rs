//! Offline stand-in for the PJRT runtime (built when the `pjrt` feature
//! is off — the default, since the offline crate set has no `xla`).
//!
//! The API mirrors the `pjrt` module's `LstmRuntime` exactly (that
//! module only exists behind the `pjrt` feature, so no doc link) so
//! every caller typechecks; [`LstmRuntime::load`] always fails, which makes
//! `experiments::try_runtime()` return `None` and every LSTM experiment
//! take its documented "artifacts not built" skip path.

use super::{AdamState, LstmParams, Manifest};
use anyhow::bail;
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` cargo feature (xla bindings absent)";

/// Stub runtime — cannot be constructed; exists so LSTM code paths
/// compile without the XLA bindings.
pub struct LstmRuntime {
    manifest: Manifest,
}

impl LstmRuntime {
    /// Always fails in the stub build.
    pub fn load(_dir: &Path) -> crate::Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    /// Always fails in the stub build.
    pub fn load_default() -> crate::Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn init(&self, _seed: u32) -> crate::Result<LstmParams> {
        bail!("{UNAVAILABLE}")
    }

    pub fn predict(&self, _params: &LstmParams, _window: &[f32]) -> crate::Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn train_step(
        &self,
        _params: &mut LstmParams,
        _opt: &mut AdamState,
        _xb: &[f32],
        _yb: &[f32],
    ) -> crate::Result<f32> {
        bail!("{UNAVAILABLE}")
    }

    pub fn train_epoch(
        &self,
        _params: &mut LstmParams,
        _opt: &mut AdamState,
        _xs: &[f32],
        _ys: &[f32],
    ) -> crate::Result<f32> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_gracefully() {
        let err = LstmRuntime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
        assert!(LstmRuntime::load_default().is_err());
    }
}

//! Additive Holt–Winters (triple exponential smoothing) — the cheap,
//! strong seasonal baseline in the forecaster zoo.
//!
//! One independent (level, trend, seasonal[..]) state per protocol
//! metric, matching the §4.2.2 protocol's "predict all input
//! variables". The model is *online*: it folds every observed vector
//! into its smoothing state via [`Forecaster::observe`], so the
//! periodic `retrain` call is a no-op under `KeepSeed`/`FineTune` and a
//! deterministic replay of the history file under `RetrainScratch`.
//!
//! The first `season` observations are buffered as a warm-up; the state
//! is then initialized (level = warm-up mean, trend = 0, seasonal =
//! deviations from the mean) and predictions begin. Before warm-up
//! completes `predict` returns `None` — Algorithm 1's robust fallback
//! covers the gap.

use super::{Forecaster, UpdatePolicy};
use crate::metrics::METRIC_DIM;

/// Default season length in control-loop ticks: 30 ticks ≙ 10 minutes
/// of 20-second loops, the cadence of the synthetic diurnal bursts.
pub const DEFAULT_SEASON: usize = 30;

/// Smoothing state, one slot per protocol metric.
#[derive(Debug, Clone)]
struct HwState {
    level: [f64; METRIC_DIM],
    trend: [f64; METRIC_DIM],
    /// `season` rows of additive seasonal offsets.
    seasonal: Vec<[f64; METRIC_DIM]>,
    /// Count of smoothed observations since init (phase pointer).
    steps: usize,
}

/// Additive-seasonal Holt–Winters forecaster.
#[derive(Debug, Clone)]
pub struct HoltWintersForecaster {
    name: String,
    season: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
    warmup: Vec<[f64; METRIC_DIM]>,
    state: Option<HwState>,
}

impl Default for HoltWintersForecaster {
    fn default() -> Self {
        HoltWintersForecaster::new(DEFAULT_SEASON)
    }
}

impl HoltWintersForecaster {
    /// Standard smoothing weights: responsive level/seasonal, sluggish
    /// trend (edge metrics are bursty; an eager trend term overshoots).
    pub fn new(season: usize) -> Self {
        let season = season.max(2);
        HoltWintersForecaster {
            name: format!("holt-winters({season})"),
            season,
            alpha: 0.3,
            beta: 0.05,
            gamma: 0.3,
            warmup: Vec::with_capacity(season),
            state: None,
        }
    }

    /// Whether warm-up has completed and predictions are available.
    pub fn is_initialized(&self) -> bool {
        self.state.is_some()
    }

    /// Fold one observed vector into the model (warm-up buffering, then
    /// one smoothing step per call).
    fn ingest(&mut self, row: &[f64; METRIC_DIM]) {
        match &mut self.state {
            None => {
                self.warmup.push(*row);
                if self.warmup.len() == self.season {
                    let n = self.season as f64;
                    let mut level = [0.0; METRIC_DIM];
                    for r in &self.warmup {
                        for (l, x) in level.iter_mut().zip(r) {
                            *l += x / n;
                        }
                    }
                    let seasonal = self
                        .warmup
                        .iter()
                        .map(|r| {
                            let mut s = [0.0; METRIC_DIM];
                            for i in 0..METRIC_DIM {
                                s[i] = r[i] - level[i];
                            }
                            s
                        })
                        .collect();
                    self.state = Some(HwState {
                        level,
                        trend: [0.0; METRIC_DIM],
                        seasonal,
                        steps: 0,
                    });
                    self.warmup.clear();
                }
            }
            Some(state) => {
                let phase = state.steps % self.season;
                for i in 0..METRIC_DIM {
                    let y = row[i];
                    let s = state.seasonal[phase][i];
                    let prev_level = state.level[i];
                    state.level[i] = self.alpha * (y - s)
                        + (1.0 - self.alpha) * (prev_level + state.trend[i]);
                    state.trend[i] = self.beta * (state.level[i] - prev_level)
                        + (1.0 - self.beta) * state.trend[i];
                    state.seasonal[phase][i] =
                        self.gamma * (y - state.level[i]) + (1.0 - self.gamma) * s;
                }
                state.steps += 1;
            }
        }
    }
}

impl Forecaster for HoltWintersForecaster {
    fn name(&self) -> &str {
        &self.name
    }

    /// One-step-ahead forecast from the smoothing state (the history
    /// slice is ignored: the state already folds every observed row).
    /// Metrics are non-negative, so forecasts clamp at zero.
    fn predict(&mut self, _history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let state = self.state.as_ref()?;
        let phase = state.steps % self.season;
        let mut out = [0.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            out[i] = (state.level[i] + state.trend[i] + state.seasonal[phase][i]).max(0.0);
        }
        Some(out)
    }

    /// `KeepSeed`/`FineTune`: no-op (the state is already current —
    /// every tick was ingested via `observe`). `RetrainScratch`: reset
    /// and deterministically replay the history file.
    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()> {
        if policy == UpdatePolicy::RetrainScratch {
            self.state = None;
            self.warmup.clear();
            for row in history {
                self.ingest(row);
            }
        }
        Ok(())
    }

    fn observe(&mut self, actual: &[f64; METRIC_DIM]) {
        self.ingest(actual);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::M_CPU;
    use crate::util::rng::Pcg64;

    /// A noisy square wave on the CPU component, period `season`.
    fn square_wave(season: usize, n: usize, seed: u64) -> Vec<[f64; METRIC_DIM]> {
        let mut rng = Pcg64::new(seed, 5);
        (0..n)
            .map(|t| {
                let base = if (t % season) < season / 2 { 20.0 } else { 80.0 };
                let mut row = [0.0; METRIC_DIM];
                for slot in &mut row {
                    *slot = (base + rng.normal_ms(0.0, 1.0)).max(0.0);
                }
                row
            })
            .collect()
    }

    /// Walk-forward one-step MSE on the CPU component, scored after
    /// `burn_in` ticks.
    fn walk_forward_mse(
        f: &mut dyn Forecaster,
        series: &[[f64; METRIC_DIM]],
        burn_in: usize,
    ) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (t, actual) in series.iter().enumerate() {
            f.observe(actual);
            if let Some(p) = f.predict(&series[..=t]) {
                if t + 1 < series.len() && t + 1 >= burn_in {
                    let e = p[M_CPU] - series[t + 1][M_CPU];
                    sum += e * e;
                    n += 1;
                }
            }
        }
        assert!(n > 0, "no scored predictions");
        sum / n as f64
    }

    #[test]
    fn warms_up_then_predicts() {
        let mut hw = HoltWintersForecaster::new(4);
        let rows = square_wave(4, 3, 1);
        for r in &rows {
            hw.observe(r);
        }
        assert!(!hw.is_initialized());
        assert_eq!(hw.predict(&rows), None, "still warming up");
        hw.observe(&[50.0; METRIC_DIM]);
        assert!(hw.is_initialized());
        let p = hw.predict(&rows).expect("initialized");
        assert!(p.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn beats_naive_on_seasonal_series_multi_seed() {
        // The satellite battery's core claim: on a diurnal square wave
        // the seasonal model beats last-value persistence, which pays a
        // huge penalty at every phase transition — across seeds.
        let season = 20;
        for seed in [11, 12, 13] {
            let series = square_wave(season, 12 * season, seed);
            let mut hw = HoltWintersForecaster::new(season);
            let mut naive = crate::forecast::NaiveForecaster;
            let mse_hw = walk_forward_mse(&mut hw, &series, 4 * season);
            let mse_naive = walk_forward_mse(&mut naive, &series, 4 * season);
            assert!(
                mse_hw < mse_naive,
                "seed {seed}: hw {mse_hw} !< naive {mse_naive}"
            );
        }
    }

    #[test]
    fn retrain_scratch_replay_matches_online_ingest() {
        let series = square_wave(6, 40, 3);
        let mut online = HoltWintersForecaster::new(6);
        for r in &series {
            online.observe(r);
        }
        let mut replayed = HoltWintersForecaster::new(6);
        replayed
            .retrain(&series, UpdatePolicy::RetrainScratch)
            .expect("replay is infallible");
        assert_eq!(online.predict(&series), replayed.predict(&series));
    }

    #[test]
    fn keep_seed_and_fine_tune_are_noops() {
        let series = square_wave(6, 20, 4);
        let mut hw = HoltWintersForecaster::new(6);
        for r in &series {
            hw.observe(r);
        }
        let before = hw.predict(&series);
        hw.retrain(&series, UpdatePolicy::KeepSeed).expect("noop");
        hw.retrain(&series, UpdatePolicy::FineTune).expect("noop");
        assert_eq!(hw.predict(&series), before);
    }

    #[test]
    fn forecasts_clamp_at_zero() {
        let mut hw = HoltWintersForecaster::new(2);
        hw.observe(&[0.0; METRIC_DIM]);
        hw.observe(&[0.0; METRIC_DIM]);
        hw.observe(&[0.0; METRIC_DIM]);
        let p = hw.predict(&[]).expect("initialized");
        assert!(p.iter().all(|v| *v >= 0.0));
    }
}

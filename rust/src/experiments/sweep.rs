//! Parallel scenario-sweep harness: fan a (scenario × autoscaler × seed)
//! grid over a chosen [`Topology`] across worker threads, one independent
//! deterministic [`SimWorld`] per cell, and aggregate RIR percentiles,
//! response-time distributions, replica trajectories and prediction MSE
//! into a JSON report.
//!
//! Determinism: a cell's result depends only on its (topology, scenario,
//! scaler, seed, minutes) tuple — cells share no mutable state — so
//! per-cell results are bit-identical regardless of the worker-thread
//! count (asserted by `determinism_across_thread_counts` and the
//! city-scale determinism tests below) *and* of the event-queue core
//! ([`CoreKind`], asserted by the `golden_core_equivalence_*` tests).
//! With `SweepConfig::shards >= 1` the same invariant extends inward:
//! each cell runs on the conservative sharded engine
//! ([`crate::sim::run_sharded`]) and its results are bit-identical for
//! every shard count (asserted by
//! `sharded_cells_are_bit_identical_across_shard_counts` and
//! `tests/shard_identity.rs`).
//!
//! Memory stays flat per cell: response statistics are streamed
//! ([`crate::app::ResponseStats`] — Welford moments + log-histogram
//! percentiles), never collected into a per-request log.

use super::driver::SimWorld;
use crate::app::{Priority, SlaConfig, SlaSummary, TaskCosts};
use crate::autoscaler::{
    specs_label, Autoscaler, Hpa, HpaConfig, Hybrid, HybridConfig, Ppa, PpaConfig, ScalerPolicy,
    ScalerRegistry,
};
use crate::cluster::FaultPlan;
use crate::config::{ClusterConfig, Topology};
use crate::forecast::{ArmaForecaster, Forecaster, NaiveForecaster, SelectionSummary};
use crate::sim::{run_sharded, to_secs, CoreKind, ShardSpec, Time, MIN};
use crate::stats::{percentile, summarize, Summary};
use crate::util::json::Json;
use crate::workload::Scenario;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Model-update period used for sweep PPAs: short enough that the ARMA
/// model trains from live history well inside a 30-minute cell.
const SWEEP_UPDATE_INTERVAL: Time = 10 * MIN;

/// Which autoscaler a sweep cell runs on every service.
///
/// The PJRT LSTM PPA is deliberately absent: its runtime handle is not
/// `Send` (and needs artifacts); the sweep compares the thread-safe
/// model-free and ARMA variants, which is the (PPA vs HPA) axis the
/// related-work matrices use. The PPA kinds' *models* are a separate
/// axis: a fleet policy with [`ScalerPolicy::forecaster`] set swaps in
/// any pure-Rust zoo forecaster (`--forecaster
/// naive|arma|holt-winters|tcn|lstm-rs|auto:K`), including the
/// champion–challenger selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoscalerKind {
    /// Reactive baseline, full Kubernetes semantics.
    Hpa,
    /// PPA with the last-value persistence model.
    PpaNaive,
    /// PPA with the ARMA(1,1) model, trained online by the update loop.
    PpaArma,
    /// SLA-guarded hybrid: proactive ARMA baseline plus the reactive
    /// override (violation-rate signal / forecast z-guard — see
    /// [`crate::autoscaler::Hybrid`]).
    Hybrid,
}

impl AutoscalerKind {
    pub const ALL: [AutoscalerKind; 4] = [
        AutoscalerKind::Hpa,
        AutoscalerKind::PpaNaive,
        AutoscalerKind::PpaArma,
        AutoscalerKind::Hybrid,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AutoscalerKind::Hpa => "hpa",
            AutoscalerKind::PpaNaive => "ppa-naive",
            AutoscalerKind::PpaArma => "ppa-arma",
            AutoscalerKind::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "hpa" => Ok(AutoscalerKind::Hpa),
            "ppa-naive" | "naive" => Ok(AutoscalerKind::PpaNaive),
            "ppa-arma" | "arma" => Ok(AutoscalerKind::PpaArma),
            "hybrid" => Ok(AutoscalerKind::Hybrid),
            other => bail!("unknown autoscaler '{other}' (hpa|ppa-naive|ppa-arma|hybrid)"),
        }
    }

    /// Fresh default-policy autoscaler for one service of one cell
    /// (single cpu:70 spec, stock behavior).
    fn build(&self) -> Box<dyn Autoscaler> {
        let ppa_cfg = PpaConfig {
            update_interval: SWEEP_UPDATE_INTERVAL,
            ..PpaConfig::default()
        };
        match self {
            AutoscalerKind::Hpa => Box::new(Hpa::with_defaults()),
            AutoscalerKind::PpaNaive => Box::new(Ppa::new(ppa_cfg, Box::new(NaiveForecaster))),
            // Starts model-less: Algorithm 1 falls back to the current
            // metric until the first update loop fits an ARMA from the
            // live history file — the cold-start path the paper's
            // "Robust" property describes.
            AutoscalerKind::PpaArma => {
                Box::new(Ppa::new(ppa_cfg, Box::new(ArmaForecaster::new())))
            }
            // Same ARMA baseline, wrapped in the reactive guardrail.
            AutoscalerKind::Hybrid => Box::new(Hybrid::new(
                HybridConfig {
                    ppa: ppa_cfg,
                    ..HybridConfig::default()
                },
                Box::new(ArmaForecaster::new()),
            )),
        }
    }

    /// Fresh autoscaler running one fleet entry's `(spec set, behavior,
    /// forecaster)` policy. The HPA reads every spec reactively (and
    /// ignores the forecaster axis); the PPAs honour each spec's
    /// current/forecast source and swap their stock model for
    /// `policy.forecaster` when set, seeding learned inits from the cell
    /// seed so the build stays pure. A policy without a behavior
    /// override keeps the kind's stock default (HPA: 5-min down window;
    /// PPA: 2-min), so metric-only fleets never skew the baselines.
    fn build_with(&self, policy: &ScalerPolicy, seed: u64) -> Box<dyn Autoscaler> {
        match self {
            AutoscalerKind::Hpa => {
                let default = HpaConfig::default();
                Box::new(Hpa::new(HpaConfig {
                    specs: policy.specs.clone(),
                    behavior: policy.behavior.unwrap_or(default.behavior),
                    ..default
                }))
            }
            AutoscalerKind::PpaNaive | AutoscalerKind::PpaArma | AutoscalerKind::Hybrid => {
                let default = PpaConfig::default();
                let cfg = PpaConfig {
                    specs: policy.specs.clone(),
                    behavior: policy.behavior.unwrap_or(default.behavior),
                    update_interval: SWEEP_UPDATE_INTERVAL,
                    ..default
                };
                let model: Box<dyn Forecaster> = match policy.forecaster {
                    Some(kind) => kind.build(seed),
                    None if *self == AutoscalerKind::PpaNaive => Box::new(NaiveForecaster),
                    None => Box::new(ArmaForecaster::new()),
                };
                if *self == AutoscalerKind::Hybrid {
                    Box::new(Hybrid::new(
                        HybridConfig {
                            ppa: cfg,
                            ..HybridConfig::default()
                        },
                        model,
                    ))
                } else {
                    Box::new(Ppa::new(cfg, model))
                }
            }
        }
    }
}

/// The sweep grid.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Cluster topology every cell runs on (Table 2 or a generated city).
    pub topology: Topology,
    /// Named scenarios (see [`crate::config::scenario_presets`] and
    /// [`crate::config::city_scenario_presets`]).
    pub scenarios: Vec<(String, Scenario)>,
    pub scalers: Vec<AutoscalerKind>,
    pub seeds: Vec<u64>,
    /// Simulated length of every cell.
    pub minutes: u64,
    /// Worker threads (0 = one per available core).
    pub threads: usize,
    /// Event-queue core every cell runs on. [`CoreKind::Calendar`] is
    /// the fast default; [`CoreKind::Heap`] is the golden reference —
    /// per-cell results are bit-identical either way (asserted by
    /// `golden_core_equivalence_*` below).
    pub core: CoreKind,
    /// Optional fleet registry: per-service `(spec set, behavior)`
    /// policies, so one cell scales different deployments under
    /// different metric specs. `None` = every service on the scaler
    /// kind's default single-metric policy.
    pub fleet: Option<ScalerRegistry>,
    /// Within-cell sharding: `0` runs each cell on the monolithic
    /// [`SimWorld`]; `>= 1` runs it on the conservative sharded engine
    /// ([`run_sharded`]) with that many worker threads per cell.
    /// Sharded cells are bit-identical across every `shards >= 1` value
    /// (asserted by `tests/shard_identity.rs`); the monolith remains the
    /// golden single-threaded reference with its own RNG stream layout,
    /// so `0` and `>= 1` are two (each bit-reproducible) schedules.
    pub shards: usize,
    /// Fault plan every cell runs under (see `cluster::chaos` and
    /// [`crate::config::chaos_presets`]). [`FaultPlan::none`] — the
    /// fault-free default — is a strict no-op: cells are bit-identical
    /// to a sweep without the chaos plane (asserted by
    /// `tests/golden_chaos_equivalence.rs`). Faulted cells stay
    /// bit-reproducible across runs, worker-thread counts and shard
    /// counts, because all fault randomness comes from dedicated chaos
    /// RNG streams keyed by the cell seed.
    pub chaos: FaultPlan,
    /// Resilience plane every cell runs under (deadline/retry/shed SLA
    /// plus the arrival priority mix — see [`crate::app::SlaConfig`]).
    /// `None` — the default — is a strict no-op: no SLA RNG stream is
    /// built, no timeout events are scheduled, and cells are
    /// bit-identical to a pre-resilience sweep (asserted by
    /// `tests/golden_sla_equivalence.rs`).
    pub sla: Option<SlaConfig>,
}

/// Deterministic per-cell outcome (everything except wall-clock).
#[derive(Debug, Clone, PartialEq)]
pub struct CellMetrics {
    pub topology: String,
    pub scenario: String,
    pub scaler: String,
    /// Per-service metric-spec labels (`cpu:70`,
    /// `cpu:70+req_rate:150`, …) — the fleet the cell actually ran.
    pub specs: Vec<String>,
    pub seed: u64,
    pub events: u64,
    pub completed: usize,
    /// Streaming per-task response summaries (Welford moments — see
    /// [`crate::stats::StreamingStats`]; cells never retain the full
    /// per-request log).
    pub sort: Summary,
    /// Response percentiles are log-histogram estimates (geometric bin
    /// centers, ≤ ~1.1% relative error), not exact order statistics.
    pub sort_p50: f64,
    pub sort_p95: f64,
    pub sort_p99: f64,
    pub eigen: Summary,
    pub rir: Summary,
    pub rir_p50: f64,
    pub rir_p95: f64,
    pub rir_p99: f64,
    /// Mean/max of the replica trajectory across all services.
    pub replicas_mean: f64,
    pub replicas_max: usize,
    /// Mean prediction MSE across PPA scalers that made predictions.
    pub prediction_mse: Option<f64>,
    /// Champion model name of every service that ran champion–challenger
    /// selection (`--forecaster auto:K`), in service order — the same
    /// order on the monolith and on every shard count; empty otherwise.
    pub champions: Vec<String>,
    /// Shadow-score MSE per zoo model, pooled across selecting services
    /// (weighted by each service's scored-tick count), sorted by model
    /// name; empty unless some service ran selection.
    pub model_mses: Vec<(String, f64)>,
    /// Fault-plan label the cell ran under (`none` when fault-free).
    pub chaos: String,
    /// Node crashes injected.
    pub crashes: u64,
    /// Crashed nodes that rejoined before the end of the cell.
    pub rejoins: u64,
    /// Pods killed by node crashes.
    pub pods_killed: u64,
    /// Replacement pods scheduled by the post-crash reconciles.
    pub pods_rescheduled: u64,
    /// Simulated container crash-loop restarts.
    pub crash_loops: u64,
    /// Total node downtime in simulated seconds (crash → rejoin or end).
    pub downtime_secs: f64,
    /// p95 of perturbed pod init delays, seconds (NaN when no pod chaos).
    pub cold_start_p95: f64,
    /// SLA-policy label the cell ran under (`none` when the resilience
    /// plane is off).
    pub sla: String,
    /// Deadline expiries (still-queued or in-service attempts that
    /// outlived the per-attempt deadline).
    pub sla_timeouts: u64,
    /// Timed-out attempts rescheduled with backoff (budget remaining).
    pub sla_retries: u64,
    /// Requests dropped with the retry budget spent.
    pub sla_violations: u64,
    /// `Batch` arrivals shed by admission control.
    pub sla_shed: u64,
    /// Distinct simulated minutes containing >= 1 violation, summed per
    /// world (the sweep's SLA-violation-minutes currency).
    pub sla_violation_minutes: u64,
    /// Per-priority-class response summaries (Critical/Standard/Batch
    /// order); empty when the resilience plane is off.
    pub class_response: Vec<(String, Summary)>,
    /// Node-hours billed over the cell (downtime excluded — a crashed
    /// node stops billing until it rejoins).
    pub cost_node_hours: f64,
    /// Pods ever spawned (scale-ups + crash replacements) — the cost
    /// ledger's churn counter.
    pub pod_churn: u64,
    /// Reactive-override trips of the hybrid scaler (`None` when the
    /// cell ran no hybrid).
    pub hybrid_trips: Option<u64>,
    /// Control ticks decided under the reactive override (`None` when
    /// the cell ran no hybrid).
    pub hybrid_override_ticks: Option<u64>,
}

impl CellMetrics {
    /// Canonical text form of every deterministic field. Unlike a
    /// `PartialEq` comparison this treats NaN (empty-sample summaries) as
    /// equal to itself, so it is the right equality for determinism
    /// checks and for diffing reports.
    pub fn fingerprint(&self) -> String {
        format!("{self:?}")
    }
}

/// One grid cell: deterministic metrics + measured wall time.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub metrics: CellMetrics,
    pub wall_secs: f64,
}

/// The whole sweep.
#[derive(Debug)]
pub struct SweepResult {
    pub topology: String,
    /// Event-queue core the cells ran on.
    pub core: CoreKind,
    /// Within-cell shard count the cells ran on (0 = monolithic world).
    pub shards: usize,
    pub cells: Vec<CellResult>,
    pub minutes: u64,
    pub threads_used: usize,
    pub wall_secs: f64,
}

/// Per-worker scratch buffers reused across the grid, so city sweeps
/// stop paying a build/drop of these temporaries for every cell. The
/// buffers never leak data between cells (`run_cell_with_scratch`
/// clears them up front) and never shrink, so a worker converges on the
/// high-water allocation of its largest cell.
#[derive(Debug, Default)]
pub struct CellScratch {
    rirs: Vec<f64>,
    reps: Vec<f64>,
    mses: Vec<f64>,
    specs: Vec<String>,
    selections: Vec<SelectionSummary>,
}

/// Per-priority-class response summaries in Critical/Standard/Batch
/// order — empty when the resilience plane is off, so SLA-free cells
/// keep the pre-resilience report shape.
fn class_response(sla: Option<&SlaConfig>, summary: &SlaSummary) -> Vec<(String, Summary)> {
    if sla.is_none() {
        return Vec::new();
    }
    [Priority::Critical, Priority::Standard, Priority::Batch]
        .iter()
        .map(|p| (p.name().to_string(), summary.class_stats[p.index()].summary()))
        .collect()
}

/// Run one independent cell on `cluster` (a materialized topology).
/// Response statistics come from the app's always-on streaming stats —
/// the cell never accumulates a per-request log, so memory stays flat
/// however long (or busy) the cell runs.
///
/// `shards == 0` runs the monolithic [`SimWorld`]; `shards >= 1` runs
/// the conservative sharded engine with that many worker threads —
/// bit-identical for every `shards >= 1` value.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    topology_label: &str,
    cluster: &ClusterConfig,
    scenario_name: &str,
    scenario: &Scenario,
    scaler: AutoscalerKind,
    fleet: Option<&ScalerRegistry>,
    seed: u64,
    minutes: u64,
    core: CoreKind,
    shards: usize,
    chaos: &FaultPlan,
    sla: Option<&SlaConfig>,
) -> CellResult {
    let mut scratch = CellScratch::default();
    run_cell_with_scratch(
        topology_label,
        cluster,
        scenario_name,
        scenario,
        scaler,
        fleet,
        seed,
        minutes,
        core,
        shards,
        chaos,
        sla,
        &mut scratch,
    )
}

/// [`run_cell`] against caller-owned scratch — what the sweep workers
/// use to reuse one set of buffers across their whole share of the grid.
#[allow(clippy::too_many_arguments)]
pub fn run_cell_with_scratch(
    topology_label: &str,
    cluster: &ClusterConfig,
    scenario_name: &str,
    scenario: &Scenario,
    scaler: AutoscalerKind,
    fleet: Option<&ScalerRegistry>,
    seed: u64,
    minutes: u64,
    core: CoreKind,
    shards: usize,
    chaos: &FaultPlan,
    sla: Option<&SlaConfig>,
    scratch: &mut CellScratch,
) -> CellResult {
    let wall = crate::util::wallclock();
    scratch.rirs.clear();
    scratch.reps.clear();
    scratch.mses.clear();
    scratch.specs.clear();
    scratch.selections.clear();
    let end = minutes * MIN;

    let (
        events,
        completed,
        sort,
        eigen,
        replicas_max,
        chaos_counters,
        sla_summary,
        cost_node_hours,
        pod_churn,
        hybrid_trips,
        hybrid_override_ticks,
    ) = if shards == 0 {
        let mut world = SimWorld::build_with_core(cluster, TaskCosts::default(), seed, core);
        for gen in scenario.build_generators() {
            world.add_generator(gen);
        }
        let n_services = world.app.services.len();
        for svc in 0..n_services {
            let autoscaler = match fleet {
                Some(registry) => scaler.build_with(registry.policy_for(svc), seed),
                None => scaler.build(),
            };
            world.add_scaler(autoscaler, svc);
        }
        world.install_chaos(chaos, seed, end);
        if let Some(cfg) = sla {
            world.install_sla(cfg, seed);
        }
        let events = world.run_until(end);
        scratch
            .specs
            .extend(world.scalers.iter().map(|b| specs_label(b.autoscaler.specs())));
        scratch.rirs.extend(world.rir_log.iter().map(|s| s.rir));
        scratch
            .reps
            .extend(world.replica_log.iter().map(|&(_, _, r)| r as f64));
        let replicas_max = world.replica_log.iter().map(|&(_, _, r)| r).max().unwrap_or(0);
        let mut hybrid_trips: Option<u64> = None;
        let mut hybrid_override_ticks: Option<u64> = None;
        for binding in &world.scalers {
            if let Some(ppa) = binding.autoscaler.as_any().downcast_ref::<Ppa>() {
                // Streaming count/MSE: the exact prediction log stays off
                // in sweep cells (flat memory).
                if ppa.prediction_count() > 0 {
                    scratch.mses.push(ppa.prediction_mse());
                }
                if let Some(selection) = ppa.selection() {
                    scratch.selections.push(selection);
                }
            } else if let Some(h) = binding.autoscaler.as_any().downcast_ref::<Hybrid>() {
                if h.prediction_count() > 0 {
                    scratch.mses.push(h.prediction_mse());
                }
                if let Some(selection) = h.selection() {
                    scratch.selections.push(selection);
                }
                hybrid_trips = Some(hybrid_trips.unwrap_or(0) + h.trips());
                hybrid_override_ticks =
                    Some(hybrid_override_ticks.unwrap_or(0) + h.override_ticks());
            }
        }
        let stats = &world.app.stats;
        (
            events,
            world.app.completed(),
            stats.sort.clone(),
            stats.eigen.clone(),
            replicas_max,
            world.chaos_summary(end),
            world.sla_summary(),
            world.cost_node_hours(end),
            world.cluster.pod_churn,
            hybrid_trips,
            hybrid_override_ticks,
        )
    } else {
        let spec = ShardSpec {
            shards,
            core,
            seed,
            costs: TaskCosts::default(),
            end,
            record_decisions: false,
            chaos: *chaos,
            sla: sla.copied(),
        };
        let run = run_sharded(
            cluster,
            scenario.build_generators(),
            &|svc| match fleet {
                Some(registry) => scaler.build_with(registry.policy_for(svc), seed),
                None => scaler.build(),
            },
            &spec,
        )
        .expect("sharded cell failed (topology was validated up front)");
        scratch.specs.extend(run.spec_labels());
        scratch.rirs.extend(run.rir_log().iter().map(|s| s.rir));
        let replica_log = run.replica_log();
        scratch
            .reps
            .extend(replica_log.iter().map(|&(_, _, r)| r as f64));
        let replicas_max = replica_log.iter().map(|&(_, _, r)| r).max().unwrap_or(0);
        scratch.mses.extend(run.prediction_mses());
        scratch.selections.extend(run.selections());
        (
            run.events(),
            run.completed(),
            run.sort_stats(),
            run.eigen_stats(),
            replicas_max,
            run.chaos_counters(),
            run.sla_summary(),
            run.cost_node_hours(),
            run.pod_churn(),
            run.hybrid_trips(),
            run.hybrid_override_ticks(),
        )
    };

    let champions: Vec<String> =
        scratch.selections.iter().map(|s| s.champion.clone()).collect();
    // Pool each model's shadow MSE across selecting services, weighted by
    // the per-service scored-tick count. BTreeMap keys the sums by model
    // name; the service-order iteration makes the float accumulation
    // order identical on the monolith and on every shard count.
    let mut pooled: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    for selection in &scratch.selections {
        for model in &selection.models {
            if let Some(mse) = model.mse {
                let slot = pooled.entry(model.name.clone()).or_insert((0.0, 0.0));
                slot.0 += mse * model.n as f64;
                slot.1 += model.n as f64;
            }
        }
    }
    let model_mses: Vec<(String, f64)> = pooled
        .into_iter()
        .map(|(name, (weighted, n))| (name, weighted / n))
        .collect();

    let metrics = CellMetrics {
        topology: topology_label.to_string(),
        scenario: scenario_name.to_string(),
        scaler: scaler.name().to_string(),
        specs: scratch.specs.clone(),
        seed,
        events,
        completed,
        sort: sort.summary(),
        sort_p50: sort.quantile(50.0),
        sort_p95: sort.quantile(95.0),
        sort_p99: sort.quantile(99.0),
        eigen: eigen.summary(),
        rir: summarize(&scratch.rirs),
        rir_p50: percentile(&scratch.rirs, 50.0),
        rir_p95: percentile(&scratch.rirs, 95.0),
        rir_p99: percentile(&scratch.rirs, 99.0),
        replicas_mean: summarize(&scratch.reps).mean,
        replicas_max,
        prediction_mse: (!scratch.mses.is_empty()).then(|| summarize(&scratch.mses).mean),
        champions,
        model_mses,
        chaos: chaos.label(),
        crashes: chaos_counters.crashes,
        rejoins: chaos_counters.rejoins,
        pods_killed: chaos_counters.pods_killed,
        pods_rescheduled: chaos_counters.pods_rescheduled,
        crash_loops: chaos_counters.crash_loops,
        downtime_secs: to_secs(chaos_counters.downtime),
        cold_start_p95: chaos_counters.cold_start_p95(),
        sla: sla.map_or_else(|| "none".to_string(), SlaConfig::label),
        sla_timeouts: sla_summary.counters.timeouts,
        sla_retries: sla_summary.counters.retries,
        sla_violations: sla_summary.counters.violations,
        sla_shed: sla_summary.counters.shed,
        sla_violation_minutes: sla_summary.counters.violation_minutes,
        class_response: class_response(sla, &sla_summary),
        cost_node_hours,
        pod_churn,
        hybrid_trips,
        hybrid_override_ticks,
    };
    CellResult {
        metrics,
        wall_secs: wall.elapsed().as_secs_f64(),
    }
}

/// Run the full grid, fanning cells across `threads` workers.
pub fn run_sweep(cfg: &SweepConfig) -> crate::Result<SweepResult> {
    if cfg.scenarios.is_empty() || cfg.scalers.is_empty() || cfg.seeds.is_empty() {
        bail!("sweep grid is empty (scenarios x scalers x seeds)");
    }
    // Materialize the topology once; cells share it read-only.
    let topology_label = cfg.topology.label();
    let cluster = cfg.topology.cluster();
    cluster.validate()?;
    if cfg.shards >= 1 {
        // Fail fast (and with a real error) if the topology cannot be
        // partitioned into zone worlds, instead of inside a worker.
        crate::sim::partition_worlds(&cluster)?;
    }
    // Validate scenario zones against the chosen topology before
    // spawning anything.
    let edge_zones: Vec<u32> = cluster.deployments.iter().filter_map(|d| d.zone).collect();
    for (name, scenario) in &cfg.scenarios {
        for gen in scenario.build_generators() {
            if !edge_zones.contains(&gen.zone()) {
                bail!(
                    "scenario '{name}' targets zone {} but topology '{topology_label}' \
                     only has {} zones",
                    gen.zone(),
                    edge_zones.len()
                );
            }
        }
    }

    let mut specs = Vec::new();
    for (name, scenario) in &cfg.scenarios {
        for &scaler in &cfg.scalers {
            for &seed in &cfg.seeds {
                specs.push((name.as_str(), scenario, scaler, seed));
            }
        }
    }

    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.threads
    };
    let threads = threads.clamp(1, specs.len());

    let wall = crate::util::wallclock();
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<CellResult>>> = Mutex::new(vec![None; specs.len()]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // One scratch per worker, reused for its whole share of
                // the grid (no per-cell build/drop of the buffers).
                let mut scratch = CellScratch::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let (name, scenario, scaler, seed) = specs[i];
                    let result = run_cell_with_scratch(
                        &topology_label,
                        &cluster,
                        name,
                        scenario,
                        scaler,
                        cfg.fleet.as_ref(),
                        seed,
                        cfg.minutes,
                        cfg.core,
                        cfg.shards,
                        &cfg.chaos,
                        cfg.sla.as_ref(),
                        &mut scratch,
                    );
                    slots.lock().unwrap()[i] = Some(result);
                }
            });
        }
    });

    let cells: Vec<CellResult> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|c| c.expect("every cell claimed by a worker"))
        .collect();
    Ok(SweepResult {
        topology: topology_label,
        core: cfg.core,
        shards: cfg.shards,
        cells,
        minutes: cfg.minutes,
        threads_used: threads,
        wall_secs: wall.elapsed().as_secs_f64(),
    })
}

// ---------------------------------------------------------------------------
// JSON report
// ---------------------------------------------------------------------------

/// NaN/inf-safe number (JSON has no NaN; empty-sample stats become null).
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn summary_json(s: &Summary) -> Json {
    let mut o = BTreeMap::new();
    o.insert("n".to_string(), Json::Num(s.n as f64));
    o.insert("mean".to_string(), num(s.mean));
    o.insert("std".to_string(), num(s.std));
    o.insert("min".to_string(), num(s.min));
    o.insert("max".to_string(), num(s.max));
    Json::Obj(o)
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        let mut o = BTreeMap::new();
        o.insert("topology".to_string(), Json::Str(m.topology.clone()));
        o.insert("scenario".to_string(), Json::Str(m.scenario.clone()));
        o.insert("scaler".to_string(), Json::Str(m.scaler.clone()));
        o.insert(
            "specs".to_string(),
            Json::Arr(m.specs.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        o.insert("seed".to_string(), Json::Num(m.seed as f64));
        o.insert("events".to_string(), Json::Num(m.events as f64));
        o.insert("completed".to_string(), Json::Num(m.completed as f64));
        o.insert("sort_response".to_string(), summary_json(&m.sort));
        o.insert("sort_p50".to_string(), num(m.sort_p50));
        o.insert("sort_p95".to_string(), num(m.sort_p95));
        o.insert("sort_p99".to_string(), num(m.sort_p99));
        o.insert("eigen_response".to_string(), summary_json(&m.eigen));
        o.insert("rir".to_string(), summary_json(&m.rir));
        o.insert("rir_p50".to_string(), num(m.rir_p50));
        o.insert("rir_p95".to_string(), num(m.rir_p95));
        o.insert("rir_p99".to_string(), num(m.rir_p99));
        o.insert("replicas_mean".to_string(), num(m.replicas_mean));
        o.insert("replicas_max".to_string(), Json::Num(m.replicas_max as f64));
        o.insert(
            "prediction_mse".to_string(),
            m.prediction_mse.map_or(Json::Null, num),
        );
        o.insert(
            "champions".to_string(),
            Json::Arr(m.champions.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        o.insert(
            "model_mses".to_string(),
            Json::Obj(
                m.model_mses
                    .iter()
                    .map(|(name, mse)| (name.clone(), num(*mse)))
                    .collect(),
            ),
        );
        o.insert("chaos".to_string(), Json::Str(m.chaos.clone()));
        o.insert("crashes".to_string(), Json::Num(m.crashes as f64));
        o.insert("rejoins".to_string(), Json::Num(m.rejoins as f64));
        o.insert("pods_killed".to_string(), Json::Num(m.pods_killed as f64));
        o.insert(
            "pods_rescheduled".to_string(),
            Json::Num(m.pods_rescheduled as f64),
        );
        o.insert("crash_loops".to_string(), Json::Num(m.crash_loops as f64));
        o.insert("downtime_secs".to_string(), num(m.downtime_secs));
        o.insert("cold_start_p95".to_string(), num(m.cold_start_p95));
        o.insert("sla".to_string(), Json::Str(m.sla.clone()));
        o.insert("sla_timeouts".to_string(), Json::Num(m.sla_timeouts as f64));
        o.insert("sla_retries".to_string(), Json::Num(m.sla_retries as f64));
        o.insert(
            "sla_violations".to_string(),
            Json::Num(m.sla_violations as f64),
        );
        o.insert("sla_shed".to_string(), Json::Num(m.sla_shed as f64));
        o.insert(
            "sla_violation_minutes".to_string(),
            Json::Num(m.sla_violation_minutes as f64),
        );
        o.insert(
            "class_response".to_string(),
            Json::Obj(
                m.class_response
                    .iter()
                    .map(|(name, s)| (name.clone(), summary_json(s)))
                    .collect(),
            ),
        );
        o.insert("cost_node_hours".to_string(), num(m.cost_node_hours));
        o.insert("pod_churn".to_string(), Json::Num(m.pod_churn as f64));
        o.insert(
            "hybrid_trips".to_string(),
            m.hybrid_trips.map_or(Json::Null, |t| Json::Num(t as f64)),
        );
        o.insert(
            "hybrid_override_ticks".to_string(),
            m.hybrid_override_ticks.map_or(Json::Null, |t| Json::Num(t as f64)),
        );
        o.insert("wall_secs".to_string(), num(self.wall_secs));
        Json::Obj(o)
    }
}

impl SweepResult {
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("topology".to_string(), Json::Str(self.topology.clone()));
        root.insert("core".to_string(), Json::Str(self.core.name().to_string()));
        root.insert("shards".to_string(), Json::Num(self.shards as f64));
        root.insert("minutes".to_string(), Json::Num(self.minutes as f64));
        root.insert("threads".to_string(), Json::Num(self.threads_used as f64));
        root.insert("wall_secs".to_string(), num(self.wall_secs));
        root.insert(
            "cells".to_string(),
            Json::Arr(self.cells.iter().map(CellResult::to_json).collect()),
        );
        Json::Obj(root)
    }

    /// Write the JSON report (creating parent directories).
    pub fn write_json(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario_presets;
    use crate::sim::SEC;
    use crate::workload::{FlashCrowdConfig, StepSurgeConfig};

    /// A cheap 3-scenario grid for tests.
    fn tiny_scenarios() -> Vec<(String, Scenario)> {
        vec![
            (
                "step".to_string(),
                Scenario::StepSurge {
                    cfg: StepSurgeConfig {
                        levels_rps: vec![0.5, 2.0],
                        step: 2 * MIN,
                    },
                    zones: vec![1, 2],
                },
            ),
            (
                "flash".to_string(),
                Scenario::FlashCrowd {
                    cfg: FlashCrowdConfig {
                        base_rps: 0.4,
                        spike_rps: 3.0,
                        spike_start: 2 * MIN,
                        ramp: 20 * SEC,
                        hold: 2 * MIN,
                        decay: 30 * SEC,
                    },
                    zones: vec![1, 2],
                    stagger: MIN,
                },
            ),
            (
                "diurnal".to_string(),
                Scenario::Diurnal {
                    cfg: crate::workload::DiurnalConfig {
                        period: 10 * MIN, // whole day compressed into the cell
                        peak_hour: 12.0,
                        ..Default::default()
                    },
                    zones: vec![1, 2],
                },
            ),
        ]
    }

    fn tiny_config(threads: usize) -> SweepConfig {
        SweepConfig {
            topology: Topology::Paper,
            scenarios: tiny_scenarios(),
            scalers: vec![AutoscalerKind::Hpa, AutoscalerKind::PpaNaive],
            seeds: vec![1, 2],
            minutes: 6,
            threads,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        }
    }

    fn fingerprints(r: &SweepResult) -> Vec<String> {
        r.cells.iter().map(|c| c.metrics.fingerprint()).collect()
    }

    #[test]
    fn grid_covers_every_combination() {
        let cfg = tiny_config(2);
        let result = run_sweep(&cfg).unwrap();
        assert_eq!(result.cells.len(), 3 * 2 * 2);
        for (name, _) in &cfg.scenarios {
            for scaler in &cfg.scalers {
                for seed in &cfg.seeds {
                    assert!(
                        result.cells.iter().any(|c| c.metrics.scenario == *name
                            && c.metrics.scaler == scaler.name()
                            && c.metrics.seed == *seed),
                        "missing cell {name}/{}/{seed}",
                        scaler.name()
                    );
                }
            }
        }
        // Cells actually simulated something.
        assert!(result.cells.iter().all(|c| c.metrics.events > 100));
        assert!(result.cells.iter().all(|c| c.metrics.completed > 10));
    }

    #[test]
    fn determinism_across_thread_counts() {
        // The acceptance grid: >= 3 scenarios x 2 autoscalers x 4 seeds,
        // serial vs parallel.
        let grid = |threads| SweepConfig {
            seeds: vec![1, 2, 3, 4],
            minutes: 4,
            threads,
            ..tiny_config(threads)
        };
        let serial = run_sweep(&grid(1)).unwrap();
        let parallel = run_sweep(&grid(4)).unwrap();
        assert_eq!(serial.cells.len(), 3 * 2 * 4);
        assert_eq!(serial.threads_used, 1);
        assert!(parallel.threads_used > 1);
        assert_eq!(
            fingerprints(&serial),
            fingerprints(&parallel),
            "per-cell results must be bit-identical regardless of threads"
        );
    }

    #[test]
    fn same_config_reproduces_and_seeds_differ() {
        let a = run_sweep(&tiny_config(2)).unwrap();
        let b = run_sweep(&tiny_config(2)).unwrap();
        assert_eq!(fingerprints(&a), fingerprints(&b));
        // Within one run, the two seeds of the same (scenario, scaler)
        // must not be identical worlds.
        let c1 = &a.cells[0].metrics;
        let c2 = &a.cells[1].metrics;
        assert_eq!(
            (c1.scenario.as_str(), c1.scaler.as_str()),
            (c2.scenario.as_str(), c2.scaler.as_str())
        );
        assert_ne!(c1.seed, c2.seed);
        assert_ne!(c1.fingerprint(), c2.fingerprint());
    }

    #[test]
    fn ppa_arma_trains_online_and_reports_mse() {
        // One 25-minute ARMA cell: the 10-min update loop must have fitted
        // a model, so predictions (and an MSE) exist.
        let cfg = SweepConfig {
            topology: Topology::Paper,
            scenarios: tiny_scenarios()[..1].to_vec(),
            scalers: vec![AutoscalerKind::PpaArma],
            seeds: vec![5],
            minutes: 25,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let result = run_sweep(&cfg).unwrap();
        let cell = &result.cells[0].metrics;
        assert!(
            cell.prediction_mse.is_some(),
            "ARMA PPA should be predicting after the first model update"
        );
        assert!(cell.prediction_mse.unwrap().is_finite());
        // Without a selecting forecaster, the selection columns stay
        // empty (and the JSON keys are present but empty).
        assert!(cell.champions.is_empty());
        assert!(cell.model_mses.is_empty());
    }

    #[test]
    fn auto_fleet_reports_champions_and_model_mses() {
        // One champion–challenger cell on the paper topology: every
        // service runs `auto:3`, so the cell reports one champion per
        // service and a pooled shadow MSE for each roster model.
        let fleet = ScalerRegistry::uniform(
            ScalerPolicy::default().with_forecaster(crate::forecast::ForecasterKind::Auto(3)),
        );
        let cfg = SweepConfig {
            topology: Topology::Paper,
            scenarios: tiny_scenarios()[..1].to_vec(),
            scalers: vec![AutoscalerKind::PpaArma],
            seeds: vec![5],
            minutes: 25,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: Some(fleet),
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let result = run_sweep(&cfg).unwrap();
        let cell = &result.cells[0].metrics;
        assert_eq!(cell.champions.len(), 3, "one champion per paper service");
        let roster = ["holt-winters(30)", "arma(1,1)", "naive-last-value"];
        assert!(cell.champions.iter().all(|c| roster.contains(&c.as_str())));
        assert!(!cell.model_mses.is_empty(), "challengers were shadow-scored");
        assert!(cell.model_mses.iter().all(|(n, mse)| {
            roster.contains(&n.as_str()) && mse.is_finite() && *mse >= 0.0
        }));
        let doc = result.cells[0].to_json();
        assert_eq!(doc.get("champions").as_arr().unwrap().len(), 3);
        assert!(doc.get("model_mses").get(&cell.model_mses[0].0).as_f64().is_some());
    }

    #[test]
    fn json_report_roundtrips() {
        let result = run_sweep(&SweepConfig {
            topology: Topology::Paper,
            scenarios: tiny_scenarios()[..1].to_vec(),
            scalers: vec![AutoscalerKind::Hpa],
            seeds: vec![3],
            minutes: 4,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        })
        .unwrap();
        let dir = std::env::temp_dir().join("ppa_sweep_test");
        let path = dir.join("sweep.json");
        result.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let cells = doc.get("cells").as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("scaler").as_str(), Some("hpa"));
        assert!(cells[0].get("rir").get("mean").as_f64().is_some());
        // Schema: per-service spec labels (default fleet = cpu:70
        // everywhere — 3 paper services).
        let specs = cells[0].get("specs").as_arr().unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs.iter().all(|s| s.as_str() == Some("cpu:70")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn presets_are_valid_sweep_inputs() {
        // Every shipped preset must build generators on cluster zones and
        // carry a unique name.
        let presets = scenario_presets();
        assert!(presets.len() >= 5, "library should be broad");
        let mut names: Vec<&str> = presets.iter().map(|(n, _)| n.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), presets.len(), "duplicate preset names");
        for (_, scenario) in &presets {
            let gens = scenario.build_generators();
            assert!(!gens.is_empty());
            assert!(gens.iter().all(|g| (1..=2).contains(&g.zone())));
        }
    }

    #[test]
    fn empty_grid_rejected() {
        let cfg = SweepConfig {
            topology: Topology::Paper,
            scenarios: vec![],
            scalers: vec![AutoscalerKind::Hpa],
            seeds: vec![1],
            minutes: 1,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn bad_zone_rejected() {
        let cfg = SweepConfig {
            topology: Topology::Paper,
            scenarios: vec![(
                "bad".to_string(),
                Scenario::RandomAccess { zones: vec![9] },
            )],
            scalers: vec![AutoscalerKind::Hpa],
            seeds: vec![1],
            minutes: 1,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let err = run_sweep(&cfg).unwrap_err();
        assert!(format!("{err}").contains("zone 9"));
    }

    #[test]
    fn city_cell_is_deterministic_at_50_zones() {
        // One 50-zone city cell run twice must be bit-identical: same
        // event count and the same response-time stream (the strongest
        // per-cell signal — every float in it).
        let topo = Topology::EdgeCity {
            zones: 50,
            workers_per_zone: 2,
            mix: Default::default(),
        };
        let cluster = topo.cluster();
        let presets = crate::config::city_scenario_presets(50);
        let (name, scenario) = &presets[1]; // city50-flash-mosaic
        let run = |core: CoreKind| {
            let mut world =
                SimWorld::build_with_core(&cluster, TaskCosts::default(), 77, core);
            for gen in scenario.build_generators() {
                world.add_generator(gen);
            }
            for svc in 0..world.app.services.len() {
                world.add_scaler(AutoscalerKind::Hpa.build(), svc);
            }
            let events = world.run_until(3 * MIN);
            // The streaming digest covers every response time bit-exactly.
            (events, world.app.completed(), world.app.stats.fingerprint())
        };
        let (events_a, completed_a, digest_a) = run(CoreKind::Calendar);
        let (events_b, completed_b, digest_b) = run(CoreKind::Calendar);
        assert!(events_a > 500, "{name}: city should be busy ({events_a})");
        assert!(completed_a > 0);
        assert_eq!(events_a, events_b, "event counts must be bit-identical");
        assert_eq!(completed_a, completed_b);
        assert_eq!(digest_a, digest_b, "responses must be bit-identical");
        // And the heap reference core reproduces the same world.
        let (events_h, completed_h, digest_h) = run(CoreKind::Heap);
        assert_eq!(events_a, events_h, "calendar vs heap event count");
        assert_eq!(completed_a, completed_h);
        assert_eq!(digest_a, digest_h, "calendar vs heap response stream");
    }

    #[test]
    fn city_grid_determinism_across_thread_counts() {
        // A small city grid, serial vs parallel: per-cell fingerprints
        // (every deterministic field, incl. topology) must match.
        let grid = |threads| SweepConfig {
            topology: Topology::EdgeCity {
                zones: 8,
                workers_per_zone: 2,
                mix: Default::default(),
            },
            scenarios: crate::config::city_scenario_presets(8)[..2].to_vec(),
            scalers: vec![AutoscalerKind::Hpa, AutoscalerKind::PpaArma],
            seeds: vec![1, 2],
            minutes: 4,
            threads,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let serial = run_sweep(&grid(1)).unwrap();
        let parallel = run_sweep(&grid(4)).unwrap();
        assert_eq!(serial.cells.len(), 2 * 2 * 2);
        assert_eq!(serial.topology, "city-8x2");
        assert!(serial
            .cells
            .iter()
            .all(|c| c.metrics.topology == "city-8x2"));
        assert_eq!(
            fingerprints(&serial),
            fingerprints(&parallel),
            "city cells must be bit-identical regardless of threads"
        );
    }

    #[test]
    fn golden_core_equivalence_paper_grid() {
        // The acceptance contract: sweep results on the calendar core
        // are bit-identical to the heap-based reference core on the
        // paper (Table-2) grid — every deterministic field, fingerprint
        // for fingerprint.
        let grid = |core| SweepConfig {
            seeds: vec![1, 2],
            minutes: 4,
            core,
            ..tiny_config(2)
        };
        let calendar = run_sweep(&grid(CoreKind::Calendar)).unwrap();
        let heap = run_sweep(&grid(CoreKind::Heap)).unwrap();
        assert_eq!(calendar.core, CoreKind::Calendar);
        assert_eq!(heap.core, CoreKind::Heap);
        assert!(calendar.cells.iter().all(|c| c.metrics.completed > 0));
        assert_eq!(
            fingerprints(&calendar),
            fingerprints(&heap),
            "calendar core must reproduce the heap reference on the paper grid"
        );
    }

    #[test]
    fn golden_core_equivalence_city8_grid() {
        let grid = |core| SweepConfig {
            topology: Topology::EdgeCity {
                zones: 8,
                workers_per_zone: 2,
                mix: Default::default(),
            },
            scenarios: crate::config::city_scenario_presets(8)[..2].to_vec(),
            scalers: vec![AutoscalerKind::Hpa, AutoscalerKind::PpaArma],
            seeds: vec![7],
            minutes: 3,
            threads: 2,
            core,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let calendar = run_sweep(&grid(CoreKind::Calendar)).unwrap();
        let heap = run_sweep(&grid(CoreKind::Heap)).unwrap();
        assert!(calendar.cells.iter().all(|c| c.metrics.events > 100));
        assert_eq!(
            fingerprints(&calendar),
            fingerprints(&heap),
            "calendar core must reproduce the heap reference on the city-8 grid"
        );
    }

    #[test]
    fn city_scenarios_rejected_on_paper_topology() {
        // 50-zone scenarios cannot run on the 2-zone Table-2 cluster.
        let cfg = SweepConfig {
            topology: Topology::Paper,
            scenarios: crate::config::city_scenario_presets(50),
            scalers: vec![AutoscalerKind::Hpa],
            seeds: vec![1],
            minutes: 1,
            threads: 1,
            core: CoreKind::Calendar,
            fleet: None,
            shards: 0,
            chaos: FaultPlan::none(),
            sla: None,
        };
        let err = run_sweep(&cfg).unwrap_err();
        assert!(format!("{err}").contains("topology 'paper'"), "{err}");
    }

    #[test]
    fn city8_fleet_cell_scales_heterogeneous_spec_sets() {
        // The acceptance scenario: one city-8 sweep cell drives a fleet
        // where zone-2's deployment scales under cpu:70+req_rate:0.5
        // while everything else runs plain cpu:70 — heterogeneous
        // policies inside a single cell.
        use crate::autoscaler::{MetricSpec, ScalingBehavior};
        use crate::metrics::{M_CPU, M_REQ_RATE};
        let topology = Topology::EdgeCity {
            zones: 8,
            workers_per_zone: 2,
            mix: Default::default(),
        };
        let cluster = topology.cluster();
        let presets = crate::config::city_scenario_presets(8);
        let (name, scenario) = &presets[0]; // city8-diurnal-wave
        let fleet = ScalerRegistry::uniform(ScalerPolicy::default()).with_policy(
            1,
            ScalerPolicy::new(
                vec![
                    MetricSpec::forecast(M_CPU, 70.0),
                    MetricSpec::forecast(M_REQ_RATE, 0.5),
                ],
                ScalingBehavior::stabilize_down(MIN),
            ),
        );
        let cell = run_cell(
            "city-8x2",
            &cluster,
            name,
            scenario,
            AutoscalerKind::PpaNaive,
            Some(&fleet),
            11,
            4,
            CoreKind::Calendar,
            0,
            &FaultPlan::none(),
            None,
        );
        let m = &cell.metrics;
        assert!(m.events > 100, "fleet cell must simulate: {}", m.events);
        assert!(m.completed > 0);
        // 8 edge services + the cloud pool, each labeled with the spec
        // set it actually ran.
        assert_eq!(m.specs.len(), 9);
        assert_eq!(m.specs[0], "cpu:70");
        assert_eq!(m.specs[1], "cpu:70+req_rate:0.5");
        assert!(m.specs[2..].iter().all(|s| s == "cpu:70"));
        // And the fleet axis is part of the deterministic fingerprint.
        assert!(m.fingerprint().contains("req_rate:0.5"));
    }

    #[test]
    fn sharded_cells_are_bit_identical_across_shard_counts() {
        // The tentpole invariant at the cell level: one paper-topology
        // cell, `shards` 1 vs 2 vs 4 — every deterministic field equal.
        let cluster = Topology::Paper.cluster();
        let scenarios = tiny_scenarios();
        let (name, scenario) = &scenarios[0];
        let cell = |shards: usize| {
            run_cell(
                "paper",
                &cluster,
                name,
                scenario,
                AutoscalerKind::Hpa,
                None,
                9,
                5,
                CoreKind::Calendar,
                shards,
                &FaultPlan::none(),
                None,
            )
            .metrics
        };
        let one = cell(1);
        let two = cell(2);
        let four = cell(4);
        assert!(one.events > 100, "sharded cell must simulate: {}", one.events);
        assert!(one.completed > 10);
        assert_eq!(one.fingerprint(), two.fingerprint());
        assert_eq!(one.fingerprint(), four.fingerprint());
        // The sharded schedule is its own world (per-world RNG streams):
        // reproducible, but intentionally not the monolith's bits.
        let mono = cell(0);
        assert_eq!(mono.specs, one.specs);
        assert_eq!(mono.topology, one.topology);
    }

    #[test]
    fn sharded_sweep_reports_shards_in_json() {
        let result = run_sweep(&SweepConfig {
            scenarios: tiny_scenarios()[..1].to_vec(),
            scalers: vec![AutoscalerKind::Hpa],
            seeds: vec![3],
            minutes: 3,
            shards: 2,
            ..tiny_config(1)
        })
        .unwrap();
        assert_eq!(result.shards, 2);
        let doc = result.to_json();
        assert_eq!(doc.get("shards").as_f64(), Some(2.0));
        assert!(result.cells[0].metrics.events > 100);
    }

    #[test]
    fn fleet_registry_changes_decisions() {
        // The second metric must actually drive scaling: a tight
        // req_rate spec on the same world yields decisions ≥ the
        // cpu-only fleet's everywhere, and strictly more pod-time.
        use crate::autoscaler::{MetricSpec, ScalingBehavior};
        use crate::metrics::{M_CPU, M_REQ_RATE};
        let topology = Topology::EdgeCity {
            zones: 8,
            workers_per_zone: 2,
            mix: Default::default(),
        };
        let cluster = topology.cluster();
        let presets = crate::config::city_scenario_presets(8);
        let (_, scenario) = &presets[0];
        let run = |fleet: &ScalerRegistry| {
            let mut world = SimWorld::build_with_core(
                &cluster,
                TaskCosts::default(),
                7,
                CoreKind::Calendar,
            );
            world.record_decisions();
            for gen in scenario.build_generators() {
                world.add_generator(gen);
            }
            for svc in 0..world.app.services.len() {
                world.add_scaler(
                    AutoscalerKind::PpaNaive.build_with(fleet.policy_for(svc), 7),
                    svc,
                );
            }
            world.run_until(4 * MIN);
            world
        };
        let cpu_only = ScalerRegistry::uniform(ScalerPolicy::default());
        let hot = ScalerRegistry::uniform(ScalerPolicy::new(
            vec![
                MetricSpec::forecast(M_CPU, 70.0),
                MetricSpec::forecast(M_REQ_RATE, 0.2),
            ],
            ScalingBehavior::stabilize_down(2 * MIN),
        ));
        let base = run(&cpu_only);
        let multi = run(&hot);
        let sum = |w: &SimWorld| -> usize { w.decision_log.iter().map(|d| d.desired).sum() };
        assert!(
            sum(&multi) > sum(&base),
            "the req_rate spec must add replicas: {} vs {}",
            sum(&multi),
            sum(&base)
        );
        // Per-metric provenance: multi-spec decisions carry 2 recs.
        assert!(multi.decision_log.iter().all(|d| d.recommendations.len() == 2));
        assert!(base.decision_log.iter().all(|d| d.recommendations.len() == 1));
    }

    #[test]
    fn faulted_cell_reports_counters_and_reproduces() {
        // A faulted monolith cell: fault counters surface in the
        // metrics/JSON, and the whole cell is bit-reproducible.
        let cluster = Topology::Paper.cluster();
        let scenarios = tiny_scenarios();
        let (name, scenario) = &scenarios[0];
        let chaos = crate::config::chaos_preset("full-storm").unwrap();
        let cell = |shards: usize| {
            run_cell(
                "paper",
                &cluster,
                name,
                scenario,
                AutoscalerKind::Hpa,
                None,
                21,
                6,
                CoreKind::Calendar,
                shards,
                &chaos,
                None,
            )
            .metrics
        };
        let a = cell(0);
        let b = cell(0);
        assert_eq!(a.chaos, "crash+coldstart+crashloop+netdelay");
        assert!(a.crashes > 0, "storm must crash nodes: {a:?}");
        assert!(a.downtime_secs > 0.0);
        assert!(a.events > 100 && a.completed > 0);
        assert_eq!(a.fingerprint(), b.fingerprint(), "faulted cell must reproduce");
        // The JSON schema carries the fault columns.
        let result = CellResult {
            metrics: a.clone(),
            wall_secs: 0.0,
        };
        let doc = result.to_json();
        assert_eq!(doc.get("chaos").as_str(), Some("crash+coldstart+crashloop+netdelay"));
        assert_eq!(doc.get("crashes").as_f64(), Some(a.crashes as f64));
        assert!(doc.get("downtime_secs").as_f64().unwrap() > 0.0);
        // And the sharded engine reproduces its own faulted schedule.
        let s1 = cell(1);
        let s2 = cell(2);
        assert_eq!(s1.fingerprint(), s2.fingerprint(), "faulted shards 1 vs 2");
        assert!(s1.crashes > 0);
    }

    #[test]
    fn autoscaler_kind_parse() {
        assert_eq!(AutoscalerKind::parse("hpa").unwrap(), AutoscalerKind::Hpa);
        assert_eq!(
            AutoscalerKind::parse("arma").unwrap(),
            AutoscalerKind::PpaArma
        );
        assert_eq!(
            AutoscalerKind::parse("hybrid").unwrap(),
            AutoscalerKind::Hybrid
        );
        assert!(AutoscalerKind::parse("lstm").is_err());
        let err = AutoscalerKind::parse("lstm").unwrap_err();
        assert!(format!("{err}").contains("hybrid"), "{err}");
    }

    #[test]
    fn sla_cell_reports_resilience_columns() {
        // One SLA'd hybrid cell on the paper topology: the resilience
        // columns surface (and reproduce), and a plain cell keeps the
        // pre-resilience shape — label `none`, zero counters, null
        // hybrid columns, empty per-class table.
        use crate::app::SlaPolicy;
        use crate::sim::{MS, SEC};
        let cluster = Topology::Paper.cluster();
        let scenarios = tiny_scenarios();
        let (name, scenario) = &scenarios[0];
        let sla = SlaConfig::new(SlaPolicy {
            deadline: 2 * SEC,
            max_retries: 1,
            backoff_base: 100 * MS,
            shed_queue_depth: 4,
        });
        let cell = |scaler: AutoscalerKind, sla: Option<&SlaConfig>| {
            run_cell(
                "paper",
                &cluster,
                name,
                scenario,
                scaler,
                None,
                13,
                6,
                CoreKind::Calendar,
                0,
                &FaultPlan::none(),
                sla,
            )
            .metrics
        };
        let a = cell(AutoscalerKind::Hybrid, Some(&sla));
        let b = cell(AutoscalerKind::Hybrid, Some(&sla));
        assert_eq!(a.fingerprint(), b.fingerprint(), "SLA'd hybrid cell must reproduce");
        assert_eq!(a.scaler, "hybrid");
        assert_eq!(a.sla, "d2000ms:r1:b100ms:q4@0.1:0.7:0.2");
        assert!(a.sla_timeouts > 0, "2s deadline must expire under surge: {a:?}");
        assert_eq!(a.class_response.len(), 3);
        assert_eq!(a.class_response[0].0, "critical");
        assert!(a.hybrid_trips.is_some(), "hybrid cells report the trip counter");
        assert!(a.cost_node_hours > 0.0);
        assert!(a.pod_churn > 0, "initial pods count as churn");
        let doc = CellResult {
            metrics: a.clone(),
            wall_secs: 0.0,
        }
        .to_json();
        assert_eq!(doc.get("sla").as_str(), Some("d2000ms:r1:b100ms:q4@0.1:0.7:0.2"));
        assert_eq!(doc.get("sla_timeouts").as_f64(), Some(a.sla_timeouts as f64));
        assert!(doc.get("class_response").get("critical").get("n").as_f64().is_some());
        assert!(doc.get("cost_node_hours").as_f64().unwrap() > 0.0);
        assert!(doc.get("hybrid_trips").as_f64().is_some());

        let plain = cell(AutoscalerKind::Hpa, None);
        assert_eq!(plain.sla, "none");
        assert_eq!(plain.sla_timeouts + plain.sla_violations + plain.sla_shed, 0);
        assert!(plain.class_response.is_empty());
        assert_eq!(plain.hybrid_trips, None);
        let doc = CellResult {
            metrics: plain,
            wall_secs: 0.0,
        }
        .to_json();
        assert_eq!(doc.get("sla").as_str(), Some("none"));
        assert!(matches!(doc.get("hybrid_trips"), &Json::Null));
    }
}

//! The rule registry and checkers.
//!
//! Every rule encodes one clause of the repo's determinism contract —
//! the dynamic property (bit-identical decision logs and response
//! fingerprints across `QueryMode`, `CoreKind`, seeds, and thread
//! counts) restated as a *static* invariant a token scan can enforce:
//!
//! * **D1** — simulation modules read no wall clock (`Instant`,
//!   `SystemTime`), no `std::env`, and no randomness source other than
//!   the seeded `util::rng` streams.
//! * **D2** — simulation modules never traverse a `HashMap`/`HashSet`
//!   (`.iter()`, `.keys()`, `.values()`, `for … in`): iteration order is
//!   nondeterministic. Lookups are fine.
//! * **N1** — index-invariant nexus methods (`Cluster::set_phase`,
//!   `Node::bind`/`unbind`) and the request-arena type may only be
//!   named inside their owning module, so a new call site can't bypass
//!   the incremental indices.
//! * **P1** — no `unwrap()`/`expect()`/`panic!`-family macros on the
//!   arrival→complete hot path outside `#[cfg(test)]` items and
//!   `debug_assert!` arguments.
//! * **S1** — suppression pragmas (`// detlint: allow(D1) — reason`)
//!   must name known rules and carry a reason.
//!
//! Suppression scope: a *trailing* pragma covers its own line; a
//! *standalone* pragma covers the next item (through the close of its
//! first top-level brace block, or its terminating `;`). Doc comments
//! are never pragmas.

use crate::diagnostics::{finalize, Diagnostic};
use crate::lexer::{lex, Lexed, Tok, TokKind};

/// One registry entry, surfaced by `--list-rules`.
pub struct Rule {
    pub id: &'static str,
    pub title: &'static str,
    pub scope: &'static str,
    pub rationale: &'static str,
}

pub const RULES: &[Rule] = &[
    Rule {
        id: "D1",
        title: "no wall clock, std::env, or ambient randomness in simulation modules",
        scope: "rust/src/{sim,app,cluster,autoscaler,workload,metrics,forecast,config,experiments,stats,util}",
        rationale: "sim state must be a pure function of (config, seed); a clock or env read \
                    makes replays diverge silently",
    },
    Rule {
        id: "D2",
        title: "no order-dependent traversal of HashMap/HashSet in simulation modules",
        scope: "same modules as D1",
        rationale: "hash iteration order varies across runs and toolchains; lookups are fine, \
                    traversal must use Vec/BTree collections",
    },
    Rule {
        id: "N1",
        title: "index-invariant nexus methods only named in their owning module",
        scope: "all scanned files",
        rationale: "set_phase / Node::bind / Node::unbind / RequestArena maintain incremental \
                    indices; an outside call site could desynchronize them from the scan baseline",
    },
    Rule {
        id: "P1",
        title: "no unwrap/expect/panic on the arrival→complete hot path",
        scope: "rust/src/{sim,app,cluster} + the per-tick forecaster zoo",
        rationale: "a panic mid-run tears down city-scale simulations; hot-path code handles \
                    its None/Err arms (test modules and debug_assert! arguments exempt)",
    },
    Rule {
        id: "S1",
        title: "suppression pragmas name known rules and carry a reason",
        scope: "all scanned files",
        rationale: "`// detlint: allow(RULE, …) — reason` keeps escapes visible and auditable; \
                    unknown rules or missing reasons are rejected",
    },
];

/// Rules a pragma may suppress (S1 itself is not suppressible).
const SUPPRESSIBLE: &[&str] = &["D1", "D2", "N1", "P1"];

/// Modules under the determinism contract (D1/D2).
const SIM_SCOPE: &[&str] = &[
    "rust/src/sim/",
    "rust/src/app/",
    "rust/src/cluster/",
    "rust/src/autoscaler/",
    "rust/src/workload/",
    "rust/src/metrics/",
    "rust/src/forecast/",
    "rust/src/config/",
    "rust/src/experiments/",
    "rust/src/stats/",
    "rust/src/util/",
];

/// The arrival→complete hot path (P1). The `rust/src/sim/` prefix
/// covers the sharded engine (`sim/shard.rs`) too: its cross-shard
/// channels (`Mutex`, `Barrier`, scoped threads) are not banned tokens,
/// but its lock handling must stay panic-free — poisoned locks are
/// recovered with `into_inner`, never `.lock().unwrap()`. The four
/// zoo files run inside every PPA tick (`evaluate` → predict/observe,
/// and the selector's review loop), so they are hot path too; the
/// PJRT-backed `forecast/lstm.rs` is not listed — it never enters the
/// simulation loop without an explicit `--model lstm` opt-in and its
/// FFI layer has its own error contract. The resilience plane rides the
/// same path: deadline timeouts, retry scheduling and shedding live in
/// `rust/src/app/` (already covered), and the hybrid scaler's
/// override/guard logic (`autoscaler/hybrid.rs`) runs inside every
/// scaler tick, so it is listed individually like the zoo files.
const HOT_SCOPE: &[&str] = &[
    "rust/src/sim/",
    "rust/src/app/",
    "rust/src/cluster/",
    "rust/src/autoscaler/hybrid.rs",
    "rust/src/forecast/selector.rs",
    "rust/src/forecast/holt_winters.rs",
    "rust/src/forecast/tcn.rs",
    "rust/src/forecast/lstm_cell.rs",
];

/// Nondeterministic randomness identifiers (anything outside `util::rng`).
const RNG_IDENTS: &[&str] = &[
    "thread_rng",
    "getrandom",
    "from_entropy",
    "RandomState",
    "OsRng",
    "StdRng",
    "SmallRng",
];

const HASH_TYPES: &[&str] = &["HashMap", "HashSet"];

/// Methods that traverse (or drain) a hash collection in storage order.
const TRAVERSAL_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// An N1 nexus: `name` may only appear in `allowed` files.
struct Nexus {
    name: &'static str,
    owner: &'static str,
    /// `true`: every mention of the identifier counts (types);
    /// `false`: only call/definition positions (methods).
    is_type: bool,
    allowed: &'static [&'static str],
}

const NODE_FILES: &[&str] = &[
    "rust/src/cluster/node.rs",
    "rust/src/cluster/mod.rs",
    "rust/src/cluster/scheduler.rs",
];

/// The chaos plane's `crash_node` force-kills pods through the same
/// nexuses (`set_phase` to `Gone`, `Node::unbind`) while maintaining
/// the incremental indices — `Cluster::verify_indices()` covers it in
/// the recovery battery — so `cluster/chaos.rs` is a sanctioned owner
/// file, not a bypass.
const PHASE_FILES: &[&str] = &["rust/src/cluster/mod.rs", "rust/src/cluster/chaos.rs"];

const UNBIND_FILES: &[&str] = &[
    "rust/src/cluster/node.rs",
    "rust/src/cluster/mod.rs",
    "rust/src/cluster/scheduler.rs",
    "rust/src/cluster/chaos.rs",
];

const NEXUSES: &[Nexus] = &[
    Nexus {
        name: "set_phase",
        owner: "Cluster",
        is_type: false,
        allowed: PHASE_FILES,
    },
    Nexus {
        name: "bind",
        owner: "Node",
        is_type: false,
        allowed: NODE_FILES,
    },
    Nexus {
        name: "unbind",
        owner: "Node",
        is_type: false,
        allowed: UNBIND_FILES,
    },
    Nexus {
        name: "RequestArena",
        owner: "App",
        is_type: true,
        allowed: &["rust/src/app/arena.rs", "rust/src/app/mod.rs"],
    },
];

/// Token text at `i`, or `""` past the end.
fn t(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

/// Token text before `i`, or `""` at the start.
fn before(toks: &[Tok], i: usize) -> &str {
    if i == 0 {
        ""
    } else {
        t(toks, i - 1)
    }
}

fn is_ident_at(toks: &[Tok], i: usize) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Ident)
}

/// Index of the bracket matching the opener at `open` (any of `(`/`[`/`{`),
/// or the last token if unterminated.
fn matching(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (i, tok) in toks.iter().enumerate().skip(open) {
        match tok.text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

/// Index of the last token of the item starting at `i` (leading outer
/// attributes are part of the item): the close of its first top-level
/// brace block, or its terminating `;`.
fn item_end(toks: &[Tok], mut i: usize) -> usize {
    while t(toks, i) == "#" && t(toks, i + 1) == "[" {
        i = matching(toks, i + 1) + 1;
    }
    let mut depth = 0i32;
    let mut braced = false;
    while i < toks.len() {
        match t(toks, i) {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "{" => {
                depth += 1;
                if depth == 1 {
                    braced = true;
                }
            }
            "}" => {
                depth -= 1;
                if depth == 0 && braced {
                    return i;
                }
            }
            ";" if depth == 0 => return i,
            _ => {}
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Mark every token inside a `#[cfg(test)]` or `#[cfg(debug_assertions)]`
/// item: compiled out of release builds, exempt from D1/D2/P1.
fn cfg_exempt(toks: &[Tok]) -> Vec<bool> {
    let mut exempt = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if t(toks, i) == "#"
            && t(toks, i + 1) == "["
            && t(toks, i + 2) == "cfg"
            && t(toks, i + 3) == "("
        {
            let close = matching(toks, i + 3);
            let inner: Vec<&str> = (i + 4..close).map(|k| t(toks, k)).collect();
            if inner == ["test"] || inner == ["debug_assertions"] {
                let end = item_end(toks, i);
                for flag in exempt.iter_mut().take(end + 1).skip(i) {
                    *flag = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    exempt
}

/// Mark every token inside a `debug_assert*!(…)` invocation: debug-only,
/// exempt from P1.
fn debug_assert_exempt(toks: &[Tok]) -> Vec<bool> {
    let mut exempt = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if is_ident_at(toks, i)
            && toks[i].text.starts_with("debug_assert")
            && t(toks, i + 1) == "!"
            && matches!(t(toks, i + 2), "(" | "[" | "{")
        {
            let close = matching(toks, i + 2);
            for flag in exempt.iter_mut().take(close + 1).skip(i) {
                *flag = true;
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    exempt
}

/// A parsed `// detlint: allow(…) — reason` pragma's effect.
struct Suppression {
    rule: String,
    from: u32,
    to: u32,
}

/// Parse suppression pragmas out of the comment stream. Returns the
/// active suppressions plus S1 diagnostics for malformed ones.
fn parse_pragmas(lexed: &Lexed, rel_path: &str) -> (Vec<Suppression>, Vec<Diagnostic>) {
    let toks = &lexed.toks;
    let mut sups = Vec::new();
    let mut diags = Vec::new();
    let mut s1 = |line: u32, message: String| {
        diags.push(Diagnostic {
            path: rel_path.to_string(),
            line,
            rule: "S1",
            message,
        });
    };
    for c in &lexed.comments {
        if c.doc {
            continue; // doc comments are prose, never pragmas
        }
        let body = c.text.trim();
        let Some(rest) = body.strip_prefix("detlint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let directive = rest.strip_prefix("allow").map(str::trim_start);
        let Some(args) = directive.and_then(|d| d.strip_prefix('(')) else {
            s1(
                c.line,
                "malformed pragma (expected `detlint: allow(RULE, …) — reason`)".to_string(),
            );
            continue;
        };
        let Some(close) = args.find(')') else {
            s1(c.line, "unterminated rule list in pragma".to_string());
            continue;
        };
        let ids: Vec<&str> = args[..close]
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        let reason = args[close + 1..]
            .trim_start_matches(|ch: char| matches!(ch, ' ' | '\t' | ':' | '-' | '—' | '–'))
            .trim();
        if ids.is_empty() {
            s1(c.line, "pragma suppresses no rules".to_string());
            continue;
        }
        let mut ok = true;
        for id in &ids {
            if !SUPPRESSIBLE.contains(id) {
                ok = false;
                s1(
                    c.line,
                    format!(
                        "unknown or non-suppressible rule `{id}` in pragma (suppressible: {})",
                        SUPPRESSIBLE.join(", ")
                    ),
                );
            }
        }
        if reason.is_empty() {
            ok = false;
            s1(
                c.line,
                "suppression needs a reason (`detlint: allow(RULE) — why this escape is sound`)"
                    .to_string(),
            );
        }
        if !ok {
            continue; // a rejected pragma suppresses nothing
        }
        let to = if c.trailing {
            c.line
        } else {
            // Standalone: cover the next item.
            match toks.iter().position(|t| t.line > c.line) {
                Some(first) => toks[item_end(toks, first)].line,
                None => c.line,
            }
        };
        for id in ids {
            sups.push(Suppression {
                rule: id.to_string(),
                from: c.line,
                to,
            });
        }
    }
    (sups, diags)
}

fn in_scope(rel_path: &str, scope: &[&str]) -> bool {
    scope.iter().any(|p| rel_path.starts_with(p))
}

/// Candidate violation: token index + rule id + message.
type Candidate = (usize, &'static str, String);

fn check_d1(toks: &[Tok], out: &mut Vec<Candidate>) {
    for (i, tok) in toks.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        let name = tok.text.as_str();
        match name {
            "Instant" | "SystemTime" | "UNIX_EPOCH" => out.push((
                i,
                "D1",
                format!(
                    "wall-clock source `{name}` in a simulation module — sim code derives time \
                     from `sim::Time`; harness timing goes through `util::wallclock()`"
                ),
            )),
            "env" if i >= 2 && t(toks, i - 2) == "std" && t(toks, i - 1) == "::" => out.push((
                i,
                "D1",
                "`std::env` in a simulation module — configuration must arrive through explicit \
                 config structs, not ambient process state"
                    .to_string(),
            )),
            "rand" if t(toks, i + 1) == "::" => out.push((
                i,
                "D1",
                "`rand` crate path in a simulation module — use the seeded `util::rng::Pcg64` \
                 streams"
                    .to_string(),
            )),
            _ if RNG_IDENTS.contains(&name) => out.push((
                i,
                "D1",
                format!(
                    "nondeterministic randomness source `{name}` — use the seeded \
                     `util::rng::Pcg64` streams"
                ),
            )),
            _ => {}
        }
    }
}

/// Names bound to a hash-collection type in this file: struct fields and
/// parameters (`name: HashMap<…>`, through `&`/`mut`/paths/`Option<`),
/// and `let` bindings whose initializer statement mentions `HashMap::`.
fn hash_bound_names(toks: &[Tok]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if is_ident_at(toks, i) && t(toks, i + 1) == ":" {
            let mut j = i + 2;
            for _ in 0..8 {
                if toks.get(j).is_some_and(|t| t.kind == TokKind::Lifetime) {
                    j += 1;
                    continue;
                }
                match t(toks, j) {
                    "&" | "mut" | "std" | "collections" | "::" | "<" | "Option" | "Box" => j += 1,
                    ty if HASH_TYPES.contains(&ty) => {
                        names.push(toks[i].text.clone());
                        break;
                    }
                    _ => break,
                }
            }
        }
        if t(toks, i) == "let" {
            let mut j = i + 1;
            if t(toks, j) == "mut" {
                j += 1;
            }
            if !is_ident_at(toks, j) {
                continue;
            }
            let name = toks[j].text.clone();
            let mut depth = 0i32;
            let mut k = j + 1;
            while k < toks.len() {
                match t(toks, k) {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => {
                        if depth == 0 {
                            break;
                        }
                        depth -= 1;
                    }
                    ";" if depth == 0 => break,
                    ty if HASH_TYPES.contains(&ty) => {
                        names.push(name.clone());
                    }
                    _ => {}
                }
                k += 1;
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

fn check_d2(toks: &[Tok], out: &mut Vec<Candidate>) {
    let names = hash_bound_names(toks);
    let is_hashy = |i: usize| -> bool {
        toks.get(i).is_some_and(|tok| {
            tok.kind == TokKind::Ident
                && (HASH_TYPES.contains(&tok.text.as_str())
                    || names.binary_search(&tok.text).is_ok())
        })
    };
    for i in 0..toks.len() {
        // `name.iter()` / `name.keys()` / … on a hash-bound name.
        if is_hashy(i)
            && t(toks, i + 1) == "."
            && is_ident_at(toks, i + 2)
            && TRAVERSAL_METHODS.contains(&t(toks, i + 2))
            && t(toks, i + 3) == "("
        {
            out.push((
                i + 2,
                "D2",
                format!(
                    "order-dependent traversal `{}.{}()` of a hash collection — hash iteration \
                     order is nondeterministic; traverse a Vec/BTree index instead (lookups are \
                     fine)",
                    toks[i].text,
                    t(toks, i + 2),
                ),
            ));
        }
        // `for … in <expr naming a hash collection> { … }`.
        if t(toks, i) == "for" {
            let mut depth = 0i32;
            let mut j = i + 1;
            let mut in_at = None;
            while j < toks.len() {
                match t(toks, j) {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" | ";" if depth == 0 => break,
                    "in" if depth == 0 => {
                        in_at = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            let Some(start) = in_at else { continue };
            let mut depth = 0i32;
            let mut k = start + 1;
            while k < toks.len() {
                match t(toks, k) {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => break,
                    _ => {}
                }
                if is_hashy(k) {
                    out.push((
                        k,
                        "D2",
                        format!(
                            "`for … in` over hash collection `{}` — iteration order is \
                             nondeterministic; traverse a Vec/BTree index instead",
                            toks[k].text
                        ),
                    ));
                }
                k += 1;
            }
        }
    }
}

fn check_n1(rel_path: &str, toks: &[Tok], out: &mut Vec<Candidate>) {
    for nexus in NEXUSES {
        if nexus.allowed.contains(&rel_path) {
            continue;
        }
        for (i, tok) in toks.iter().enumerate() {
            if tok.kind != TokKind::Ident || tok.text != nexus.name {
                continue;
            }
            let named = nexus.is_type
                || t(toks, i + 1) == "("
                || matches!(before(toks, i), "." | "::" | "fn");
            if named {
                out.push((
                    i,
                    "N1",
                    format!(
                        "`{}` is an index-invariant nexus owned by `{}` — it may only be named \
                         in {}",
                        nexus.name,
                        nexus.owner,
                        nexus.allowed.join(", ")
                    ),
                ));
            }
        }
    }
}

fn check_p1(toks: &[Tok], out: &mut Vec<Candidate>) {
    for (i, tok) in toks.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        let name = tok.text.as_str();
        if (name == "unwrap" || name == "expect")
            && before(toks, i) == "."
            && t(toks, i + 1) == "("
        {
            out.push((
                i,
                "P1",
                format!(
                    "`.{name}()` on the arrival→complete hot path — handle the None/Err arm \
                     explicitly (a panic tears down the whole city-scale run)"
                ),
            ));
        }
        if PANIC_MACROS.contains(&name) && t(toks, i + 1) == "!" {
            out.push((
                i,
                "P1",
                format!("`{name}!` on the arrival→complete hot path — must not panic"),
            ));
        }
    }
}

/// Lint one file. `rel_path` is repo-relative with forward slashes.
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let (sups, mut meta) = parse_pragmas(&lexed, rel_path);
    let cfg_ex = cfg_exempt(toks);
    let dbg_ex = debug_assert_exempt(toks);

    let mut cands: Vec<Candidate> = Vec::new();
    if in_scope(rel_path, SIM_SCOPE) {
        check_d1(toks, &mut cands);
        check_d2(toks, &mut cands);
    }
    check_n1(rel_path, toks, &mut cands);
    if in_scope(rel_path, HOT_SCOPE) {
        check_p1(toks, &mut cands);
    }

    let mut diags = Vec::new();
    for (idx, rule, message) in cands {
        // Test / debug-only items never run in a release simulation.
        // (N1 stays live there: an index bypass in a test still
        // invalidates the scan-vs-indexed equivalence it asserts.)
        if rule != "N1" && cfg_ex.get(idx).copied().unwrap_or(false) {
            continue;
        }
        if rule == "P1" && dbg_ex.get(idx).copied().unwrap_or(false) {
            continue;
        }
        let line = toks[idx].line;
        if sups
            .iter()
            .any(|s| s.rule == rule && s.from <= line && line <= s.to)
        {
            continue;
        }
        diags.push(Diagnostic {
            path: rel_path.to_string(),
            line,
            rule,
            message,
        });
    }
    diags.append(&mut meta);
    finalize(diags)
}

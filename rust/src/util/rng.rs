//! Deterministic PRNG streams for the simulator (no `rand` crate offline).
//!
//! PCG64 (XSL-RR 128/64) — the same generator family numpy defaults to.
//! Every simulator subsystem takes its own seeded [`Pcg64`] stream so runs
//! are reproducible and subsystems are statistically independent.

/// PCG XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | (stream as u128 ^ 0xda3e39cb94b95bdb);
        let mut rng = Pcg64 {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128 ^ 0x5851f42d4c957f2d);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi) (half-open), like `Range(lo, hi)`.
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty int_range");
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean 1/rate). Used for Poisson arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 50.0 {
            let x = self.normal_ms(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3, 0);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Pcg64::new(11, 0);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(5, 0);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn int_range_bounds() {
        let mut r = Pcg64::new(9, 0);
        for _ in 0..1000 {
            let x = r.int_range(20, 200);
            assert!((20..200).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13, 0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(17, 0);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = Pcg64::new(19, 0);
        for lambda in [0.5, 4.0, 80.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut r = Pcg64::new(23, 0);
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }
}

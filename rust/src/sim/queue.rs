//! The event queue: a deterministic calendar (bucket) queue with a
//! retained binary-heap reference backend.
//!
//! # Ordering contract
//!
//! Both backends pop events in strictly ascending `(time, seq)` order,
//! where `seq` is the global schedule counter: simultaneous events
//! dispatch in insertion (FIFO) order. This is the determinism contract
//! the whole simulator rests on — swapping backends never changes a
//! run's event order, which is asserted by
//! `prop_calendar_queue_matches_heap_reference` (tests/properties.rs)
//! and by the golden core-equivalence sweep tests.
//!
//! # Calendar queue layout
//!
//! The default [`CoreKind::Calendar`] backend is a near-future time
//! wheel over fixed-width buckets plus a sorted overflow map:
//!
//! * **Wheel** — `NUM_BUCKETS` (4096) slots of `2^BUCKET_SHIFT` µs
//!   (≈0.52 s) each, covering a ≈36-minute horizon past the cursor.
//!   Scheduling into the wheel is an O(1) unsorted push; a bucket is
//!   sorted once, when the cursor reaches it.
//! * **Current bucket** — entries at or before the cursor, kept sorted
//!   descending by `(time, seq)` so the minimum pops from the back.
//!   Events scheduled at (or clamped to) `now` binary-search into this
//!   run, preserving FIFO order within a timestamp.
//! * **Overflow** — a `BTreeMap<(Time, seq), Event>` for events beyond
//!   the wheel horizon (e.g. hour-scale model-update ticks). Invariant:
//!   every overflow entry is later than every wheel entry; entries are
//!   drained into wheel slots as the cursor advances and the horizon
//!   grows, so each entry moves at most once.
//!
//! Past-time schedules are clamped to `now` (dispatching next, in FIFO
//! order) — identical under both backends.

use super::{Event, Time};
use anyhow::bail;
use std::cell::Cell;
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BinaryHeap};
use std::mem;

/// Which event-queue backend a simulation core runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CoreKind {
    /// The calendar/bucket queue (the fast default).
    #[default]
    Calendar,
    /// The `BinaryHeap` reference core, retained for golden-equivalence
    /// tests and old-vs-new benchmarks.
    Heap,
}

impl CoreKind {
    pub const ALL: [CoreKind; 2] = [CoreKind::Calendar, CoreKind::Heap];

    pub fn name(&self) -> &'static str {
        match self {
            CoreKind::Calendar => "calendar",
            CoreKind::Heap => "heap",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "calendar" => Ok(CoreKind::Calendar),
            "heap" => Ok(CoreKind::Heap),
            other => bail!("unknown core '{other}' (calendar|heap)"),
        }
    }
}

/// Wheel-bucket width: `2^19` µs ≈ 0.52 s.
const BUCKET_SHIFT: u32 = 19;
/// Wheel size (power of two); horizon = `NUM_BUCKETS << BUCKET_SHIFT`
/// ≈ 36 simulated minutes past the cursor.
const NUM_BUCKETS: usize = 4096;
const BUCKET_MASK: u64 = NUM_BUCKETS as u64 - 1;

#[derive(Debug)]
struct Entry {
    time: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-time semantics on BinaryHeap (a max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The calendar backend. See the module docs for the layout invariants.
#[derive(Debug)]
struct CalendarQueue {
    /// Wheel slot `i` holds (unsorted) entries of the unique absolute
    /// bucket `b` with `b & BUCKET_MASK == i` and
    /// `cursor < b < cursor + NUM_BUCKETS`.
    slots: Vec<Vec<Entry>>,
    /// Entries of buckets at or before the cursor, sorted descending by
    /// `(time, seq)`: the global minimum is `current.last()`.
    current: Vec<Entry>,
    /// Absolute bucket index the cursor is on (monotonically advancing).
    cursor: u64,
    /// Number of entries across all wheel slots.
    wheel_len: usize,
    /// Beyond-horizon entries, ordered by `(time, seq)`.
    overflow: BTreeMap<(Time, u64), Event>,
    /// Scan accelerator. Invariant: no wheel slot holds an entry of a
    /// bucket `b` with `cursor < b < scan_hint` — so peeks and cursor
    /// advances may start at `max(cursor + 1, scan_hint)` instead of
    /// walking every empty slot. Lowered on wheel inserts
    /// (schedule/refill), tightened by peek scans (`Cell`: peeking is
    /// `&self`). A stale-low hint only costs scan time, never order.
    scan_hint: Cell<u64>,
}

impl CalendarQueue {
    fn new() -> Self {
        CalendarQueue {
            slots: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            current: Vec::new(),
            cursor: 0,
            wheel_len: 0,
            overflow: BTreeMap::new(),
            scan_hint: Cell::new(0),
        }
    }

    fn len(&self) -> usize {
        self.current.len() + self.wheel_len + self.overflow.len()
    }

    fn schedule(&mut self, entry: Entry) {
        let bucket = entry.time >> BUCKET_SHIFT;
        if bucket <= self.cursor {
            // At-or-before the cursor (now-clamped or current-bucket
            // events): keep `current` sorted. The entry's fresh `seq` is
            // the largest ever issued, so among equal timestamps it lands
            // closest to the front — popping last, preserving FIFO.
            let key = (entry.time, entry.seq);
            let idx = self.current.partition_point(|e| (e.time, e.seq) > key);
            self.current.insert(idx, entry);
        } else if bucket < self.cursor + NUM_BUCKETS as u64 {
            self.slots[(bucket & BUCKET_MASK) as usize].push(entry);
            self.wheel_len += 1;
            self.scan_hint.set(self.scan_hint.get().min(bucket));
        } else {
            self.overflow.insert((entry.time, entry.seq), entry.event);
        }
    }

    /// Move overflow entries that now fit under the wheel horizon into
    /// their slots. Called whenever the cursor advances.
    fn refill_from_overflow(&mut self) {
        let horizon = self.cursor + NUM_BUCKETS as u64;
        while let Some(first) = self.overflow.first_entry() {
            if first.key().0 >> BUCKET_SHIFT >= horizon {
                break;
            }
            let ((time, seq), event) = first.remove_entry();
            let bucket = time >> BUCKET_SHIFT;
            self.slots[(bucket & BUCKET_MASK) as usize].push(Entry { time, seq, event });
            self.wheel_len += 1;
            self.scan_hint.set(self.scan_hint.get().min(bucket));
        }
    }

    /// Advance the cursor to the next non-empty bucket and sort it into
    /// `current`. Returns false when the queue is empty.
    fn advance(&mut self) -> bool {
        debug_assert!(self.current.is_empty());
        loop {
            if self.wheel_len == 0 {
                // Jump straight to the first overflow bucket (skipping a
                // potentially huge run of empty wheel rotations).
                let Some((&(time, _), _)) = self.overflow.first_key_value() else {
                    return false;
                };
                self.cursor = time >> BUCKET_SHIFT;
            } else {
                // By the scan-hint invariant there is nothing between
                // cursor and the hint — skip the empty run in one step.
                self.cursor = (self.cursor + 1).max(self.scan_hint.get());
            }
            self.refill_from_overflow();
            let slot = &mut self.slots[(self.cursor & BUCKET_MASK) as usize];
            if !slot.is_empty() {
                // Swap rather than take: the drained slot inherits the
                // old `current`'s capacity, recycling allocations.
                mem::swap(&mut self.current, slot);
                self.wheel_len -= self.current.len();
                self.current
                    .sort_unstable_by_key(|e| Reverse((e.time, e.seq)));
                return true;
            }
        }
    }

    /// Pop the next entry if its time is `<= limit`.
    fn pop_due(&mut self, limit: Time) -> Option<Entry> {
        loop {
            if let Some(e) = self.current.last() {
                if e.time > limit {
                    return None;
                }
                return self.current.pop();
            }
            if !self.advance() {
                return None;
            }
        }
    }

    fn peek_time(&self) -> Option<Time> {
        if let Some(e) = self.current.last() {
            return Some(e.time);
        }
        if self.wheel_len > 0 {
            // Start at the hint (nothing lives below it) and tighten it
            // to the first non-empty bucket, so repeated peeks are O(1).
            let start = self.scan_hint.get().max(self.cursor + 1);
            for bucket in start..self.cursor + NUM_BUCKETS as u64 {
                let slot = &self.slots[(bucket & BUCKET_MASK) as usize];
                if let Some(t) = slot.iter().map(|e| e.time).min() {
                    self.scan_hint.set(bucket);
                    return Some(t);
                }
            }
        }
        self.overflow.keys().next().map(|&(t, _)| t)
    }
}

#[derive(Debug)]
enum Backend {
    Calendar(CalendarQueue),
    Heap(BinaryHeap<Entry>),
}

/// Deterministic min-time event queue (see the module docs for the
/// ordering contract and the calendar layout).
#[derive(Debug)]
pub struct EventQueue {
    backend: Backend,
    seq: u64,
    now: Time,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl EventQueue {
    /// A queue on the default calendar core.
    pub fn new() -> Self {
        EventQueue::with_core(CoreKind::Calendar)
    }

    /// A queue on an explicit core (the heap core is the golden
    /// reference for equivalence tests and benchmarks).
    pub fn with_core(core: CoreKind) -> Self {
        let backend = match core {
            CoreKind::Calendar => Backend::Calendar(CalendarQueue::new()),
            CoreKind::Heap => Backend::Heap(BinaryHeap::with_capacity(4096)),
        };
        EventQueue {
            backend,
            seq: 0,
            now: 0,
        }
    }

    /// Which backend this queue runs on.
    pub fn core(&self) -> CoreKind {
        match self.backend {
            Backend::Calendar(_) => CoreKind::Calendar,
            Backend::Heap(_) => CoreKind::Heap,
        }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Events scheduled in the
    /// past are clamped to `now` (dispatching immediately, in order).
    pub fn schedule_at(&mut self, at: Time, event: Event) {
        let time = at.max(self.now);
        let entry = Entry {
            time,
            seq: self.seq,
            event,
        };
        self.seq += 1;
        match &mut self.backend {
            Backend::Calendar(c) => c.schedule(entry),
            Backend::Heap(h) => h.push(entry),
        }
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, event: Event) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Schedule a same-time burst of events at absolute time `at`.
    ///
    /// Result is byte-identical to calling [`EventQueue::schedule_at`]
    /// once per event (each entry still draws its own consecutive
    /// `seq`, so within-burst FIFO order is preserved), but the clamp
    /// and bucket computation are paid once: when the whole burst lands
    /// in a single future wheel slot it is one `Vec::extend` and one
    /// scan-hint update instead of N pushes. Current-bucket and
    /// beyond-horizon times fall back to the per-entry path; the heap
    /// core pushes each entry.
    pub fn schedule_in_batch(&mut self, delay: Time, events: impl IntoIterator<Item = Event>) {
        self.schedule_batch(self.now.saturating_add(delay), events);
    }

    /// Absolute-time form of [`EventQueue::schedule_in_batch`].
    pub fn schedule_batch(&mut self, at: Time, events: impl IntoIterator<Item = Event>) {
        let time = at.max(self.now);
        match &mut self.backend {
            Backend::Calendar(c) => {
                let bucket = time >> BUCKET_SHIFT;
                if bucket > c.cursor && bucket < c.cursor + NUM_BUCKETS as u64 {
                    // Fast path: the whole burst belongs to one pending
                    // wheel slot (sorted later, when the cursor reaches
                    // it), so appending in seq order is exactly what N
                    // individual schedules would have produced.
                    let slot = &mut c.slots[(bucket & BUCKET_MASK) as usize];
                    let before = slot.len();
                    let seq0 = self.seq;
                    slot.extend(events.into_iter().enumerate().map(|(i, event)| Entry {
                        time,
                        seq: seq0 + i as u64,
                        event,
                    }));
                    let n = slot.len() - before;
                    self.seq += n as u64;
                    c.wheel_len += n;
                    if n > 0 {
                        c.scan_hint.set(c.scan_hint.get().min(bucket));
                    }
                } else {
                    // Current-bucket (sorted insert) or overflow times:
                    // per-entry scheduling already is the right shape.
                    for event in events {
                        let entry = Entry {
                            time,
                            seq: self.seq,
                            event,
                        };
                        self.seq += 1;
                        c.schedule(entry);
                    }
                }
            }
            Backend::Heap(h) => {
                for event in events {
                    h.push(Entry {
                        time,
                        seq: self.seq,
                        event,
                    });
                    self.seq += 1;
                }
            }
        }
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, Event)> {
        self.pop_due(Time::MAX)
    }

    /// Pop the next event only if it is due at or before `limit`
    /// (leaving the queue untouched otherwise). This is the run-loop
    /// primitive: it avoids the separate peek scan `pop` would repeat.
    pub fn pop_due(&mut self, limit: Time) -> Option<(Time, Event)> {
        let entry = match &mut self.backend {
            Backend::Calendar(c) => c.pop_due(limit)?,
            Backend::Heap(h) => {
                if h.peek().is_some_and(|e| e.time <= limit) {
                    h.pop()?
                } else {
                    return None;
                }
            }
        };
        debug_assert!(entry.time >= self.now, "time went backwards");
        self.now = entry.time;
        Some((entry.time, entry.event))
    }

    /// Time of the next pending event, if any.
    pub fn peek_time(&self) -> Option<Time> {
        match &self.backend {
            Backend::Calendar(c) => c.peek_time(),
            Backend::Heap(h) => h.peek().map(|e| e.time),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Calendar(c) => c.len(),
            Backend::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{HOUR, MIN, SEC};

    fn tick(g: u32) -> Event {
        Event::WorkloadTick { generator: g }
    }

    /// Run `f` against a fresh queue on each core.
    fn on_each_core(f: impl Fn(EventQueue)) {
        for core in CoreKind::ALL {
            f(EventQueue::with_core(core));
        }
    }

    #[test]
    fn pops_in_time_order() {
        on_each_core(|mut q| {
            q.schedule_at(3 * SEC, tick(3));
            q.schedule_at(SEC, tick(1));
            q.schedule_at(2 * SEC, tick(2));
            let order: Vec<u32> = std::iter::from_fn(|| q.pop())
                .map(|(_, e)| match e {
                    Event::WorkloadTick { generator } => generator,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order, vec![1, 2, 3]);
        });
    }

    #[test]
    fn simultaneous_events_fifo() {
        on_each_core(|mut q| {
            for g in 0..50 {
                q.schedule_at(5 * SEC, tick(g));
            }
            let order: Vec<u32> = std::iter::from_fn(|| q.pop())
                .map(|(_, e)| match e {
                    Event::WorkloadTick { generator } => generator,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order, (0..50).collect::<Vec<_>>());
        });
    }

    #[test]
    fn clock_advances_monotonically() {
        on_each_core(|mut q| {
            q.schedule_at(10, tick(0));
            q.schedule_at(5, tick(1));
            let (t1, _) = q.pop().unwrap();
            assert_eq!(t1, 5);
            assert_eq!(q.now(), 5);
            // Scheduling in the past clamps to now.
            q.schedule_at(1, tick(2));
            let (t2, e2) = q.pop().unwrap();
            assert_eq!(t2, 5);
            assert_eq!(e2, tick(2));
            let (t3, _) = q.pop().unwrap();
            assert_eq!(t3, 10);
        });
    }

    #[test]
    fn schedule_in_is_relative() {
        on_each_core(|mut q| {
            q.schedule_at(7, tick(0));
            q.pop().unwrap();
            q.schedule_in(3, tick(1));
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, 10);
        });
    }

    #[test]
    fn len_and_empty() {
        on_each_core(|mut q| {
            assert!(q.is_empty());
            q.schedule_at(1, tick(0));
            assert_eq!(q.len(), 1);
            assert_eq!(q.peek_time(), Some(1));
            q.pop();
            assert!(q.is_empty());
            assert_eq!(q.peek_time(), None);
        });
    }

    #[test]
    fn pop_due_respects_limit() {
        on_each_core(|mut q| {
            q.schedule_at(5 * SEC, tick(0));
            q.schedule_at(15 * SEC, tick(1));
            assert_eq!(q.pop_due(4 * SEC), None);
            assert_eq!(q.len(), 2, "declined pop must not lose events");
            // Inclusive limit.
            assert_eq!(q.pop_due(5 * SEC), Some((5 * SEC, tick(0))));
            assert_eq!(q.pop_due(10 * SEC), None);
            // Scheduling after a declined pop_due stays ordered.
            q.schedule_at(8 * SEC, tick(2));
            assert_eq!(q.pop_due(20 * SEC), Some((8 * SEC, tick(2))));
            assert_eq!(q.pop_due(20 * SEC), Some((15 * SEC, tick(1))));
            assert!(q.is_empty());
        });
    }

    #[test]
    fn far_future_overflow_roundtrips() {
        // Events far past the wheel horizon (≈36 min) live in overflow
        // and still pop in global order, including at the boundary.
        on_each_core(|mut q| {
            q.schedule_at(3 * HOUR, tick(3));
            q.schedule_at(10 * SEC, tick(0));
            q.schedule_at(50 * MIN, tick(2));
            q.schedule_at(40 * MIN, tick(1));
            assert_eq!(q.peek_time(), Some(10 * SEC));
            let order: Vec<(Time, u32)> = std::iter::from_fn(|| q.pop())
                .map(|(t, e)| match e {
                    Event::WorkloadTick { generator } => (t, generator),
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(
                order,
                vec![
                    (10 * SEC, 0),
                    (40 * MIN, 1),
                    (50 * MIN, 2),
                    (3 * HOUR, 3)
                ]
            );
        });
    }

    #[test]
    fn wheel_wraps_across_many_rotations() {
        // Periodic rescheduling far beyond one wheel rotation: the slot
        // indices wrap (mod NUM_BUCKETS) without ever colliding.
        on_each_core(|mut q| {
            q.schedule_at(0, tick(0));
            let mut pops = 0u32;
            let mut last = 0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
                pops += 1;
                if pops < 500 {
                    // 90 s steps cross bucket boundaries; 500 steps cross
                    // the 36-minute horizon ~20 times.
                    q.schedule_in(90 * SEC, tick(pops));
                }
            }
            assert_eq!(pops, 500);
            assert_eq!(last, 499 * 90 * SEC);
        });
    }

    #[test]
    fn same_timestamp_burst_interleaved_with_pops() {
        on_each_core(|mut q| {
            q.schedule_at(SEC, tick(0));
            q.schedule_at(SEC, tick(1));
            assert_eq!(q.pop(), Some((SEC, tick(0))));
            // now == 1 s; a past schedule clamps to 1 s and must pop
            // after the already-queued tick(1) (FIFO by seq).
            q.schedule_at(0, tick(2));
            q.schedule_at(SEC, tick(3));
            assert_eq!(q.pop(), Some((SEC, tick(1))));
            assert_eq!(q.pop(), Some((SEC, tick(2))));
            assert_eq!(q.pop(), Some((SEC, tick(3))));
        });
    }

    /// Drain a queue into `(time, generator)` pairs.
    fn drain(mut q: EventQueue) -> Vec<(Time, u32)> {
        std::iter::from_fn(|| q.pop())
            .map(|(t, e)| match e {
                Event::WorkloadTick { generator } => (t, generator),
                _ => unreachable!(),
            })
            .collect()
    }

    #[test]
    fn schedule_batch_matches_repeated_schedule_at() {
        // Every batch landing zone — future wheel slot (fast path),
        // current bucket, past-clamp, beyond-horizon overflow — must
        // reproduce the per-entry schedule byte-for-byte, interleaved
        // with individually scheduled same-time events.
        let times = [30 * SEC, 0, 2 * HOUR, 5];
        for core in CoreKind::ALL {
            let mut one = EventQueue::with_core(core);
            let mut batched = EventQueue::with_core(core);
            for q in [&mut one, &mut batched] {
                q.schedule_at(10, tick(900));
                q.pop(); // now == 10: later schedules at 5 and 0 clamp
                q.schedule_at(30 * SEC, tick(901));
            }
            let mut g = 0;
            for &at in &times {
                for i in 0..40 {
                    one.schedule_at(at, tick(g + i));
                }
                batched.schedule_batch(at, (g..g + 40).map(tick));
                g += 40;
            }
            assert_eq!(drain(one), drain(batched), "core {}", core.name());
        }
    }

    #[test]
    fn schedule_in_batch_is_relative_and_empty_batch_is_noop() {
        on_each_core(|mut q| {
            q.schedule_at(7, tick(0));
            q.pop();
            q.schedule_batch(3, Vec::new()); // empty: no effect
            assert!(q.is_empty());
            q.schedule_in_batch(3, vec![tick(1), tick(2)]);
            assert_eq!(drain(q), vec![(10, 1), (10, 2)]);
        });
    }

    #[test]
    fn core_kind_parse_and_names() {
        assert_eq!(CoreKind::parse("calendar").unwrap(), CoreKind::Calendar);
        assert_eq!(CoreKind::parse("heap").unwrap(), CoreKind::Heap);
        assert!(CoreKind::parse("btree").is_err());
        assert_eq!(CoreKind::default().name(), "calendar");
        assert_eq!(EventQueue::new().core(), CoreKind::Calendar);
        assert_eq!(
            EventQueue::with_core(CoreKind::Heap).core(),
            CoreKind::Heap
        );
    }
}

//! Pods: the schedulable worker units, with lifecycle FSM and busy-time
//! accounting (the source of the CPU-utilization metric).

use super::DeploymentId;
use crate::sim::{NodeId, PodId, RequestId, Time};

/// Pod lifecycle. `Gone` marks a free slab slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    /// Created but unschedulable (no node fits).
    Pending,
    /// Bound to a node, container initializing (the reactive-lag window).
    Initializing,
    /// Serving.
    Running,
    /// Draining; accepts no new work.
    Terminating,
    /// Removed.
    Gone,
}

/// Resource requests (K8s Guaranteed QoS: request == limit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PodSpec {
    pub cpu_millis: u32,
    pub ram_mb: u32,
}

impl PodSpec {
    pub fn new(cpu_millis: u32, ram_mb: u32) -> Self {
        PodSpec { cpu_millis, ram_mb }
    }
}

/// A pod instance.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub deployment: DeploymentId,
    pub node: Option<NodeId>,
    pub phase: PodPhase,
    pub spec: PodSpec,
    pub created: Time,
    /// Request currently being serviced (workers are single-slot, like a
    /// Celery worker with concurrency 1). The generational handle goes
    /// stale once the request completes in the arena. Cluster-resident
    /// pods must change occupancy through `Cluster::start_service` /
    /// `Cluster::finish_service` so the idle-pod dispatch set stays
    /// exact (the methods below are the pod-local mechanics).
    pub current_request: Option<RequestId>,
    /// Busy-time accumulator since the last metrics scrape.
    busy_accum: Time,
    /// When the current service period started (None if idle).
    busy_since: Option<Time>,
}

impl Pod {
    pub fn new(id: PodId, deployment: DeploymentId, spec: PodSpec, now: Time) -> Self {
        Pod {
            id,
            deployment,
            node: None,
            phase: PodPhase::Pending,
            spec,
            created: now,
            current_request: None,
            busy_accum: 0,
            busy_since: None,
        }
    }

    /// Mark the pod busy on `request_id` starting at `now`.
    pub fn start_service(&mut self, request_id: RequestId, now: Time) {
        debug_assert!(self.current_request.is_none(), "pod already busy");
        self.current_request = Some(request_id);
        self.busy_since = Some(now);
    }

    /// Mark the current request finished at `now`.
    pub fn finish_service(&mut self, now: Time) -> Option<RequestId> {
        let req = self.current_request.take();
        if let Some(since) = self.busy_since.take() {
            self.busy_accum += now.saturating_sub(since);
        }
        req
    }

    /// Drain the busy-time accumulator for a scrape at `now`, restarting
    /// accounting for a still-in-flight request. Returns busy time since
    /// the previous scrape.
    pub fn take_busy(&mut self, now: Time) -> Time {
        let mut busy = self.busy_accum;
        self.busy_accum = 0;
        if let Some(since) = self.busy_since {
            busy += now.saturating_sub(since);
            self.busy_since = Some(now);
        }
        busy
    }

    pub fn is_idle_running(&self) -> bool {
        self.phase == PodPhase::Running && self.current_request.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    fn pod() -> Pod {
        Pod::new(PodId(0), DeploymentId(0), PodSpec::new(500, 256), 0)
    }

    fn rid(index: u32) -> RequestId {
        RequestId::new(index, 0)
    }

    #[test]
    fn busy_accounting_across_scrapes() {
        let mut p = pod();
        p.start_service(rid(1), 2 * SEC);
        // Scrape at t=5s: busy 3s, still in flight.
        assert_eq!(p.take_busy(5 * SEC), 3 * SEC);
        // Finish at t=7s; busy 2s more.
        assert_eq!(p.finish_service(7 * SEC), Some(rid(1)));
        assert_eq!(p.take_busy(10 * SEC), 2 * SEC);
        // Idle after.
        assert_eq!(p.take_busy(12 * SEC), 0);
    }

    #[test]
    fn busy_accumulates_multiple_requests() {
        let mut p = pod();
        p.start_service(rid(1), 0);
        p.finish_service(SEC);
        p.start_service(rid(2), 2 * SEC);
        p.finish_service(3 * SEC);
        assert_eq!(p.take_busy(4 * SEC), 2 * SEC);
    }

    #[test]
    fn idle_running_check() {
        let mut p = pod();
        p.phase = PodPhase::Running;
        assert!(p.is_idle_running());
        p.start_service(rid(5), 0);
        assert!(!p.is_idle_running());
    }
}

//! Seed-model pretraining data collection (paper §5.3.1): run the example
//! application under Random Access on an unconstrained cluster and record
//! the protocol vector per control interval — "1800 records" over 10 h at
//! a 20 s interval in the paper.

use super::SimWorld;
use crate::app::TaskCosts;
use crate::autoscaler::{Autoscaler, ScaleDecision};
use crate::cluster::{Cluster, DeploymentId};
use crate::config::unconstrained_cluster;
use crate::metrics::{MetricsPipeline, METRIC_DIM};
use crate::sim::{ServiceId, Time, HOUR, SEC};
use crate::workload::{Generator, RandomAccessGen};

/// A fixed-replica "autoscaler" whose evaluate also snapshots the metric
/// vector each control tick — the data-collection harness.
struct FixedRecorder {
    replicas: usize,
    interval: Time,
    pub history: Vec<[f64; METRIC_DIM]>,
}

impl Autoscaler for FixedRecorder {
    fn name(&self) -> &str {
        "fixed-recorder"
    }

    fn control_interval(&self) -> Time {
        self.interval
    }

    fn evaluate(
        &mut self,
        _now: Time,
        service: ServiceId,
        _target: DeploymentId,
        metrics: &MetricsPipeline,
        _cluster: &Cluster,
    ) -> ScaleDecision {
        let vector = metrics.latest_vector(service);
        self.history.push(vector);
        ScaleDecision {
            desired: self.replicas,
            key_value: vector[0],
            predicted: None,
            used_fallback: false,
            recommendations: Vec::new(),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Run the pretraining collection. Returns one history per service
/// (index 0 = edge-z1 pool, last = cloud pool) sampled every
/// `control_interval_secs`, plus the completed-request count.
///
/// `hours=10` reproduces the paper's 1800-record dataset; tests use
/// shorter runs.
pub fn pretrain_histories(
    hours: f64,
    control_interval_secs: u64,
    seed: u64,
) -> (Vec<Vec<[f64; METRIC_DIM]>>, usize) {
    let cfg = unconstrained_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), seed);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    let n_services = world.app.services.len();
    for svc in 0..n_services {
        // "Unconstrained" = never saturated, but at a replica count near
        // what production runs (2-4): the seed model must see CPU sums on
        // the same scale it will predict in the autoscaled cluster.
        world.add_scaler(
            Box::new(FixedRecorder {
                replicas: 4,
                interval: control_interval_secs * SEC,
                history: Vec::new(),
            }),
            svc,
        );
    }
    let end = (hours * HOUR as f64) as Time;
    world.run_until(end);

    let histories = world
        .scalers
        .iter()
        .map(|b| {
            b.autoscaler
                .as_any()
                .downcast_ref::<FixedRecorder>()
                .expect("recorder")
                .history
                .clone()
        })
        .collect();
    (histories, world.app.completed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_expected_record_count() {
        // 0.5 h at 20 s -> ~90 records per service.
        let (histories, responses) = pretrain_histories(0.5, 20, 5);
        assert_eq!(histories.len(), 2); // edge-z1 + cloud
        for h in &histories {
            assert!(
                (85..=95).contains(&h.len()),
                "expected ~90 records, got {}",
                h.len()
            );
        }
        assert!(responses > 100, "app must have served requests");
        // CPU column shows real variation (the load phases).
        let cpus: Vec<f64> = histories[0].iter().map(|r| r[0]).collect();
        let s = crate::stats::summarize(&cpus);
        assert!(s.std > 1.0, "cpu should vary across phases: {s:?}");
    }

    #[test]
    fn paper_scale_record_count() {
        // The paper's 10 h / 20 s = 1800 records; verify the arithmetic
        // on a faster 1 h run (~180).
        let (histories, _) = pretrain_histories(1.0, 20, 6);
        assert!((175..=185).contains(&histories[0].len()));
    }
}

//! Deterministic discrete-event simulation core.
//!
//! Time is simulated microseconds ([`Time`]). The engine is a classic
//! event-queue DES built on [`EventQueue`] — by default a calendar
//! (bucket) queue, with a `BinaryHeap` reference core retained behind
//! [`CoreKind::Heap`] for golden-equivalence tests and benchmarks.
//!
//! # Determinism contract
//!
//! Every queued event carries a global schedule counter `seq`; events
//! pop in strictly ascending `(time, seq)` order, so identical-timestamp
//! events dispatch in insertion (FIFO) order. Past-time schedules clamp
//! to `now`. Both queue cores honor the same contract, which makes
//! whole-cluster runs bit-reproducible for a given seed — the
//! paper-figure experiments and the sweep harness rely on this. The
//! `shard` module extends the contract inward: a city world split into
//! per-zone worlds advancing in conservative lockstep windows stays
//! bit-identical for any `--shards` count (see [`run_sharded`]).
//!
//! # Identifier types
//!
//! [`PodId`], [`NodeId`] and [`ServiceId`] are plain indices into the
//! world's slabs. [`RequestId`] is a *generational* handle into the
//! request arena (`crate::app::RequestArena`): the `index` addresses a
//! slot, the `generation` must match the slot's current generation, so
//! handles to completed (freed-and-reused) requests miss instead of
//! aliasing a new request.

mod queue;
mod shard;

pub use queue::{CoreKind, EventQueue};
pub use shard::{partition_worlds, run_sharded, ShardSpec, ShardedRun, WorldOutcome, WorldPlan};

/// Simulated time in microseconds since simulation start.
pub type Time = u64;

pub const US: Time = 1;
pub const MS: Time = 1_000;
pub const SEC: Time = 1_000_000;
pub const MIN: Time = 60 * SEC;
pub const HOUR: Time = 60 * MIN;

/// Convert simulated time to fractional seconds (for reporting).
pub fn to_secs(t: Time) -> f64 {
    t as f64 / SEC as f64
}

/// Convert fractional seconds to simulated time.
pub fn from_secs(s: f64) -> Time {
    debug_assert!(s >= 0.0);
    (s * SEC as f64).round() as Time
}

/// Identifier types — plain indices into the world's slabs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u32);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// A service = one autoscaled deployment + its task queue (edge zone
/// worker pools and the cloud worker pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServiceId(pub u32);

/// Generational handle to an in-flight request in the app's request
/// arena (`crate::app::RequestArena`).
///
/// `index` is the arena slot; `generation` is the slot's generation at
/// insertion time. The arena bumps a slot's generation when the request
/// completes, so a stale handle (e.g. an event referring to an already
/// freed request) fails the generation check and resolves to `None`
/// instead of aliasing whatever request reuses the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId {
    pub index: u32,
    pub generation: u32,
}

impl RequestId {
    pub fn new(index: u32, generation: u32) -> Self {
        RequestId { index, generation }
    }
}

/// Simulation events. One enum for the whole world keeps dispatch flat
/// and allocation-free on the hot path: request events carry copyable
/// [`RequestId`] handles, never owned payloads.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A client request enters the system at its origin zone.
    RequestArrival { request_id: RequestId },
    /// A pod finished servicing a request.
    ServiceComplete { pod: PodId, request_id: RequestId },
    /// A pod finished container init and is now Running.
    PodRunning { pod: PodId },
    /// A pod finished draining and is gone.
    PodTerminated { pod: PodId },
    /// Prometheus scrape tick (global).
    Scrape,
    /// An autoscaler control-loop tick.
    AutoscaleTick { scaler: u32 },
    /// A PPA model-update-loop tick.
    ModelUpdateTick { scaler: u32 },
    /// Workload generator wake-up (next arrival / phase switch).
    WorkloadTick { generator: u32 },
    /// Chaos plane: a node crashes (see `cluster::chaos`). Only
    /// enqueued by `schedule_node_faults` — absent from fault-free runs.
    NodeCrash { node: NodeId },
    /// Chaos plane: a crashed node rejoins the cluster.
    NodeRejoin { node: NodeId },
    /// Resilience plane: a request's per-attempt deadline expired
    /// (fires at `created + deadline`, and for retries at
    /// `retry_arrival + deadline`). Only scheduled when an
    /// `SlaPolicy` is installed — absent from SLA-free runs, so those
    /// stay byte-identical to pre-resilience builds. A stale handle
    /// (the request already completed) makes this a no-op.
    RequestTimeout { request_id: RequestId },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(to_secs(2 * SEC + 500 * MS), 2.5);
        assert_eq!(from_secs(2.5), 2 * SEC + 500 * MS);
        assert_eq!(from_secs(to_secs(123_456_789)), 123_456_789);
    }

    #[test]
    fn unit_constants() {
        assert_eq!(SEC, 1_000 * MS);
        assert_eq!(MIN, 60 * SEC);
        assert_eq!(HOUR, 3600 * SEC);
    }

    #[test]
    fn request_ids_compare_by_index_then_generation() {
        let a = RequestId::new(1, 0);
        assert_eq!(a, RequestId::new(1, 0));
        assert_ne!(a, RequestId::new(1, 1));
        assert_ne!(a, RequestId::new(2, 0));
    }
}

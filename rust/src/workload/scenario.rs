//! Workload scenario library — the descriptor layer over the generators.
//!
//! The paper evaluates on exactly two workloads (Random Access and the
//! scaled NASA trace). Related autoscaler studies (arXiv:2512.14290,
//! arXiv:2510.10166) compare across whole *families* of bursty and
//! diurnal workloads; this module adds those families behind a single
//! [`Scenario`] descriptor so the sweep harness
//! ([`crate::experiments::sweep`]) can fan a (scenario × autoscaler ×
//! seed) grid across threads:
//!
//! * [`RateProfile::Diurnal`] — Gaussian-peak day/night cycle.
//! * [`RateProfile::FlashCrowd`] — baseline with a sudden ramp/hold/decay
//!   spike (the "flash crowd" every reactive autoscaler trails).
//! * [`RateProfile::Step`] — a cycling staircase of arrival-rate levels.
//! * [`Scenario::Composite`] — any mix of the above across zones, with
//!   staggered starts.
//!
//! Analytic profiles are replayed piecewise-constant over 10 s segments
//! by [`RateGen`], the exact sampling scheme [`super::TraceGen`] uses for
//! minute-resolution traces.

use super::{draw_task, Generator, RandomAccessGen, TraceGen};
use crate::app::App;
use crate::sim::{Event, EventQueue, Time, HOUR, MIN, SEC};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Piecewise-constant sampling resolution for analytic rate profiles.
const SEGMENT: Time = 10 * SEC;

/// Gaussian-peak diurnal cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiurnalConfig {
    /// Overnight floor (req/s).
    pub base_rps: f64,
    /// Rate at the daily peak (req/s).
    pub peak_rps: f64,
    /// Virtual hour-of-day of the peak (0..24).
    pub peak_hour: f64,
    /// Gaussian width of the peak in virtual hours (σ).
    pub width_hours: f64,
    /// Wall length of one virtual day (24 h by default; shrink it to
    /// time-compress a full day/night cycle into a short sweep window).
    pub period: Time,
}

impl Default for DiurnalConfig {
    fn default() -> Self {
        DiurnalConfig {
            base_rps: 0.3,
            peak_rps: 3.0,
            peak_hour: 15.0,
            width_hours: 3.0,
            period: 24 * HOUR,
        }
    }
}

/// A sudden surge: base → (linear ramp) → hold at spike → (linear decay)
/// → base.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashCrowdConfig {
    pub base_rps: f64,
    pub spike_rps: f64,
    /// When the ramp starts, relative to generator start.
    pub spike_start: Time,
    pub ramp: Time,
    pub hold: Time,
    pub decay: Time,
}

impl Default for FlashCrowdConfig {
    fn default() -> Self {
        FlashCrowdConfig {
            base_rps: 0.5,
            spike_rps: 6.0,
            spike_start: 10 * MIN,
            ramp: MIN,
            hold: 8 * MIN,
            decay: 4 * MIN,
        }
    }
}

/// A cycling staircase of arrival-rate levels.
#[derive(Debug, Clone, PartialEq)]
pub struct StepSurgeConfig {
    /// Rate levels (req/s), visited in order, then repeated.
    pub levels_rps: Vec<f64>,
    /// Dwell time per level.
    pub step: Time,
}

impl Default for StepSurgeConfig {
    fn default() -> Self {
        StepSurgeConfig {
            levels_rps: vec![0.5, 2.0, 4.0, 1.0],
            step: 8 * MIN,
        }
    }
}

/// An analytic arrival-rate curve, evaluated at time since generator
/// start.
#[derive(Debug, Clone, PartialEq)]
pub enum RateProfile {
    Diurnal(DiurnalConfig),
    FlashCrowd(FlashCrowdConfig),
    Step(StepSurgeConfig),
}

impl RateProfile {
    /// Arrival rate (req/s) at `elapsed` since the generator started.
    pub fn rate_at(&self, elapsed: Time) -> f64 {
        match self {
            RateProfile::Diurnal(c) => {
                let period = c.period.max(1);
                let hour = (elapsed % period) as f64 / period as f64 * 24.0;
                let dist = (hour - c.peak_hour).abs();
                let dist = dist.min(24.0 - dist); // circular day
                let sigma = c.width_hours.max(1e-6);
                let bump = (-0.5 * (dist / sigma) * (dist / sigma)).exp();
                (c.base_rps + (c.peak_rps - c.base_rps) * bump).max(0.0)
            }
            RateProfile::FlashCrowd(c) => {
                if elapsed < c.spike_start {
                    return c.base_rps.max(0.0);
                }
                let since = elapsed - c.spike_start;
                let rate = if since < c.ramp {
                    let f = since as f64 / c.ramp.max(1) as f64;
                    c.base_rps + (c.spike_rps - c.base_rps) * f
                } else if since < c.ramp + c.hold {
                    c.spike_rps
                } else if since < c.ramp + c.hold + c.decay {
                    let f = (since - c.ramp - c.hold) as f64 / c.decay.max(1) as f64;
                    c.spike_rps + (c.base_rps - c.spike_rps) * f
                } else {
                    c.base_rps
                };
                rate.max(0.0)
            }
            RateProfile::Step(c) => {
                if c.levels_rps.is_empty() {
                    return 0.0;
                }
                let idx = (elapsed / c.step.max(1)) as usize % c.levels_rps.len();
                c.levels_rps[idx].max(0.0)
            }
        }
    }

    /// A contiguous silent scan of this length proves the profile is
    /// silent forever after: one full cycle for the periodic profiles,
    /// the whole transient (plus a segment) for the flash crowd.
    fn silent_span(&self) -> Time {
        match self {
            RateProfile::Diurnal(c) => c.period.max(1) + SEGMENT,
            RateProfile::FlashCrowd(c) => {
                c.spike_start + c.ramp + c.hold + c.decay + SEGMENT
            }
            RateProfile::Step(c) => {
                c.step.max(1).saturating_mul(c.levels_rps.len().max(1) as Time) + SEGMENT
            }
        }
    }
}

/// Event-driven Poisson generator over an analytic [`RateProfile`] —
/// the analytic-curve sibling of [`TraceGen`], with the same
/// relative-to-origin indexing (staggered starts replay the full curve).
#[derive(Debug)]
pub struct RateGen {
    pub zone: u32,
    profile: RateProfile,
    pub(super) start_delay: Time,
    /// Stop generating after this much elapsed time (None = unbounded).
    horizon: Option<Time>,
    origin: Option<Time>,
}

impl RateGen {
    pub fn new(zone: u32, profile: RateProfile) -> Self {
        RateGen {
            zone,
            profile,
            start_delay: 0,
            horizon: None,
            origin: None,
        }
    }

    pub fn with_start_delay(mut self, delay: Time) -> Self {
        self.start_delay = delay;
        self
    }

    pub fn with_horizon(mut self, horizon: Time) -> Self {
        self.horizon = Some(horizon);
        self
    }

    pub(super) fn on_tick(
        &mut self,
        index: u32,
        app: &mut App,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) -> bool {
        let now = queue.now();
        let origin = match self.origin {
            Some(o) => {
                app.submit(draw_task(rng), self.zone, now, queue);
                o
            }
            None => {
                self.origin = Some(now);
                now
            }
        };

        // Piecewise-constant over SEGMENT: sample an exponential gap at
        // the current rate; if it crosses a segment boundary, re-sample
        // there (the rate may have moved). A contiguous silent scan
        // longer than the profile's silent span proves the curve is zero
        // forever (all-zero configs) — stop instead of hopping segments
        // until overflow.
        let silent_span = self.profile.silent_span();
        let mut t = now - origin;
        let mut silent_since = t;
        loop {
            if let Some(h) = self.horizon {
                if t >= h {
                    return false;
                }
            }
            let rate = self.profile.rate_at(t);
            if rate > 1e-9 {
                let gap = crate::sim::from_secs(rng.exponential(rate)).max(1);
                let seg_end = (t / SEGMENT + 1) * SEGMENT;
                if t + gap <= seg_end {
                    // The horizon bounds scheduled arrivals too, not just
                    // the loop cursor (it may not be segment-aligned).
                    if let Some(h) = self.horizon {
                        if t + gap > h {
                            return false;
                        }
                    }
                    queue.schedule_at(origin + t + gap, Event::WorkloadTick { generator: index });
                    return true;
                }
                t = seg_end;
                silent_since = t;
            } else {
                t = (t / SEGMENT + 1) * SEGMENT;
                if t - silent_since > silent_span {
                    return false;
                }
            }
        }
    }
}

/// A named, buildable workload scenario: which generator family, on which
/// zones, with what stagger. The sweep harness treats scenarios as data —
/// one descriptor per grid row — and materializes fresh [`Generator`]s
/// per cell so every cell is an independent deterministic world.
#[derive(Debug, Clone)]
pub enum Scenario {
    /// The paper's Algorithm-2 bursty generator, one per zone.
    RandomAccess { zones: Vec<u32> },
    /// Per-minute trace replay (e.g. the scaled NASA trace), one per
    /// zone, each delayed by `i * stagger`.
    Trace {
        counts: Arc<Vec<f64>>,
        scale: f64,
        zones: Vec<u32>,
        stagger: Time,
    },
    /// Gaussian-peak diurnal cycle on every zone.
    Diurnal { cfg: DiurnalConfig, zones: Vec<u32> },
    /// Flash crowd on every zone (staggered per zone).
    FlashCrowd {
        cfg: FlashCrowdConfig,
        zones: Vec<u32>,
        stagger: Time,
    },
    /// Step staircase on every zone.
    StepSurge { cfg: StepSurgeConfig, zones: Vec<u32> },
    /// Any combination of the above (multi-zone mixed workloads).
    Composite { parts: Vec<Scenario> },
}

impl Scenario {
    /// Short kind tag (report labels).
    pub fn kind(&self) -> &'static str {
        match self {
            Scenario::RandomAccess { .. } => "random-access",
            Scenario::Trace { .. } => "trace",
            Scenario::Diurnal { .. } => "diurnal",
            Scenario::FlashCrowd { .. } => "flash-crowd",
            Scenario::StepSurge { .. } => "step-surge",
            Scenario::Composite { .. } => "composite",
        }
    }

    /// Materialize fresh generators for one simulation cell.
    pub fn build_generators(&self) -> Vec<Generator> {
        match self {
            Scenario::RandomAccess { zones } => zones
                .iter()
                .map(|&z| Generator::RandomAccess(RandomAccessGen::new(z)))
                .collect(),
            Scenario::Trace {
                counts,
                scale,
                zones,
                stagger,
            } => zones
                .iter()
                .enumerate()
                .map(|(i, &z)| {
                    Generator::Trace(
                        TraceGen::new(z, counts.clone(), *scale)
                            .with_start_delay(i as Time * *stagger),
                    )
                })
                .collect(),
            Scenario::Diurnal { cfg, zones } => zones
                .iter()
                .map(|&z| Generator::Rate(RateGen::new(z, RateProfile::Diurnal(*cfg))))
                .collect(),
            Scenario::FlashCrowd {
                cfg,
                zones,
                stagger,
            } => zones
                .iter()
                .enumerate()
                .map(|(i, &z)| {
                    Generator::Rate(
                        RateGen::new(z, RateProfile::FlashCrowd(*cfg))
                            .with_start_delay(i as Time * *stagger),
                    )
                })
                .collect(),
            Scenario::StepSurge { cfg, zones } => zones
                .iter()
                .map(|&z| Generator::Rate(RateGen::new(z, RateProfile::Step(cfg.clone()))))
                .collect(),
            Scenario::Composite { parts } => {
                parts.iter().flat_map(|p| p.build_generators()).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::TaskCosts;
    use crate::cluster::{Cluster, Deployment, PodSpec, Selector, Tier};

    fn app() -> App {
        let mut cluster = Cluster::new();
        let edge = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            0,
            1,
        ));
        let cloud = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Cloud, None),
            PodSpec::new(1000, 512),
            0,
            1,
        ));
        App::new(TaskCosts::default(), &[(1, edge)], cloud)
    }

    /// Pump a single generator until `end`, returning arrival times.
    fn arrivals_until(mut gen: Generator, end: Time, seed: u64) -> Vec<Time> {
        let mut a = app();
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(seed, 50);
        gen.start(0, &mut q);
        let mut arrivals = Vec::new();
        while let Some(next) = q.peek_time() {
            if next > end {
                break;
            }
            let (t, ev) = q.pop().unwrap();
            match ev {
                Event::WorkloadTick { generator } => {
                    if !gen.on_tick(generator, &mut a, &mut q, &mut rng) {
                        break;
                    }
                }
                Event::RequestArrival { .. } => arrivals.push(t),
                _ => {}
            }
        }
        arrivals
    }

    #[test]
    fn diurnal_profile_peaks_at_peak_hour() {
        let cfg = DiurnalConfig::default();
        let p = RateProfile::Diurnal(cfg);
        let at = |h: f64| p.rate_at((h * HOUR as f64) as Time);
        assert!((at(cfg.peak_hour) - cfg.peak_rps).abs() < 1e-6);
        // Trough (12 h away) sits near the base rate.
        let trough = at((cfg.peak_hour + 12.0) % 24.0);
        assert!(trough < cfg.base_rps * 1.1, "trough {trough}");
        // Repeats daily.
        assert!((at(cfg.peak_hour + 24.0) - cfg.peak_rps).abs() < 1e-6);
        // Circular distance: 1 h before the peak == 1 h after.
        assert!((at(cfg.peak_hour - 1.0) - at(cfg.peak_hour + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn flash_crowd_profile_ramps_holds_decays() {
        let cfg = FlashCrowdConfig::default();
        let p = RateProfile::FlashCrowd(cfg);
        assert_eq!(p.rate_at(0), cfg.base_rps);
        assert_eq!(p.rate_at(cfg.spike_start + cfg.ramp), cfg.spike_rps);
        assert_eq!(
            p.rate_at(cfg.spike_start + cfg.ramp + cfg.hold / 2),
            cfg.spike_rps
        );
        let after = cfg.spike_start + cfg.ramp + cfg.hold + cfg.decay + SEC;
        assert_eq!(p.rate_at(after), cfg.base_rps);
        // Mid-ramp is strictly between base and spike.
        let mid = p.rate_at(cfg.spike_start + cfg.ramp / 2);
        assert!(mid > cfg.base_rps && mid < cfg.spike_rps, "{mid}");
    }

    #[test]
    fn step_profile_cycles_levels() {
        let cfg = StepSurgeConfig {
            levels_rps: vec![1.0, 3.0],
            step: MIN,
        };
        let p = RateProfile::Step(cfg);
        assert_eq!(p.rate_at(0), 1.0);
        assert_eq!(p.rate_at(MIN + SEC), 3.0);
        assert_eq!(p.rate_at(2 * MIN + SEC), 1.0, "cycles back");
        let empty = RateProfile::Step(StepSurgeConfig {
            levels_rps: vec![],
            step: MIN,
        });
        assert_eq!(empty.rate_at(0), 0.0);
    }

    #[test]
    fn permanently_silent_profiles_terminate() {
        // All-zero profiles must stop the generator instead of hopping
        // segments forever.
        let empty_step = Generator::Rate(RateGen::new(
            1,
            RateProfile::Step(StepSurgeConfig {
                levels_rps: vec![],
                step: MIN,
            }),
        ));
        assert!(arrivals_until(empty_step, 60 * MIN, 1).is_empty());

        let dead_crowd = Generator::Rate(RateGen::new(
            1,
            RateProfile::FlashCrowd(FlashCrowdConfig {
                base_rps: 0.0,
                spike_rps: 0.0,
                ..FlashCrowdConfig::default()
            }),
        ));
        assert!(arrivals_until(dead_crowd, 60 * MIN, 2).is_empty());

        // A zero-base flash crowd must still reach its late spike.
        let late_spike = Generator::Rate(RateGen::new(
            1,
            RateProfile::FlashCrowd(FlashCrowdConfig {
                base_rps: 0.0,
                spike_rps: 4.0,
                spike_start: 20 * MIN,
                ramp: 10 * SEC,
                hold: MIN,
                decay: 10 * SEC,
            }),
        ));
        let arrivals = arrivals_until(late_spike, 30 * MIN, 3);
        assert!(!arrivals.is_empty(), "spike after long silence still fires");
        assert!(arrivals.iter().all(|&t| t >= 20 * MIN));
    }

    #[test]
    fn rate_gen_matches_constant_rate() {
        // Constant 2 req/s for 10 minutes → ~1200 arrivals.
        let cfg = StepSurgeConfig {
            levels_rps: vec![2.0],
            step: MIN,
        };
        let gen = Generator::Rate(RateGen::new(1, RateProfile::Step(cfg)));
        let arrivals = arrivals_until(gen, 10 * MIN, 7);
        let n = arrivals.len() as f64;
        assert!((n - 1200.0).abs() < 150.0, "expected ~1200, got {n}");
    }

    #[test]
    fn rate_gen_flash_crowd_spikes() {
        let cfg = FlashCrowdConfig {
            base_rps: 0.5,
            spike_rps: 8.0,
            spike_start: 5 * MIN,
            ramp: 30 * SEC,
            hold: 4 * MIN,
            decay: 30 * SEC,
        };
        let gen = Generator::Rate(RateGen::new(1, RateProfile::FlashCrowd(cfg)));
        let arrivals = arrivals_until(gen, 15 * MIN, 9);
        let before = arrivals.iter().filter(|&&t| t < 5 * MIN).count() as f64;
        let during = arrivals
            .iter()
            .filter(|&&t| t >= 6 * MIN && t < 9 * MIN)
            .count() as f64;
        // Per-minute rate during the spike must dwarf the baseline.
        assert!(
            during / 3.0 > 5.0 * (before / 5.0),
            "spike {during}/3min vs base {before}/5min"
        );
    }

    #[test]
    fn rate_gen_horizon_stops() {
        let cfg = StepSurgeConfig {
            levels_rps: vec![5.0],
            step: MIN,
        };
        let gen = Generator::Rate(
            RateGen::new(1, RateProfile::Step(cfg)).with_horizon(2 * MIN),
        );
        let arrivals = arrivals_until(gen, 60 * MIN, 3);
        assert!(!arrivals.is_empty());
        assert!(arrivals.iter().all(|&t| t <= 2 * MIN + SEC));
    }

    #[test]
    fn rate_gen_staggered_start_replays_curve() {
        // Same flash-crowd curve, started 3 min late: the spike must move
        // by exactly the stagger (relative-origin indexing).
        let cfg = FlashCrowdConfig {
            base_rps: 0.2,
            spike_rps: 6.0,
            spike_start: 2 * MIN,
            ramp: 10 * SEC,
            hold: 2 * MIN,
            decay: 10 * SEC,
        };
        let gen = Generator::Rate(
            RateGen::new(1, RateProfile::FlashCrowd(cfg)).with_start_delay(3 * MIN),
        );
        let arrivals = arrivals_until(gen, 10 * MIN, 13);
        let in_spike = arrivals
            .iter()
            .filter(|&&t| t >= 5 * MIN && t <= 7 * MIN + 20 * SEC)
            .count();
        assert!(
            in_spike as f64 > 0.7 * arrivals.len() as f64,
            "spike must dominate and sit at 5–7 min ({in_spike}/{})",
            arrivals.len()
        );
    }

    #[test]
    fn composite_builds_all_generators() {
        let s = Scenario::Composite {
            parts: vec![
                Scenario::Diurnal {
                    cfg: DiurnalConfig::default(),
                    zones: vec![1],
                },
                Scenario::FlashCrowd {
                    cfg: FlashCrowdConfig::default(),
                    zones: vec![2],
                    stagger: 5 * MIN,
                },
            ],
        };
        let gens = s.build_generators();
        assert_eq!(gens.len(), 2);
        assert_eq!(gens[0].zone(), 1);
        assert_eq!(gens[1].zone(), 2);
        assert_eq!(s.kind(), "composite");
    }

    #[test]
    fn scenario_generators_are_fresh_each_build() {
        let s = Scenario::RandomAccess { zones: vec![1, 2] };
        assert_eq!(s.build_generators().len(), 2);
        assert_eq!(s.build_generators().len(), 2, "descriptor is reusable");
    }
}

//! The reactive Horizontal Pod Autoscaler baseline — Kubernetes' default
//! semantics: Eq 1 on the *current* metric, a ±10% tolerance band, and a
//! scale-down stabilization window (the max of recent desired counts),
//! mirroring `--horizontal-pod-autoscaler-downscale-stabilization`.

use super::{eq1_replicas, Autoscaler, ScaleDecision};
use crate::cluster::{Cluster, DeploymentId};
use crate::metrics::MetricsPipeline;
use crate::sim::{ServiceId, Time, MIN, SEC};
use std::collections::VecDeque;

/// HPA configuration (defaults match upstream Kubernetes).
#[derive(Debug, Clone, Copy)]
pub struct HpaConfig {
    /// Key-metric index into the protocol vector (HPA: CPU).
    pub key_metric: usize,
    /// Eq 1 denominator (summed per-pod % — 70 ≈ the common 70% target).
    pub threshold: f64,
    /// Control-loop period (upstream sync period: 15 s).
    pub sync_period: Time,
    /// No action when the ratio is within ±tolerance of 1 (upstream 0.1).
    pub tolerance: f64,
    /// Scale-down stabilization window (upstream default 5 min).
    pub stabilization_window: Time,
}

impl Default for HpaConfig {
    fn default() -> Self {
        HpaConfig {
            key_metric: crate::metrics::M_CPU,
            threshold: 70.0,
            sync_period: 15 * SEC,
            tolerance: 0.1,
            stabilization_window: 5 * MIN,
        }
    }
}

/// The reactive baseline autoscaler.
#[derive(Debug)]
pub struct Hpa {
    cfg: HpaConfig,
    /// (time, desired) history for the stabilization window.
    recent_desired: VecDeque<(Time, usize)>,
}

impl Hpa {
    pub fn new(cfg: HpaConfig) -> Self {
        Hpa {
            cfg,
            recent_desired: VecDeque::new(),
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(HpaConfig::default())
    }

    /// Paper-faithful variant: pure Eq 1, no stabilization (used by the
    /// ablation bench to quantify what stabilization contributes).
    pub fn pure_eq1(threshold: f64, sync_period: Time) -> Self {
        Self::new(HpaConfig {
            threshold,
            sync_period,
            tolerance: 0.0,
            stabilization_window: 0,
            ..HpaConfig::default()
        })
    }
}

impl Autoscaler for Hpa {
    fn name(&self) -> &str {
        "hpa"
    }

    fn control_interval(&self) -> Time {
        self.cfg.sync_period
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        let key_value = metrics.latest_metric(service, self.cfg.key_metric);
        let current = cluster.live_replicas(target).max(1);

        // Tolerance band: skip action if the per-replica ratio is close
        // to target (upstream behaviour).
        let ratio = key_value / (self.cfg.threshold * current as f64);
        let mut desired = if (ratio - 1.0).abs() <= self.cfg.tolerance {
            current
        } else {
            eq1_replicas(key_value, self.cfg.threshold).max(1)
        };

        // Scale-down stabilization: never drop below the max desired in
        // the recent window.
        if self.cfg.stabilization_window > 0 {
            self.recent_desired.push_back((now, desired));
            let cutoff = now.saturating_sub(self.cfg.stabilization_window);
            while matches!(self.recent_desired.front(), Some(&(t, _)) if t < cutoff) {
                self.recent_desired.pop_front();
            }
            if desired < current {
                let stabilized = self
                    .recent_desired
                    .iter()
                    .map(|&(_, d)| d)
                    .max()
                    .unwrap_or(desired);
                desired = stabilized.min(current);
            }
        }

        ScaleDecision {
            desired,
            key_value,
            predicted: None,
            used_fallback: false,
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{App, TaskCosts};
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector, Tier};
    use crate::metrics::{MetricsPipeline, M_CPU, METRIC_DIM};
    use crate::sim::{EventQueue, ServiceId};
    use crate::util::rng::Pcg64;

    fn world_with_cpu(cpu_sum: f64, replicas: usize) -> (Cluster, MetricsPipeline) {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e", Tier::Edge, 1, 8000, 8192));
        let dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        let cloud = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1, 0);
        cluster.reconcile(dep, replicas, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let crate::sim::Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
            }
        }
        let app = App::new(TaskCosts::default(), &[(1, dep)], cloud);
        let mut mp = MetricsPipeline::new(10 * SEC, app.services.len());
        // Inject a synthetic latest vector.
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = cpu_sum;
        mp_inject(&mut mp, ServiceId(0), v, replicas);
        (cluster, mp)
    }

    /// Test helper: force a latest snapshot.
    fn mp_inject(
        mp: &mut MetricsPipeline,
        svc: ServiceId,
        vector: [f64; METRIC_DIM],
        replicas: usize,
    ) {
        // MetricsPipeline has no public injection; emulate a scrape by
        // writing through its internals via scrape of an empty world is
        // complex — instead use the test-only setter.
        mp.test_set_latest(svc, vector, replicas);
    }

    #[test]
    fn scales_up_per_eq1() {
        let (cluster, mp) = world_with_cpu(350.0, 2);
        let mut hpa = Hpa::with_defaults();
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 5); // ceil(350/70)
    }

    #[test]
    fn tolerance_band_holds() {
        // 2 replicas at 145 total (72.5 each): ratio 1.036, inside ±0.1.
        let (cluster, mp) = world_with_cpu(145.0, 2);
        let mut hpa = Hpa::with_defaults();
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 2, "within tolerance — no action");
    }

    #[test]
    fn scale_down_stabilized() {
        let (cluster, mp) = world_with_cpu(70.0, 4);
        let mut hpa = Hpa::with_defaults();
        // Earlier in the window the load was high → desired 5.
        let (c2, mp2) = world_with_cpu(350.0, 4);
        let d0 = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp2, &c2);
        assert_eq!(d0.desired, 5);
        // 1 min later load collapsed; stabilization keeps replicas.
        let d1 = hpa.evaluate(60 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d1.desired, 4, "held by stabilization (min with current)");
        // After the window passes, scale-down proceeds.
        let d2 = hpa.evaluate(7 * MIN, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d2.desired, 1); // ceil(70/70)
    }

    #[test]
    fn pure_eq1_mode_reacts_immediately() {
        let (cluster, mp) = world_with_cpu(70.0, 4);
        let mut hpa = Hpa::pure_eq1(70.0, 20 * SEC);
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 1);
    }

    #[test]
    fn zero_metric_keeps_min_one() {
        let (cluster, mp) = world_with_cpu(0.0, 1);
        let mut hpa = Hpa::pure_eq1(70.0, 20 * SEC);
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 1);
    }
}

//! Presets mirroring the paper's testbed, plus the workload scenario
//! library the sweep harness runs against.

use super::{ClassMix, ClusterConfig, DeploymentConfig, NodeConfig};
use crate::cluster::{
    ColdStartPlan, CrashLoopPlan, FaultPlan, NetDelayPlan, NodeCrashPlan, Tier,
};
use crate::sim::{HOUR, MIN, MS, SEC};
use crate::workload::{
    nasa_synthetic, DiurnalConfig, FlashCrowdConfig, NasaTraceConfig, Scenario, StepSurgeConfig,
};
use std::sync::Arc;

/// Table 2: 1 cloud control node (4000m/4GB), 2 cloud workers
/// (3000m/3GB), 2 edge zones with 2 worker nodes each (2000m/2GB).
/// The control node is fully reserved (control plane + Prometheus stack
/// + the autoscalers themselves run there — §3.2.3).
pub fn paper_cluster() -> ClusterConfig {
    let mut nodes = vec![NodeConfig {
        name: "cloud-control".into(),
        tier: Tier::Cloud,
        zone: 0,
        cpu_millis: 4000,
        ram_mb: 4096,
        // Fully reserved: hosts no worker pods.
        reserved_cpu_millis: 4000,
        reserved_ram_mb: 4096,
    }];
    for i in 1..=2 {
        nodes.push(NodeConfig {
            name: format!("cloud-worker-{i}"),
            tier: Tier::Cloud,
            zone: 0,
            cpu_millis: 3000,
            ram_mb: 3072,
            reserved_cpu_millis: 200,
            reserved_ram_mb: 256,
        });
    }
    for zone in 1..=2u32 {
        for i in 1..=2 {
            nodes.push(NodeConfig {
                name: format!("edge-z{zone}-worker-{i}"),
                tier: Tier::Edge,
                zone,
                cpu_millis: 2000,
                ram_mb: 2048,
                // Edge nodes also host the zone entrypoint + exporter.
                reserved_cpu_millis: 300,
                reserved_ram_mb: 384,
            });
        }
    }

    let deployments = vec![
        DeploymentConfig {
            name: "edge-workers-z1".into(),
            tier: Tier::Edge,
            zone: Some(1),
            pod_cpu_millis: 500,
            pod_ram_mb: 256,
            min_replicas: 1,
            max_replicas: 100,
            initial_replicas: 1,
        },
        DeploymentConfig {
            name: "edge-workers-z2".into(),
            tier: Tier::Edge,
            zone: Some(2),
            pod_cpu_millis: 500,
            pod_ram_mb: 256,
            min_replicas: 1,
            max_replicas: 100,
            initial_replicas: 1,
        },
        DeploymentConfig {
            name: "cloud-workers".into(),
            tier: Tier::Cloud,
            zone: None,
            pod_cpu_millis: 1000,
            pod_ram_mb: 512,
            min_replicas: 1,
            max_replicas: 100,
            initial_replicas: 1,
        },
    ];

    ClusterConfig { nodes, deployments }
}

/// Generated city-scale topology: `n_zones` edge zones of
/// `workers_per_zone` Table-2-class worker nodes each (2000m/2GB,
/// entrypoint+exporter reservation), one fully-reserved control node and
/// a cloud worker pool that grows with the city (one 3000m/3GB node per
/// two zones plus a floor of two, sized so the 10% Eigen forward traffic
/// of ~2 req/s/zone peaks fits without saturating — §5.2.2's rule that
/// peaks must not exceed resource limits). One autoscaled `edge-workers-z<zone>`
/// deployment per zone plus the shared cloud Eigen pool — the same shape
/// as [`paper_cluster`] (which is exactly `edge_city(2, 2)` plus Table 2
/// naming), scaled to the many-zone matrices the related hybrid/SLA
/// studies (arXiv:2512.14290, arXiv:2510.10166) evaluate on.
pub fn edge_city(n_zones: u32, workers_per_zone: u32) -> ClusterConfig {
    edge_city_with_classes(n_zones, workers_per_zone, ClassMix::default())
}

/// [`edge_city`] with a heterogeneous worker-class mix: worker `i` of
/// every zone gets `mix.class_for(i)` hardware (see
/// [`crate::config::NodeClass`]). All classes keep the Table-2 edge
/// reservation, so the homogeneous `medium` mix reproduces the classic
/// grid byte for byte.
pub fn edge_city_with_classes(
    n_zones: u32,
    workers_per_zone: u32,
    mix: ClassMix,
) -> ClusterConfig {
    assert!(n_zones >= 1, "a city needs at least one zone");
    assert!(workers_per_zone >= 1, "a zone needs at least one worker");
    let mut nodes = vec![NodeConfig {
        name: "cloud-control".into(),
        tier: Tier::Cloud,
        zone: 0,
        cpu_millis: 4000,
        ram_mb: 4096,
        reserved_cpu_millis: 4000,
        reserved_ram_mb: 4096,
    }];
    let cloud_workers = 2 + n_zones / 2;
    for i in 1..=cloud_workers {
        nodes.push(NodeConfig {
            name: format!("cloud-worker-{i}"),
            tier: Tier::Cloud,
            zone: 0,
            cpu_millis: 3000,
            ram_mb: 3072,
            reserved_cpu_millis: 200,
            reserved_ram_mb: 256,
        });
    }
    for zone in 1..=n_zones {
        for i in 1..=workers_per_zone {
            let class = mix.class_for(i - 1);
            nodes.push(NodeConfig {
                name: format!("edge-z{zone}-worker-{i}"),
                tier: Tier::Edge,
                zone,
                cpu_millis: class.cpu_millis(),
                ram_mb: class.ram_mb(),
                reserved_cpu_millis: 300,
                reserved_ram_mb: 384,
            });
        }
    }

    let mut deployments: Vec<DeploymentConfig> = (1..=n_zones)
        .map(|zone| DeploymentConfig {
            name: format!("edge-workers-z{zone}"),
            tier: Tier::Edge,
            zone: Some(zone),
            pod_cpu_millis: 500,
            pod_ram_mb: 256,
            min_replicas: 1,
            max_replicas: 100,
            initial_replicas: 1,
        })
        .collect();
    deployments.push(DeploymentConfig {
        name: "cloud-workers".into(),
        tier: Tier::Cloud,
        zone: None,
        pod_cpu_millis: 1000,
        pod_ram_mb: 512,
        min_replicas: 1,
        max_replicas: 100,
        initial_replicas: 1,
    });

    ClusterConfig { nodes, deployments }
}

/// A single unconstrained node — the paper's pretraining setup (§5.3.1:
/// "running the example application for 10 hours ... on a single
/// unconstrained node").
pub fn unconstrained_cluster() -> ClusterConfig {
    ClusterConfig {
        nodes: vec![
            NodeConfig {
                name: "big-edge".into(),
                tier: Tier::Edge,
                zone: 1,
                cpu_millis: 64_000,
                ram_mb: 65_536,
                reserved_cpu_millis: 0,
                reserved_ram_mb: 0,
            },
            NodeConfig {
                name: "big-cloud".into(),
                tier: Tier::Cloud,
                zone: 0,
                cpu_millis: 64_000,
                ram_mb: 65_536,
                reserved_cpu_millis: 0,
                reserved_ram_mb: 0,
            },
        ],
        deployments: vec![
            DeploymentConfig {
                name: "edge-workers-z1".into(),
                tier: Tier::Edge,
                zone: Some(1),
                pod_cpu_millis: 500,
                pod_ram_mb: 256,
                min_replicas: 1,
                max_replicas: 100,
                initial_replicas: 1,
            },
            DeploymentConfig {
                name: "cloud-workers".into(),
                tier: Tier::Cloud,
                zone: None,
                pod_cpu_millis: 1000,
                pod_ram_mb: 512,
                min_replicas: 1,
                max_replicas: 100,
                initial_replicas: 1,
            },
        ],
    }
}

/// A small two-node cluster for quickstart/demo runs.
pub fn quickstart_cluster() -> ClusterConfig {
    ClusterConfig {
        nodes: vec![
            NodeConfig {
                name: "edge-1".into(),
                tier: Tier::Edge,
                zone: 1,
                cpu_millis: 2000,
                ram_mb: 2048,
                reserved_cpu_millis: 200,
                reserved_ram_mb: 256,
            },
            NodeConfig {
                name: "cloud-1".into(),
                tier: Tier::Cloud,
                zone: 0,
                cpu_millis: 3000,
                ram_mb: 3072,
                reserved_cpu_millis: 200,
                reserved_ram_mb: 256,
            },
        ],
        deployments: vec![
            DeploymentConfig {
                name: "edge-workers-z1".into(),
                tier: Tier::Edge,
                zone: Some(1),
                pod_cpu_millis: 500,
                pod_ram_mb: 256,
                min_replicas: 1,
                max_replicas: 16,
                initial_replicas: 1,
            },
            DeploymentConfig {
                name: "cloud-workers".into(),
                tier: Tier::Cloud,
                zone: None,
                pod_cpu_millis: 1000,
                pod_ram_mb: 512,
                min_replicas: 1,
                max_replicas: 8,
                initial_replicas: 1,
            },
        ],
    }
}

/// The workload scenario library (sweep presets). Zones match the
/// Table-2 cluster (edge zones 1 and 2). Analytic scenarios are scaled so
/// their peaks sweep the edge pools through the full replica range
/// without saturating the cloud Eigen pool (the paper's §5.2.2 rule).
pub fn scenario_presets() -> Vec<(String, Scenario)> {
    let nasa = Arc::new(nasa_synthetic(&NasaTraceConfig::default()));
    // Time-compressed day: a full diurnal cycle inside one sweep hour,
    // peaking mid-run of the default 30-minute cells.
    let compressed_day = DiurnalConfig {
        period: HOUR,
        peak_hour: 6.0,
        ..DiurnalConfig::default()
    };
    vec![
        (
            "random-access".to_string(),
            Scenario::RandomAccess { zones: vec![1, 2] },
        ),
        (
            "nasa-trace".to_string(),
            Scenario::Trace {
                counts: nasa,
                scale: 0.5,
                zones: vec![1, 2],
                stagger: 0,
            },
        ),
        (
            "diurnal".to_string(),
            Scenario::Diurnal {
                cfg: compressed_day,
                zones: vec![1, 2],
            },
        ),
        (
            "flash-crowd".to_string(),
            Scenario::FlashCrowd {
                cfg: FlashCrowdConfig::default(),
                zones: vec![1, 2],
                stagger: 5 * MIN,
            },
        ),
        (
            "step-surge".to_string(),
            Scenario::StepSurge {
                cfg: StepSurgeConfig::default(),
                zones: vec![1, 2],
            },
        ),
        (
            "multi-zone-mix".to_string(),
            Scenario::Composite {
                parts: vec![
                    Scenario::Diurnal {
                        cfg: compressed_day,
                        zones: vec![1],
                    },
                    Scenario::FlashCrowd {
                        cfg: FlashCrowdConfig {
                            // Surge hits zone 2 while zone 1 is climbing
                            // toward its diurnal peak.
                            spike_start: 12 * MIN,
                            ..FlashCrowdConfig::default()
                        },
                        zones: vec![2],
                        stagger: 0,
                    },
                ],
            },
        ),
    ]
}

/// City-scale composite scenario presets over `n_zones` edge zones.
/// Per-zone rates are kept modest (the city's scale comes from zone
/// count, not per-zone intensity), matching the paper's §5.2.2 rule of
/// sweeping pools through their replica range without saturating them.
pub fn city_scenario_presets(n_zones: u32) -> Vec<(String, Scenario)> {
    assert!(n_zones >= 1);
    let zones: Vec<u32> = (1..=n_zones).collect();
    // One compressed virtual day per sweep hour (as in the Table-2
    // presets), base/peak tuned for per-zone pools.
    let city_day = DiurnalConfig {
        base_rps: 0.1,
        peak_rps: 2.0,
        peak_hour: 6.0,
        width_hours: 2.0,
        period: HOUR,
    };
    // The diurnal peak rolls across the city: zone i peaks 24/n virtual
    // hours after zone i-1 (commuter wave).
    let wave: Vec<Scenario> = zones
        .iter()
        .enumerate()
        .map(|(i, &z)| Scenario::Diurnal {
            cfg: DiurnalConfig {
                peak_hour: (i as f64 * 24.0 / n_zones as f64 + 3.0) % 24.0,
                ..city_day
            },
            zones: vec![z],
        })
        .collect();
    vec![
        (
            format!("city{n_zones}-diurnal-wave"),
            Scenario::Composite { parts: wave },
        ),
        (
            format!("city{n_zones}-flash-mosaic"),
            // A flash crowd sweeps zone to zone, 20 s apart: at any
            // instant a dozen zones are mid-spike while the rest idle.
            Scenario::FlashCrowd {
                cfg: FlashCrowdConfig {
                    base_rps: 0.1,
                    spike_rps: 2.0,
                    spike_start: 4 * MIN,
                    ramp: 30 * crate::sim::SEC,
                    hold: 2 * MIN,
                    decay: MIN,
                },
                zones: zones.clone(),
                stagger: 20 * crate::sim::SEC,
            },
        ),
        (
            format!("city{n_zones}-step-carpet"),
            // Every zone steps through the same staircase in lockstep —
            // the whole-city load shifts the control plane must track.
            Scenario::StepSurge {
                cfg: StepSurgeConfig {
                    levels_rps: vec![0.2, 1.0, 2.0, 0.5],
                    step: 6 * MIN,
                },
                zones: zones.clone(),
            },
        ),
        (
            format!("city{n_zones}-rush-hour"),
            // City-wide diurnal climb with a flash crowd hitting the
            // first zone mid-ramp.
            Scenario::Composite {
                parts: vec![
                    Scenario::Diurnal {
                        cfg: city_day,
                        zones,
                    },
                    Scenario::FlashCrowd {
                        cfg: FlashCrowdConfig {
                            base_rps: 0.0,
                            spike_rps: 4.0,
                            spike_start: 12 * MIN,
                            ..FlashCrowdConfig::default()
                        },
                        zones: vec![1],
                        stagger: 0,
                    },
                ],
            },
        ),
    ]
}

/// The fault-plan preset library (the `--chaos <name>` axis). All
/// timings/probabilities are drawn from the dedicated chaos RNG streams
/// at run time, so every preset is bit-reproducible per seed.
pub fn chaos_presets() -> Vec<(String, FaultPlan)> {
    vec![
        ("none".to_string(), FaultPlan::none()),
        (
            "node-outage".to_string(),
            FaultPlan {
                node_crash: Some(NodeCrashPlan {
                    mean_gap: 10 * MIN,
                    outage_min: 30 * SEC,
                    outage_max: 2 * MIN,
                    cloud: false,
                }),
                ..FaultPlan::none()
            },
        ),
        (
            "flaky-pods".to_string(),
            FaultPlan {
                cold_start: Some(ColdStartPlan {
                    slow_prob: 0.3,
                    factor_min: 2.0,
                    factor_max: 5.0,
                }),
                crash_loop: Some(CrashLoopPlan {
                    prob: 0.15,
                    max_restarts: 3,
                }),
                ..FaultPlan::none()
            },
        ),
        (
            "slow-network".to_string(),
            FaultPlan {
                net_delay: Some(NetDelayPlan {
                    extra_min: 20 * MS,
                    extra_max: 200 * MS,
                }),
                ..FaultPlan::none()
            },
        ),
        (
            "full-storm".to_string(),
            FaultPlan {
                node_crash: Some(NodeCrashPlan {
                    mean_gap: 10 * MIN,
                    outage_min: 30 * SEC,
                    outage_max: 2 * MIN,
                    cloud: false,
                }),
                cold_start: Some(ColdStartPlan {
                    slow_prob: 0.3,
                    factor_min: 2.0,
                    factor_max: 5.0,
                }),
                crash_loop: Some(CrashLoopPlan {
                    prob: 0.15,
                    max_restarts: 3,
                }),
                net_delay: Some(NetDelayPlan {
                    extra_min: 20 * MS,
                    extra_max: 200 * MS,
                }),
            },
        ),
    ]
}

/// Look up a chaos preset by name.
pub fn chaos_preset(name: &str) -> crate::Result<FaultPlan> {
    let presets = chaos_presets();
    match presets.iter().find(|(n, _)| n == name) {
        Some((_, plan)) => Ok(*plan),
        None => {
            let names: Vec<&str> = presets.iter().map(|(n, _)| n.as_str()).collect();
            anyhow::bail!("unknown chaos preset '{name}' (expected {})", names.join("|"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_presets_cover_the_axes() {
        let presets = chaos_presets();
        assert_eq!(presets.len(), 5);
        assert!(chaos_preset("none").unwrap().is_empty());
        assert_eq!(chaos_preset("node-outage").unwrap().label(), "crash");
        assert_eq!(
            chaos_preset("flaky-pods").unwrap().label(),
            "coldstart+crashloop"
        );
        assert_eq!(chaos_preset("slow-network").unwrap().label(), "netdelay");
        let storm = chaos_preset("full-storm").unwrap();
        assert_eq!(storm.label(), "crash+coldstart+crashloop+netdelay");
        assert!(storm.node_crash.is_some() && storm.net_delay.is_some());
        assert!(chaos_preset("hurricane").is_err());
    }

    #[test]
    fn heterogeneous_city_cycles_classes_per_zone() {
        use crate::config::NodeClass;
        let mix = ClassMix::new(&[NodeClass::Small, NodeClass::Large]).unwrap();
        let cfg = edge_city_with_classes(3, 3, mix);
        cfg.validate().unwrap();
        // Worker i of each zone: small, large, small.
        for zone in 1..=3u32 {
            let cpus: Vec<u32> = cfg
                .nodes
                .iter()
                .filter(|n| n.tier == Tier::Edge && n.zone == zone)
                .map(|n| n.cpu_millis)
                .collect();
            assert_eq!(cpus, vec![1000, 4000, 1000], "zone {zone}");
        }
        // Reservation is class-independent (Table-2 edge overhead).
        assert!(cfg
            .nodes
            .iter()
            .filter(|n| n.tier == Tier::Edge)
            .all(|n| n.reserved_cpu_millis == 300 && n.reserved_ram_mb == 384));
        // Small workers host (1000-300)/500 = 1 pod; large (4000-300)/500 = 7.
        let (cluster, ids) = cfg.build();
        assert_eq!(cluster.max_replicas(ids[0]), 1 + 7 + 1);
    }

    #[test]
    fn scenario_presets_build() {
        let presets = scenario_presets();
        assert_eq!(presets.len(), 6);
        for (name, s) in &presets {
            assert!(!name.is_empty());
            assert!(!s.build_generators().is_empty(), "{name} builds nothing");
        }
        // The composite mixes families across zones.
        let (_, mix) = presets.last().unwrap();
        let zones: Vec<u32> = mix.build_generators().iter().map(|g| g.zone()).collect();
        assert_eq!(zones, vec![1, 2]);
    }

    #[test]
    fn paper_cluster_matches_table2() {
        let cfg = paper_cluster();
        assert_eq!(cfg.nodes.len(), 7);
        let control = &cfg.nodes[0];
        assert_eq!(control.cpu_millis, 4000);
        assert_eq!(control.reserved_cpu_millis, 4000, "control hosts no workers");
        let edge: Vec<_> = cfg.nodes.iter().filter(|n| n.tier == Tier::Edge).collect();
        assert_eq!(edge.len(), 4, "2 zones x 2 workers");
        assert!(edge.iter().all(|n| n.cpu_millis == 2000 && n.ram_mb == 2048));
        cfg.validate().unwrap();
    }

    #[test]
    fn all_presets_validate() {
        paper_cluster().validate().unwrap();
        unconstrained_cluster().validate().unwrap();
        quickstart_cluster().validate().unwrap();
    }

    #[test]
    fn unconstrained_has_huge_capacity() {
        let (cluster, ids) = unconstrained_cluster().build();
        assert!(cluster.max_replicas(ids[0]) >= 100);
    }

    #[test]
    fn edge_city_scales_with_zones() {
        let cfg = edge_city(50, 2);
        cfg.validate().unwrap();
        assert_eq!(cfg.deployments.len(), 51, "50 zone pools + cloud");
        let edge_nodes = cfg.nodes.iter().filter(|n| n.tier == Tier::Edge).count();
        assert_eq!(edge_nodes, 100, "2 workers per zone");
        let cloud_nodes = cfg.nodes.iter().filter(|n| n.tier == Tier::Cloud).count();
        assert_eq!(cloud_nodes, 1 + 2 + 50 / 2, "control + scaled cloud pool");
        let (cluster, ids) = cfg.build();
        assert_eq!(ids.len(), 51);
        // Each zone pool can host (2000-300)/500 = 3 pods per worker.
        assert_eq!(cluster.max_replicas(ids[0]), 6);
        // Bigger workers-per-zone grows per-zone headroom.
        let (wide, wide_ids) = edge_city(4, 5).build();
        assert_eq!(wide.max_replicas(wide_ids[0]), 15);
    }

    #[test]
    fn city_presets_cover_all_zones() {
        let presets = city_scenario_presets(10);
        assert_eq!(presets.len(), 4);
        for (name, s) in &presets {
            assert!(name.starts_with("city10-"), "{name}");
            let gens = s.build_generators();
            assert!(!gens.is_empty(), "{name} builds nothing");
            let mut zones: Vec<u32> = gens.iter().map(|g| g.zone()).collect();
            zones.sort();
            zones.dedup();
            assert_eq!(zones, (1..=10).collect::<Vec<u32>>(), "{name} zone cover");
        }
    }

    #[test]
    fn city_diurnal_wave_staggers_peaks() {
        let presets = city_scenario_presets(8);
        let (_, wave) = &presets[0];
        let Scenario::Composite { parts } = wave else {
            panic!("wave is a composite")
        };
        assert_eq!(parts.len(), 8);
        let peak_of = |s: &Scenario| match s {
            Scenario::Diurnal { cfg, .. } => cfg.peak_hour,
            _ => panic!("wave parts are diurnal"),
        };
        // Consecutive zones peak 24/8 = 3 virtual hours apart.
        let delta = (peak_of(&parts[1]) - peak_of(&parts[0]) + 24.0) % 24.0;
        assert!((delta - 3.0).abs() < 1e-9, "delta={delta}");
    }
}

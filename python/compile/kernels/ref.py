"""Pure-jnp oracle for the Pallas LSTM kernels.

Every Pallas kernel in ``kernels.lstm_cell`` is checked against these
reference implementations by ``python/tests``. Gradients of the custom-vjp
cell are checked against ``jax.grad`` of :func:`lstm_cell_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, w, b):
    """Reference LSTM cell, gate order [i, f, g, o] over a fused weight.

    Mirrors kernels.lstm_cell.lstm_cell exactly (same fused-weight layout,
    same float32 accumulation).
    """
    i_dim = x.shape[-1]
    hidden = h.shape[-1]
    z = (
        jnp.dot(x, w[:i_dim, :], preferred_element_type=jnp.float32)
        + jnp.dot(h, w[i_dim:, :], preferred_element_type=jnp.float32)
        + b[None, :]
    )
    i_g = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    f_g = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g_g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o_g = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    c_new = f_g * c + i_g * g_g
    h_new = o_g * jnp.tanh(c_new)
    return h_new, c_new


def lstm_ref(xs, h0, c0, w, b):
    """Unrolled reference LSTM over a (T, B, I) sequence. Returns final h."""

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell_ref(x_t, h, c, w, b)
        return (h, c), None

    (h, _c), _ = jax.lax.scan(step, (h0, c0), xs)
    return h


def forecaster_ref(params, x):
    """Reference forward pass of the full L2 model (LSTM + ReLU dense).

    Args:
      params: dict with w (I+H,4H), b (4H,), wd (H,O), bd (O,).
      x: (B, T, I) batch of input windows.

    Returns:
      (B, O) predicted next-step metric vector.
    """
    batch = x.shape[0]
    hidden = params["wd"].shape[0]
    h0 = jnp.zeros((batch, hidden), x.dtype)
    c0 = jnp.zeros((batch, hidden), x.dtype)
    xs = jnp.swapaxes(x, 0, 1)  # (T, B, I)
    h = lstm_ref(xs, h0, c0, params["w"], params["b"])
    return jax.nn.relu(jnp.dot(h, params["wd"]) + params["bd"])


def mse_ref(pred, target):
    return jnp.mean((pred - target) ** 2)

//! The Proactive Pod Autoscaler (paper §4) — the system contribution.
//!
//! Three components, two loops, two files (Fig 4):
//! * [`Formulator`] — extracts the protocol vector from raw metrics each
//!   control loop and appends it to the *metrics history file*.
//! * [`Evaluator`] — Algorithm 1: predicts the key metric with the
//!   injected model (*model file*), falls back to the current metric when
//!   the model is invalid or under-confident, applies the *static policy*
//!   and caps at the resource-limited max replicas.
//! * [`Updater`] — the model-update loop: applies one of the three update
//!   policies (§4.2.3) to the model over the history file, then clears
//!   the history file (as the paper's Updater does).

mod evaluator;
mod formulator;
mod policy;
mod updater;

pub use evaluator::Evaluator;
pub use formulator::Formulator;
pub use policy::{ConservativeCeilPolicy, HpaCeilPolicy, StaticPolicy, StepPolicy};
pub use updater::Updater;

use super::{Autoscaler, ScaleDecision};
use crate::cluster::{Cluster, DeploymentId};
use crate::forecast::{Forecaster, UpdatePolicy};
use crate::metrics::MetricsPipeline;
use crate::sim::{ServiceId, Time, HOUR, SEC};

/// PPA configuration — Table 4's arguments.
#[derive(Debug, Clone)]
pub struct PpaConfig {
    /// `KeyMetric`: index into the protocol vector.
    pub key_metric: usize,
    /// `Threashold` (sic): Eq 1 denominator on the key metric.
    pub threshold: f64,
    /// `ControlInterval` (paper experiments: 20 s records).
    pub control_interval: Time,
    /// `UpdateInterval` (paper: hours; 1 h in the optimization runs).
    pub update_interval: Time,
    /// Model-update policy (§4.2.3).
    pub update_policy: UpdatePolicy,
    /// Confidence gate for Bayesian models (Algorithm 1).
    pub confidence_threshold: f64,
    /// Downscale stabilization window applied by the control plane to
    /// the PPA's scale requests (K8s applies the same machinery to every
    /// scaler; the PPA can afford a shorter window than HPA's 5 min
    /// because its predictions filter transient dips).
    pub downscale_stabilization: Time,
}

impl Default for PpaConfig {
    fn default() -> Self {
        PpaConfig {
            key_metric: crate::metrics::M_CPU,
            threshold: 70.0,
            control_interval: 20 * SEC,
            update_interval: HOUR,
            update_policy: UpdatePolicy::FineTune,
            confidence_threshold: 0.5,
            downscale_stabilization: 2 * crate::sim::MIN,
        }
    }
}

/// One recorded control-loop observation: what the model predicted for
/// this instant (made one interval earlier) vs what actually happened —
/// the data behind Figs 7 and 8.
#[derive(Debug, Clone, Copy)]
pub struct PredictionRecord {
    pub time: Time,
    pub predicted: f64,
    pub actual: f64,
}

/// The assembled PPA.
pub struct Ppa {
    cfg: PpaConfig,
    formulator: Formulator,
    evaluator: Evaluator,
    updater: Updater,
    /// Prediction made last tick, awaiting its actual.
    pending_prediction: Option<f64>,
    /// (predicted, actual) log for MSE evaluation.
    pub prediction_log: Vec<PredictionRecord>,
    /// Decision log (desired replicas per tick).
    pub decision_log: Vec<(Time, usize)>,
    /// (time, desired) history for the downscale-stabilization window.
    recent_desired: std::collections::VecDeque<(Time, usize)>,
}

impl Ppa {
    pub fn new(cfg: PpaConfig, forecaster: Box<dyn Forecaster>) -> Self {
        Ppa {
            evaluator: Evaluator::new(
                forecaster,
                cfg.key_metric,
                cfg.threshold,
                cfg.confidence_threshold,
            ),
            updater: Updater::new(cfg.update_policy),
            formulator: Formulator::new(),
            cfg,
            pending_prediction: None,
            prediction_log: Vec::new(),
            decision_log: Vec::new(),
            recent_desired: std::collections::VecDeque::new(),
        }
    }

    /// Replace the static policy (the paper's "users may inject their own
    /// policies").
    pub fn with_policy(mut self, policy: Box<dyn StaticPolicy>) -> Self {
        self.evaluator.set_policy(policy);
        self
    }

    pub fn forecaster_name(&self) -> &str {
        self.evaluator.forecaster_name()
    }

    /// Mean squared prediction error so far (Figs 7–8 metric).
    pub fn prediction_mse(&self) -> f64 {
        let preds: Vec<f64> = self.prediction_log.iter().map(|r| r.predicted).collect();
        let actuals: Vec<f64> = self.prediction_log.iter().map(|r| r.actual).collect();
        crate::stats::mse(&preds, &actuals)
    }
}

impl Autoscaler for Ppa {
    fn name(&self) -> &str {
        "ppa"
    }

    fn control_interval(&self) -> Time {
        self.cfg.control_interval
    }

    fn update_interval(&self) -> Option<Time> {
        Some(self.cfg.update_interval)
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        // Formulator: raw metrics -> protocol vector -> history file.
        let vector = metrics.latest_vector(service);
        self.formulator.record(vector);

        // Close the loop on last tick's prediction (Fig 7/8 data).
        if let Some(pred) = self.pending_prediction.take() {
            self.prediction_log.push(PredictionRecord {
                time: now,
                predicted: pred,
                actual: vector[self.cfg.key_metric],
            });
        }
        self.evaluator.observe_actual(&vector);

        // Evaluator: Algorithm 1.
        let mut decision = self
            .evaluator
            .evaluate(&vector, self.formulator.history(), target, cluster);
        self.pending_prediction = decision.predicted;

        // Control-plane downscale stabilization (short window).
        if self.cfg.downscale_stabilization > 0 {
            self.recent_desired.push_back((now, decision.desired));
            let cutoff = now.saturating_sub(self.cfg.downscale_stabilization);
            while matches!(self.recent_desired.front(), Some(&(t, _)) if t < cutoff) {
                self.recent_desired.pop_front();
            }
            let current = cluster.live_replicas(target);
            if decision.desired < current {
                let stabilized = self
                    .recent_desired
                    .iter()
                    .map(|&(_, d)| d)
                    .max()
                    .unwrap_or(decision.desired);
                decision.desired = stabilized.min(current);
            }
        }

        self.decision_log.push((now, decision.desired));
        decision
    }

    fn model_update(&mut self, _now: Time) -> crate::Result<()> {
        self.updater
            .run(self.evaluator.forecaster_mut(), &mut self.formulator)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector, Tier};
    use crate::forecast::NaiveForecaster;
    use crate::metrics::{M_CPU, METRIC_DIM};
    use crate::sim::EventQueue;
    use crate::util::rng::Pcg64;

    fn cluster_fixture(replicas: usize) -> Cluster {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        cluster.add_node(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048));
        let dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1, 0);
        cluster.reconcile(dep, replicas, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let crate::sim::Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
            }
        }
        cluster
    }

    fn metrics_with(cpu: f64, replicas: usize) -> MetricsPipeline {
        let mut mp = MetricsPipeline::new(10 * SEC, 1);
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = cpu;
        mp.test_set_latest(ServiceId(0), v, replicas);
        mp
    }

    #[test]
    fn proactive_with_naive_model_scales_on_trend() {
        let cluster = cluster_fixture(2);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        let mp = metrics_with(300.0, 2);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        // Naive predicts 300 → ceil(300/70) = 5.
        assert_eq!(d.desired, 5);
        assert!(!d.used_fallback);
        assert_eq!(d.predicted, Some(300.0));
    }

    #[test]
    fn caps_at_resource_limited_max() {
        let cluster = cluster_fixture(2);
        // 2 nodes x 1800m allocatable; 2 pods live (1 per node) leave
        // 2 more slots per node -> cap = 4 additional + 2 live = 6.
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        let mp = metrics_with(10_000.0, 2);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 6, "capped at resource-limited max");
    }

    #[test]
    fn prediction_log_pairs_up() {
        let cluster = cluster_fixture(1);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        for (i, cpu) in [100.0, 120.0, 90.0].iter().enumerate() {
            let mp = metrics_with(*cpu, 1);
            ppa.evaluate(i as Time * 20 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        }
        // naive: predicts last value; records pair on next tick.
        assert_eq!(ppa.prediction_log.len(), 2);
        assert_eq!(ppa.prediction_log[0].predicted, 100.0);
        assert_eq!(ppa.prediction_log[0].actual, 120.0);
        assert_eq!(ppa.prediction_log[1].predicted, 120.0);
        assert_eq!(ppa.prediction_log[1].actual, 90.0);
        let mse = ppa.prediction_mse();
        assert!((mse - (400.0 + 900.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn model_update_clears_history() {
        let cluster = cluster_fixture(1);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        for i in 0..20 {
            let mp = metrics_with(100.0, 1);
            ppa.evaluate(i * 20 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        }
        assert_eq!(ppa.formulator.history().len(), 20);
        ppa.model_update(100 * SEC).unwrap();
        assert_eq!(
            ppa.formulator.history().len(),
            0,
            "updater must clear the metrics history file"
        );
    }
}

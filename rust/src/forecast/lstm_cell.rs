//! Pure-Rust LSTM inference — evaluates the PJRT artifact's weight
//! layout without the PJRT runtime handle.
//!
//! The AOT-compiled JAX/Pallas LSTM ([`super::LstmForecaster`]) owns a
//! non-`Send` runtime `Rc`, so it cannot enter the sharded engine or
//! the parallel sweep grid. This cell reimplements the *forward* pass
//! over the same parameter shapes — `w: (I+H, 4H)` row-major with gate
//! order `[i, f, g, o]`, `b: (4H,)`, dense head `wd: (H, O)`,
//! `bd: (O,)`, ReLU output — in plain `f64` loops. Weights either come
//! from a deterministic seeded init ([`LstmCellForecaster::seeded`],
//! Glorot-uniform with the conventional forget-gate bias of 1) or are
//! injected via [`LstmCellForecaster::from_weights`] after exporting a
//! trained artifact's parameters.
//!
//! `retrain` only (re)fits the [`MinMaxScaler`]: this is an inference
//! path, not a trainer — under the champion–challenger selector an
//! unfitted random-weight cell simply never wins promotion.

use super::window::latest_window;
use super::{Forecaster, MinMaxScaler, Scaler, UpdatePolicy};
use crate::metrics::METRIC_DIM;
use crate::util::rng::Pcg64;

/// Hidden width of the paper's model (`lstm(50)`).
pub const DEFAULT_HIDDEN: usize = 50;
/// Input window length of the paper's model.
pub const DEFAULT_SEQ_LEN: usize = 8;

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// The inference-only LSTM forecaster.
pub struct LstmCellForecaster {
    name: String,
    hidden: usize,
    seq_len: usize,
    /// Cell kernel, `(METRIC_DIM + hidden) × 4*hidden` row-major.
    w: Vec<f64>,
    /// Cell bias, `4*hidden`, gate order `[i, f, g, o]`.
    b: Vec<f64>,
    /// Dense head, `hidden × METRIC_DIM` row-major.
    wd: Vec<f64>,
    /// Head bias, `METRIC_DIM`.
    bd: Vec<f64>,
    scaler: Option<MinMaxScaler>,
}

impl LstmCellForecaster {
    /// Deterministic Glorot-uniform init (forget-gate bias 1) on the
    /// paper's `hidden=50, seq_len=8` geometry. Stream 17 mirrors the
    /// PJRT forecaster's parameter stream.
    pub fn seeded(seed: u64) -> Self {
        let (hidden, seq_len) = (DEFAULT_HIDDEN, DEFAULT_SEQ_LEN);
        let mut rng = Pcg64::new(seed, 17);
        let init = |n: usize, fan_in: usize, fan_out: usize, rng: &mut Pcg64| -> Vec<f64> {
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            (0..n).map(|_| rng.range(-limit, limit)).collect()
        };
        let w = init(
            (METRIC_DIM + hidden) * 4 * hidden,
            METRIC_DIM + hidden,
            4 * hidden,
            &mut rng,
        );
        let wd = init(hidden * METRIC_DIM, hidden, METRIC_DIM, &mut rng);
        let mut b = vec![0.0; 4 * hidden];
        for slot in &mut b[hidden..2 * hidden] {
            *slot = 1.0; // forget-gate bias: remember by default
        }
        LstmCellForecaster {
            name: format!("lstm-rs({hidden})"),
            hidden,
            seq_len,
            w,
            b,
            wd,
            bd: vec![0.0; METRIC_DIM],
            scaler: None,
        }
    }

    /// Wrap exported weights. Shapes must match the artifact layout
    /// (`w: (METRIC_DIM+hidden)*4*hidden`, `b: 4*hidden`,
    /// `wd: hidden*METRIC_DIM`, `bd: METRIC_DIM`).
    pub fn from_weights(
        w: Vec<f64>,
        b: Vec<f64>,
        wd: Vec<f64>,
        bd: Vec<f64>,
        hidden: usize,
        seq_len: usize,
    ) -> crate::Result<Self> {
        if hidden == 0 || seq_len == 0 {
            anyhow::bail!("lstm-rs needs hidden > 0 and seq_len > 0");
        }
        let expect = [
            ("w", w.len(), (METRIC_DIM + hidden) * 4 * hidden),
            ("b", b.len(), 4 * hidden),
            ("wd", wd.len(), hidden * METRIC_DIM),
            ("bd", bd.len(), METRIC_DIM),
        ];
        for (name, got, want) in expect {
            if got != want {
                anyhow::bail!("lstm-rs weight `{name}`: {got} values, expected {want}");
            }
        }
        Ok(LstmCellForecaster {
            name: format!("lstm-rs({hidden})"),
            hidden,
            seq_len,
            w,
            b,
            wd,
            bd,
            scaler: None,
        })
    }

    /// Run the cell over one scaled window (`seq_len × METRIC_DIM`
    /// row-major) and return the scaled output vector.
    fn forward(&self, window: &[f64]) -> [f64; METRIC_DIM] {
        let h4 = 4 * self.hidden;
        let mut h = vec![0.0; self.hidden];
        let mut c = vec![0.0; self.hidden];
        let mut z = vec![0.0; h4];
        for step in 0..self.seq_len {
            let x = &window[step * METRIC_DIM..(step + 1) * METRIC_DIM];
            z.copy_from_slice(&self.b);
            for (i, xi) in x.iter().enumerate() {
                let row = &self.w[i * h4..(i + 1) * h4];
                for (zj, wj) in z.iter_mut().zip(row) {
                    *zj += xi * wj;
                }
            }
            for (k, hk) in h.iter().enumerate() {
                let row = &self.w[(METRIC_DIM + k) * h4..(METRIC_DIM + k + 1) * h4];
                for (zj, wj) in z.iter_mut().zip(row) {
                    *zj += hk * wj;
                }
            }
            for j in 0..self.hidden {
                let gi = sigmoid(z[j]);
                let gf = sigmoid(z[self.hidden + j]);
                let gg = z[2 * self.hidden + j].tanh();
                let go = sigmoid(z[3 * self.hidden + j]);
                c[j] = gf * c[j] + gi * gg;
                h[j] = go * c[j].tanh();
            }
        }
        let mut out = [0.0; METRIC_DIM];
        for (o, slot) in out.iter_mut().enumerate() {
            let mut acc = self.bd[o];
            for (k, hk) in h.iter().enumerate() {
                acc += hk * self.wd[k * METRIC_DIM + o];
            }
            *slot = acc.max(0.0); // ReLU head, as in the artifact
        }
        out
    }
}

impl Forecaster for LstmCellForecaster {
    fn name(&self) -> &str {
        &self.name
    }

    /// Scale the latest window, run the cell, inverse-scale. `None`
    /// until the scaler is fitted or when history is shorter than
    /// `seq_len`.
    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let scaler = self.scaler.as_ref()?;
        let window32 = latest_window(history, self.seq_len, scaler)?;
        let window: Vec<f64> = window32.iter().map(|&v| v as f64).collect();
        let scaled = self.forward(&window);
        let mut out = scaler.inverse_row(&scaled);
        for v in &mut out {
            *v = v.max(0.0);
        }
        Some(out)
    }

    /// Inference path: `retrain` (re)fits only the scaler. `KeepSeed`
    /// leaves everything untouched.
    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()> {
        if policy == UpdatePolicy::KeepSeed {
            return Ok(());
        }
        if history.len() < self.seq_len + 1 {
            anyhow::bail!(
                "history too short to fit the lstm-rs scaler ({} rows < {})",
                history.len(),
                self.seq_len + 1
            );
        }
        if policy == UpdatePolicy::RetrainScratch || self.scaler.is_none() {
            self.scaler = Some(MinMaxScaler::fit(history));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn history(n: usize) -> Vec<[f64; METRIC_DIM]> {
        (0..n)
            .map(|t| {
                let x = t as f64;
                [10.0 + x, 20.0 + x, 5.0, x * 0.5, 100.0 - x]
            })
            .collect()
    }

    #[test]
    fn unfitted_predicts_none() {
        let mut f = LstmCellForecaster::seeded(1);
        assert_eq!(f.predict(&history(32)), None);
    }

    #[test]
    fn fit_scaler_then_predictions_are_finite_and_nonnegative() {
        let mut f = LstmCellForecaster::seeded(1);
        let h = history(40);
        f.retrain(&h, UpdatePolicy::FineTune).expect("fits scaler");
        let p = f.predict(&h).expect("fitted");
        assert!(p.iter().all(|v| v.is_finite() && *v >= 0.0), "{p:?}");
        assert_eq!(f.predict(&h[..4]), None, "window shorter than seq_len");
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let h = history(30);
        let mut a = LstmCellForecaster::seeded(9);
        let mut b = LstmCellForecaster::seeded(9);
        a.retrain(&h, UpdatePolicy::RetrainScratch).expect("fits");
        b.retrain(&h, UpdatePolicy::RetrainScratch).expect("fits");
        assert_eq!(a.predict(&h), b.predict(&h));
        assert_ne!(
            LstmCellForecaster::seeded(9).w,
            LstmCellForecaster::seeded(10).w
        );
    }

    #[test]
    fn forget_gate_bias_is_one() {
        let f = LstmCellForecaster::seeded(2);
        let h = DEFAULT_HIDDEN;
        assert!(f.b[h..2 * h].iter().all(|&v| v == 1.0));
        assert!(f.b[..h].iter().all(|&v| v == 0.0));
        assert!(f.b[2 * h..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_weights_validates_shapes_and_runs() {
        let hidden = 4;
        let w = vec![0.0; (METRIC_DIM + hidden) * 4 * hidden];
        let b = vec![0.0; 4 * hidden];
        let wd = vec![0.0; hidden * METRIC_DIM];
        let bd = vec![0.25; METRIC_DIM];
        let mut f = LstmCellForecaster::from_weights(w, b, wd, bd, hidden, 3).expect("shapes ok");
        assert_eq!(f.name(), "lstm-rs(4)");
        let h = history(20);
        f.retrain(&h, UpdatePolicy::RetrainScratch).expect("fits");
        // All-zero kernel → hidden state stays 0 → output = relu(bd),
        // inverse-scaled: min + 0.25 * range on every feature.
        let p = f.predict(&h).expect("fitted");
        let scaler = MinMaxScaler::fit(&h);
        for i in 0..METRIC_DIM {
            let want = (scaler.min[i] + 0.25 * scaler.range[i]).max(0.0);
            assert!((p[i] - want).abs() < 1e-9, "feature {i}: {} vs {want}", p[i]);
        }
        let bad = LstmCellForecaster::from_weights(vec![0.0; 3], vec![], vec![], vec![], 4, 3);
        assert!(bad.expect_err("shape mismatch").to_string().contains("`w`"));
    }

    #[test]
    fn short_history_bails() {
        let mut f = LstmCellForecaster::seeded(1);
        let err = f
            .retrain(&history(DEFAULT_SEQ_LEN), UpdatePolicy::FineTune)
            .expect_err("8 rows < seq_len+1");
        assert!(err.to_string().contains("too short"), "{err}");
    }
}

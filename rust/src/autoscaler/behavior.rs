//! The shared scaling-behavior stage — Kubernetes `behavior:` semantics
//! as control-plane policy, applied to every autoscaler's combined
//! recommendation (the stage both [`super::Hpa`] and [`super::Ppa`] run
//! after the per-metric combine).
//!
//! Mirrors the HPA v2 `behavior` block: per-direction stabilization
//! windows (scale-down takes the **max** recommendation over the window,
//! scale-up the **min** — a drop/spike must persist for the whole window
//! to act), optional rate limits (at most N pods and/or P percent of the
//! period-start count per period), and a select policy choosing the most
//! (`Max`) or least (`Min`) permissive configured limit, or disabling
//! the direction outright.
//!
//! Config ([`ScalingBehavior`]) is plain copyable data; the mutable
//! window/rate histories live in a per-scaler [`BehaviorState`].

use crate::sim::Time;
use anyhow::{bail, Context};
use std::collections::VecDeque;

/// Which configured rate limit wins when several apply (K8s
/// `selectPolicy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectPolicy {
    /// The limit allowing the **most** change (K8s default).
    Max,
    /// The limit allowing the **least** change.
    Min,
    /// Scaling in this direction is disabled entirely.
    Disabled,
}

/// Optional per-direction rate limits. `None` everywhere = unlimited.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RateLimits {
    /// At most this many pods added/removed per period: `(pods, period)`.
    pub pods: Option<(u32, Time)>,
    /// At most this percent of the period-start replica count per
    /// period: `(percent, period)`.
    pub percent: Option<(f64, Time)>,
}

impl RateLimits {
    fn is_unlimited(&self) -> bool {
        self.pods.is_none() && self.percent.is_none()
    }

    fn max_period(&self) -> Time {
        let p = self.pods.map_or(0, |(_, t)| t);
        let q = self.percent.map_or(0, |(_, t)| t);
        p.max(q)
    }
}

/// One direction's rules (K8s `scaleUp:` / `scaleDown:` block).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalingRules {
    /// Recommendation-stabilization window (0 = off).
    pub stabilization_window: Time,
    pub limits: RateLimits,
    pub select: SelectPolicy,
}

impl ScalingRules {
    /// No rate limits; stabilize over `window`.
    pub fn unlimited(window: Time) -> Self {
        ScalingRules {
            stabilization_window: window,
            limits: RateLimits::default(),
            select: SelectPolicy::Max,
        }
    }

    /// This direction never scales.
    pub fn disabled() -> Self {
        ScalingRules {
            stabilization_window: 0,
            limits: RateLimits::default(),
            select: SelectPolicy::Disabled,
        }
    }
}

/// The full two-direction behavior config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalingBehavior {
    pub scale_up: ScalingRules,
    pub scale_down: ScalingRules,
}

impl ScalingBehavior {
    /// The legacy control-plane policy: immediate scale-up, scale-down
    /// stabilized over `window`, no rate limits. `stabilize_down(5 min)`
    /// is the stock-HPA default; the PPA default uses 2 min (its
    /// predictions already filter transient dips).
    pub fn stabilize_down(window: Time) -> Self {
        ScalingBehavior {
            scale_up: ScalingRules::unlimited(0),
            scale_down: ScalingRules::unlimited(window),
        }
    }

    /// Upstream Kubernetes defaults: scale-up min(not limited below)
    /// 4 pods or 100 %/15 s (whichever allows more), no up window;
    /// scale-down 100 %/15 s under a 5-minute window.
    pub fn k8s_default() -> Self {
        use crate::sim::{MIN, SEC};
        ScalingBehavior {
            scale_up: ScalingRules {
                stabilization_window: 0,
                limits: RateLimits {
                    pods: Some((4, 15 * SEC)),
                    percent: Some((100.0, 15 * SEC)),
                },
                select: SelectPolicy::Max,
            },
            scale_down: ScalingRules {
                stabilization_window: 5 * MIN,
                limits: RateLimits {
                    percent: Some((100.0, 15 * SEC)),
                    ..RateLimits::default()
                },
                select: SelectPolicy::Max,
            },
        }
    }

    /// Parse the CLI `--behavior` syntax: a comma-separated list of
    /// `key=value` entries over defaults of [`Self::stabilize_down`]
    /// with the given fallback window. Keys:
    ///
    /// * `k8s` — load the full upstream defaults ([`Self::k8s_default`],
    ///   incl. the stock rate limits) as the base; later entries
    ///   override
    /// * `up-window=DUR` / `down-window=DUR` — stabilization windows
    /// * `up-pods=N/DUR` / `down-pods=N/DUR` — pod rate limits
    /// * `up-percent=P/DUR` / `down-percent=P/DUR` — percent rate limits
    /// * `up-select=max|min|disabled` / `down-select=…`
    ///
    /// Durations are seconds by default; `s`/`m` suffixes accepted
    /// (`300s`, `5m`, `120`).
    pub fn parse(s: &str, default_down_window: Time) -> crate::Result<Self> {
        let mut b = ScalingBehavior::stabilize_down(default_down_window);
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            if entry == "k8s" {
                b = ScalingBehavior::k8s_default();
                continue;
            }
            let (key, value) = entry
                .split_once('=')
                .with_context(|| format!("behavior entry '{entry}' must be key=value"))?;
            let (dir, field) = key
                .trim()
                .split_once('-')
                .with_context(|| format!("behavior key '{key}' must be up-* or down-*"))?;
            let rules = match dir {
                "up" => &mut b.scale_up,
                "down" => &mut b.scale_down,
                other => bail!("behavior key '{key}': unknown direction '{other}' (up|down)"),
            };
            let value = value.trim();
            match field {
                "window" => rules.stabilization_window = parse_duration(value)?,
                "pods" => {
                    let (n, period) = value
                        .split_once('/')
                        .with_context(|| format!("'{entry}' must be N/period, e.g. 4/15s"))?;
                    let n: u32 = n
                        .trim()
                        .parse()
                        .with_context(|| format!("'{entry}': pod count must be an integer"))?;
                    rules.limits.pods = Some((n, parse_duration(period)?));
                }
                "percent" => {
                    let (p, period) = value
                        .split_once('/')
                        .with_context(|| format!("'{entry}' must be P/period, e.g. 100/15s"))?;
                    let p: f64 = p
                        .trim()
                        .parse()
                        .ok()
                        .filter(|p: &f64| p.is_finite() && *p >= 0.0)
                        .with_context(|| format!("'{entry}': percent must be >= 0"))?;
                    rules.limits.percent = Some((p, parse_duration(period)?));
                }
                "select" => {
                    rules.select = match value {
                        "max" => SelectPolicy::Max,
                        "min" => SelectPolicy::Min,
                        "disabled" => SelectPolicy::Disabled,
                        other => bail!("'{entry}': unknown select '{other}' (max|min|disabled)"),
                    }
                }
                other => bail!("behavior key '{key}': unknown field '{other}'"),
            }
        }
        Ok(b)
    }

    fn max_window(&self) -> Time {
        self.scale_up
            .stabilization_window
            .max(self.scale_down.stabilization_window)
    }

    fn max_period(&self) -> Time {
        self.scale_up
            .limits
            .max_period()
            .max(self.scale_down.limits.max_period())
    }
}

/// Parse a simulated duration: plain seconds, or with an `s`/`m` suffix.
pub fn parse_duration(s: &str) -> crate::Result<Time> {
    use crate::sim::{MIN, SEC};
    let s = s.trim();
    let (num, unit) = match s.strip_suffix('s') {
        Some(n) => (n, SEC),
        None => match s.strip_suffix('m') {
            Some(n) => (n, MIN),
            None => (s, SEC),
        },
    };
    let v: f64 = num
        .trim()
        .parse()
        .ok()
        .filter(|v: &f64| v.is_finite() && *v >= 0.0)
        .with_context(|| format!("bad duration '{s}' (e.g. 300s, 5m, 120)"))?;
    Ok((v * unit as f64) as Time)
}

/// The mutable half of the behavior stage: recommendation history for
/// the stabilization windows and applied-decision history for the rate
/// limits. One per scaler instance.
#[derive(Debug, Default)]
pub struct BehaviorState {
    /// `(time, combined recommendation)` — pre-behavior values, the
    /// stabilization-window input.
    recent: VecDeque<(Time, usize)>,
    /// `(time, current replicas observed at that decision)` — the rate
    /// limits' period-start base. Recording the *observed* count (not
    /// the decision output) makes one period's budget absolute: a burst
    /// of decisions inside the period cannot ratchet the base.
    observed: VecDeque<(Time, usize)>,
}

impl BehaviorState {
    pub fn new() -> Self {
        BehaviorState::default()
    }

    /// Run the behavior stage on one combined recommendation against
    /// `current` replicas; returns the clamped decision. Deterministic:
    /// depends only on the sequence of `(now, recommendation, current)`
    /// calls and the (fixed) config.
    pub fn apply(
        &mut self,
        now: Time,
        recommendation: usize,
        current: usize,
        behavior: &ScalingBehavior,
    ) -> usize {
        // Window histories.
        let max_window = behavior.max_window();
        if max_window > 0 {
            self.recent.push_back((now, recommendation));
            let cutoff = now.saturating_sub(max_window);
            while matches!(self.recent.front(), Some(&(t, _)) if t < cutoff) {
                self.recent.pop_front();
            }
        }

        // Stabilization: a scale-down must be the max recommendation of
        // the down window (legacy `recent_desired` semantics, bit-exact);
        // a scale-up the min of the up window.
        let mut desired = recommendation;
        let down_window = behavior.scale_down.stabilization_window;
        if down_window > 0 && desired < current {
            let cutoff = now.saturating_sub(down_window);
            let stabilized = self
                .recent
                .iter()
                .filter(|&&(t, _)| t >= cutoff)
                .map(|&(_, d)| d)
                .max()
                .unwrap_or(desired);
            desired = stabilized.min(current);
        }
        let up_window = behavior.scale_up.stabilization_window;
        if up_window > 0 && desired > current {
            let cutoff = now.saturating_sub(up_window);
            let stabilized = self
                .recent
                .iter()
                .filter(|&&(t, _)| t >= cutoff)
                .map(|&(_, d)| d)
                .min()
                .unwrap_or(desired);
            desired = stabilized.max(current);
        }

        // Rate limits + select policy.
        if desired > current {
            desired = match behavior.scale_up.select {
                SelectPolicy::Disabled => current,
                select => {
                    let allowed = self.allowed_up(now, current, &behavior.scale_up.limits, select);
                    desired.min(allowed.max(current))
                }
            };
        } else if desired < current {
            desired = match behavior.scale_down.select {
                SelectPolicy::Disabled => current,
                select => {
                    let floor =
                        self.allowed_down(now, current, &behavior.scale_down.limits, select);
                    desired.max(floor.min(current))
                }
            };
        }

        // Observed-replica history (rate-limit base for later calls).
        if behavior.max_period() > 0 {
            self.observed.push_back((now, current));
            let cutoff = now.saturating_sub(behavior.max_period());
            while matches!(self.observed.front(), Some(&(t, _)) if t < cutoff) {
                self.observed.pop_front();
            }
        }
        desired
    }

    /// Period-start base for an up limit: the lowest replica count
    /// observed within the period (or `current` alone when none).
    fn base_up(&self, now: Time, current: usize, period: Time) -> usize {
        let cutoff = now.saturating_sub(period);
        self.observed
            .iter()
            .filter(|&&(t, _)| t >= cutoff)
            .map(|&(_, d)| d)
            .min()
            .unwrap_or(current)
            .min(current)
    }

    /// Period-start base for a down limit (mirror: highest in window).
    fn base_down(&self, now: Time, current: usize, period: Time) -> usize {
        let cutoff = now.saturating_sub(period);
        self.observed
            .iter()
            .filter(|&&(t, _)| t >= cutoff)
            .map(|&(_, d)| d)
            .max()
            .unwrap_or(current)
            .max(current)
    }

    /// Highest replica count the up limits allow right now.
    fn allowed_up(
        &self,
        now: Time,
        current: usize,
        limits: &RateLimits,
        select: SelectPolicy,
    ) -> usize {
        if limits.is_unlimited() {
            return usize::MAX;
        }
        let mut candidates: [Option<usize>; 2] = [None, None];
        if let Some((pods, period)) = limits.pods {
            candidates[0] = Some(self.base_up(now, current, period) + pods as usize);
        }
        if let Some((pct, period)) = limits.percent {
            let base = self.base_up(now, current, period);
            candidates[1] = Some((base as f64 * (1.0 + pct / 100.0)).ceil() as usize);
        }
        let it = candidates.iter().flatten().copied();
        match select {
            SelectPolicy::Max => it.max().unwrap(),
            _ => it.min().unwrap(),
        }
    }

    /// Lowest replica count the down limits allow right now.
    fn allowed_down(
        &self,
        now: Time,
        current: usize,
        limits: &RateLimits,
        select: SelectPolicy,
    ) -> usize {
        if limits.is_unlimited() {
            return 0;
        }
        let mut candidates: [Option<usize>; 2] = [None, None];
        if let Some((pods, period)) = limits.pods {
            let base = self.base_down(now, current, period);
            candidates[0] = Some(base.saturating_sub(pods as usize));
        }
        if let Some((pct, period)) = limits.percent {
            let base = self.base_down(now, current, period);
            candidates[1] = Some((base as f64 * (1.0 - pct / 100.0)).floor().max(0.0) as usize);
        }
        let it = candidates.iter().flatten().copied();
        match select {
            // Max = most change = lowest floor; Min = least change.
            SelectPolicy::Max => it.min().unwrap(),
            _ => it.max().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MIN, SEC};

    #[test]
    fn no_behavior_passes_through() {
        let b = ScalingBehavior::stabilize_down(0);
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 7, 2, &b), 7);
        assert_eq!(s.apply(15 * SEC, 1, 7, &b), 1);
        assert!(s.recent.is_empty() && s.observed.is_empty(), "no state kept");
    }

    #[test]
    fn down_window_holds_max_of_recommendations() {
        // The legacy `recent_desired` semantics: a scale-down is held at
        // the window max, capped at current.
        let b = ScalingBehavior::stabilize_down(5 * MIN);
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 5, 4, &b), 5, "scale-up unaffected");
        assert_eq!(s.apply(MIN, 1, 4, &b), 4, "held: window max 5, min current");
        assert_eq!(s.apply(7 * MIN, 1, 4, &b), 1, "window expired, down proceeds");
    }

    #[test]
    fn up_window_holds_min_of_recommendations() {
        let b = ScalingBehavior {
            scale_up: ScalingRules::unlimited(2 * MIN),
            scale_down: ScalingRules::unlimited(0),
        };
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 2, 2, &b), 2);
        // A spike must persist for the whole up window: min(2, 8) = 2.
        assert_eq!(s.apply(MIN, 8, 2, &b), 2, "one-tick spike filtered");
        assert_eq!(s.apply(4 * MIN, 8, 2, &b), 8, "old low reading expired");
    }

    #[test]
    fn pods_rate_limit_caps_per_period() {
        let b = ScalingBehavior {
            scale_up: ScalingRules {
                stabilization_window: 0,
                limits: RateLimits {
                    pods: Some((2, MIN)),
                    percent: None,
                },
                select: SelectPolicy::Max,
            },
            scale_down: ScalingRules::unlimited(0),
        };
        let mut s = BehaviorState::new();
        // Want 10, have 1: at most +2 per minute.
        assert_eq!(s.apply(0, 10, 1, &b), 3);
        // Same period: base is still the 1 observed at t=0 → the +2
        // budget is spent, no further growth.
        assert_eq!(s.apply(30 * SEC, 10, 3, &b), 3);
        // Next period: the t=0 observation expired; base = 3 → 5.
        assert_eq!(s.apply(61 * SEC, 10, 3, &b), 5);
    }

    #[test]
    fn percent_rate_limit_and_select_min() {
        let limits = RateLimits {
            pods: Some((10, MIN)),
            percent: Some((50.0, MIN)),
        };
        let mk = |select| ScalingBehavior {
            scale_up: ScalingRules {
                stabilization_window: 0,
                limits,
                select,
            },
            scale_down: ScalingRules::unlimited(0),
        };
        // From 4: pods allows 14, percent allows ceil(4*1.5)=6.
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 20, 4, &mk(SelectPolicy::Max)), 14);
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 20, 4, &mk(SelectPolicy::Min)), 6);
    }

    #[test]
    fn down_rate_limit_floors_per_period() {
        let b = ScalingBehavior {
            scale_up: ScalingRules::unlimited(0),
            scale_down: ScalingRules {
                stabilization_window: 0,
                limits: RateLimits {
                    pods: Some((1, MIN)),
                    percent: None,
                },
                select: SelectPolicy::Max,
            },
        };
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 1, 8, &b), 7, "at most -1 per minute");
        assert_eq!(s.apply(30 * SEC, 1, 7, &b), 7, "base still 8 → floor 7");
        assert_eq!(s.apply(90 * SEC, 1, 7, &b), 6, "new period");
    }

    #[test]
    fn disabled_direction_freezes() {
        let b = ScalingBehavior {
            scale_up: ScalingRules::unlimited(0),
            scale_down: ScalingRules::disabled(),
        };
        let mut s = BehaviorState::new();
        assert_eq!(s.apply(0, 1, 5, &b), 5, "scale-down disabled");
        assert_eq!(s.apply(0, 9, 5, &b), 9, "scale-up still free");
    }

    #[test]
    fn parse_behavior_syntax() {
        let b = ScalingBehavior::parse(
            "down-window=5m, up-pods=4/15s, up-percent=100/15s, down-select=min",
            2 * MIN,
        )
        .unwrap();
        assert_eq!(b.scale_down.stabilization_window, 5 * MIN);
        assert_eq!(b.scale_up.limits.pods, Some((4, 15 * SEC)));
        assert_eq!(b.scale_up.limits.percent, Some((100.0, 15 * SEC)));
        assert_eq!(b.scale_down.select, SelectPolicy::Min);
        // Defaults untouched elsewhere.
        assert_eq!(b.scale_up.stabilization_window, 0);

        assert!(ScalingBehavior::parse("sideways-window=5m", 0).is_err());
        assert!(ScalingBehavior::parse("down-pods=4", 0).is_err());
        assert!(ScalingBehavior::parse("down-select=sometimes", 0).is_err());
        assert!(ScalingBehavior::parse("window=5m", 0).is_err());
    }

    #[test]
    fn parse_k8s_shorthand_loads_upstream_defaults() {
        let b = ScalingBehavior::parse("k8s", 0).unwrap();
        assert_eq!(b, ScalingBehavior::k8s_default());
        assert_eq!(b.scale_up.limits.pods, Some((4, 15 * SEC)));
        assert_eq!(b.scale_down.stabilization_window, 5 * MIN);
        // Later entries override the loaded base.
        let b = ScalingBehavior::parse("k8s, down-window=1m", 0).unwrap();
        assert_eq!(b.scale_down.stabilization_window, MIN);
        assert_eq!(b.scale_down.limits.percent, Some((100.0, 15 * SEC)));
    }

    #[test]
    fn parse_duration_forms() {
        assert_eq!(parse_duration("120").unwrap(), 120 * SEC);
        assert_eq!(parse_duration("300s").unwrap(), 300 * SEC);
        assert_eq!(parse_duration("5m").unwrap(), 5 * MIN);
        assert_eq!(parse_duration("0").unwrap(), 0);
        assert!(parse_duration("-3").is_err());
        assert!(parse_duration("fast").is_err());
    }
}

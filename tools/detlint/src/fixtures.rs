//! Embedded fixture corpus: one positive and one negative fixture per
//! rule, plus pragma-handling cases. The same table backs the unit
//! tests (`cargo test -p detlint`) and the runtime self-check
//! (`cargo run -p detlint -- --self-test`), so CI proves the rules fire
//! before trusting a clean repo scan.
//!
//! Fixtures are lexed, never compiled — they only need to be lexically
//! plausible Rust.

use crate::rules::lint_source;

/// One corpus entry: a virtual file and the exact rule-id sequence the
/// lint must produce for it (diagnostics ordered by line, then rule).
pub struct Fixture {
    pub name: &'static str,
    /// Repo-relative virtual path — placement decides rule scope.
    pub path: &'static str,
    pub src: &'static str,
    pub expect: &'static [&'static str],
}

pub const FIXTURES: &[Fixture] = &[
    // ---- D1: wall clock / env / ambient randomness ----
    Fixture {
        name: "d1_instant_fires",
        path: "rust/src/sim/fixture.rs",
        src: r##"
pub fn stamp() -> u64 {
    let wall = std::time::Instant::now();
    wall.elapsed().as_millis() as u64
}
"##,
        expect: &["D1"],
    },
    Fixture {
        name: "d1_env_fires",
        path: "rust/src/workload/fixture.rs",
        src: r##"
pub fn home() -> Option<String> {
    std::env::var("HOME").ok()
}
"##,
        expect: &["D1"],
    },
    Fixture {
        name: "d1_seeded_rng_clean",
        path: "rust/src/sim/fixture.rs",
        src: r##"
use crate::util::rng::Pcg64;

pub fn roll(rng: &mut Pcg64) -> f64 {
    rng.f64()
}
"##,
        expect: &[],
    },
    Fixture {
        name: "d1_test_module_exempt",
        path: "rust/src/metrics/fixture.rs",
        src: r##"
pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_is_fine_in_tests() {
        let wall = std::time::Instant::now();
        assert!(wall.elapsed().as_secs() < 1);
    }
}
"##,
        expect: &[],
    },
    Fixture {
        name: "d1_out_of_scope_bench_clean",
        path: "rust/benches/fixture.rs",
        src: r##"
pub fn wall_time() -> std::time::Instant {
    std::time::Instant::now()
}
"##,
        expect: &[],
    },
    Fixture {
        name: "d1_string_literal_is_not_a_token",
        path: "rust/src/sim/fixture.rs",
        src: r##"
pub const DOC: &str = "std::time::Instant::now() is banned here";
"##,
        expect: &[],
    },
    // ---- D2: hash-collection traversal ----
    Fixture {
        name: "d2_iter_fires",
        path: "rust/src/metrics/fixture.rs",
        src: r##"
use std::collections::HashMap;

pub fn total(m: &HashMap<u32, u64>) -> u64 {
    let mut sum = 0;
    for (_, v) in m.iter() {
        sum += v;
    }
    sum
}
"##,
        expect: &["D2"],
    },
    Fixture {
        name: "d2_for_loop_fires",
        path: "rust/src/cluster/fixture.rs",
        src: r##"
use std::collections::HashSet;

pub fn drain_all(seen: &mut HashSet<u32>, out: &mut Vec<u32>) {
    for id in seen.drain() {
        out.push(id);
    }
}
"##,
        expect: &["D2"],
    },
    Fixture {
        name: "d2_lookup_clean",
        path: "rust/src/metrics/fixture.rs",
        src: r##"
use std::collections::HashMap;

pub struct Interner {
    by_name: HashMap<String, u32>,
}

impl Interner {
    pub fn id(&self, name: &str) -> Option<u32> {
        self.by_name.get(name).copied()
    }

    pub fn insert(&mut self, name: &str, id: u32) {
        self.by_name.insert(name.to_string(), id);
    }
}
"##,
        expect: &[],
    },
    // ---- N1: nexus enforcement ----
    Fixture {
        name: "n1_set_phase_outside_owner_fires",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn sneak(cluster: &mut Cluster, pid: PodId) {
    cluster.set_phase(pid, PodPhase::Gone);
}
"##,
        expect: &["N1"],
    },
    Fixture {
        name: "n1_arena_type_outside_owner_fires",
        path: "rust/src/cluster/fixture.rs",
        src: r##"
pub fn steal(arena: &mut RequestArena) {
    let _ = arena.len();
}
"##,
        expect: &["N1"],
    },
    Fixture {
        name: "n1_owner_module_clean",
        path: "rust/src/cluster/mod.rs",
        src: r##"
impl Cluster {
    fn set_phase(&mut self, pid: PodId, to: PodPhase) {
        self.pods[pid.0 as usize].phase = to;
    }

    pub fn kill(&mut self, pid: PodId) {
        self.set_phase(pid, PodPhase::Gone);
    }
}
"##,
        expect: &[],
    },
    Fixture {
        name: "n1_unrelated_ident_clean",
        path: "rust/src/autoscaler/fixture.rs",
        src: r##"
pub fn binding_label(bindings: &[u32]) -> usize {
    bindings.len()
}
"##,
        expect: &[],
    },
    // ---- P1: hot-path panics ----
    Fixture {
        name: "p1_unwrap_fires",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}
"##,
        expect: &["P1"],
    },
    Fixture {
        name: "p1_panic_macro_fires",
        path: "rust/src/sim/fixture.rs",
        src: r##"
pub fn advance(step: u64) -> u64 {
    if step == 0 {
        panic!("zero step");
    }
    step - 1
}
"##,
        expect: &["P1"],
    },
    Fixture {
        name: "p1_handled_arm_clean",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn first(v: &[u32]) -> u32 {
    match v.first() {
        Some(&x) => x,
        None => 0,
    }
}
"##,
        expect: &[],
    },
    Fixture {
        name: "p1_debug_assert_exempt",
        path: "rust/src/cluster/fixture.rs",
        src: r##"
pub fn check(v: &[u32]) -> usize {
    debug_assert!(*v.first().unwrap() == 0, "first must be zero");
    v.len()
}
"##,
        expect: &[],
    },
    Fixture {
        name: "p1_test_module_exempt",
        path: "rust/src/sim/fixture.rs",
        src: r##"
pub fn len_of(v: &[u32]) -> usize {
    v.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
        assert_eq!(len_of(&v), 1);
    }
}
"##,
        expect: &[],
    },
    // ---- Pragmas: suppression scope and S1 hygiene ----
    Fixture {
        name: "pragma_standalone_covers_next_item",
        path: "rust/src/experiments/fixture.rs",
        src: r##"
// detlint: allow(D1) — harness-side timing, reported to the operator only
pub fn wall() -> std::time::Instant {
    std::time::Instant::now()
}
"##,
        expect: &[],
    },
    Fixture {
        name: "pragma_trailing_covers_its_line",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn pick(v: &[u32]) -> u32 {
    *v.first().expect("non-empty by construction") // detlint: allow(P1) — validated at build time
}
"##,
        expect: &[],
    },
    Fixture {
        name: "pragma_wrong_rule_does_not_suppress",
        path: "rust/src/sim/fixture.rs",
        src: r##"
// detlint: allow(P1) — aimed at the wrong rule
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
"##,
        expect: &["D1", "D1"],
    },
    Fixture {
        name: "pragma_unknown_rule_rejected",
        path: "rust/src/sim/fixture.rs",
        src: r##"
// detlint: allow(Z9) — no such rule
pub fn fine() -> u64 {
    7
}
"##,
        expect: &["S1"],
    },
    Fixture {
        name: "pragma_missing_reason_rejected",
        path: "rust/src/sim/fixture.rs",
        src: r##"
// detlint: allow(D1)
pub fn stamp() -> u64 {
    let wall = std::time::Instant::now();
    wall.elapsed().as_millis() as u64
}
"##,
        expect: &["S1", "D1"],
    },
    Fixture {
        name: "pragma_in_doc_comment_is_prose",
        path: "rust/src/sim/fixture.rs",
        src: r##"
/// detlint: allow(D1) — this is documentation, not a pragma
pub fn fine() -> u64 {
    7
}
"##,
        expect: &[],
    },
    // ---- Sharded engine (sim/shard.rs is inside SIM_SCOPE + HOT_SCOPE) ----
    Fixture {
        // Cross-shard exchange machinery — `Mutex`, `Barrier`, poison
        // recovery — is deterministic plumbing, not a banned source;
        // none of D1/D2/P1 may fire on it (the false-positive case the
        // sharded engine's barrier loop would otherwise trip).
        name: "d1_shard_channel_clean",
        path: "rust/src/sim/shard.rs",
        src: r##"
use std::sync::{Barrier, Mutex};

pub fn exchange(slots: &[Mutex<Vec<u64>>], barrier: &Barrier) -> Vec<u64> {
    barrier.wait();
    let mut merged = Vec::new();
    for slot in slots {
        let mut batch = match slot.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        merged.append(&mut batch);
    }
    barrier.wait();
    merged
}
"##,
        expect: &[],
    },
    // ---- Chaos plane (cluster/chaos.rs is inside SIM_SCOPE + HOT_SCOPE
    //      and a sanctioned N1 owner for set_phase / unbind) ----
    Fixture {
        // Fault schedules must come from the dedicated seeded streams
        // (`chaos_schedule_stream` et al.), never ambient randomness or
        // the wall clock — D1 applies to the chaos plane like any other
        // simulation module.
        name: "d1_chaos_wall_clock_fires",
        path: "rust/src/cluster/chaos.rs",
        src: r##"
pub fn next_crash_gap() -> u64 {
    let wall = std::time::SystemTime::now();
    wall.elapsed().map(|d| d.as_micros() as u64).unwrap_or(0)
}
"##,
        expect: &["D1"],
    },
    Fixture {
        // The real shape: gaps drawn from a seeded per-world stream.
        name: "d1_chaos_seeded_stream_clean",
        path: "rust/src/cluster/chaos.rs",
        src: r##"
use crate::util::rng::Pcg64;

pub fn next_crash_gap(rng: &mut Pcg64, mean_gap: u64) -> u64 {
    (rng.f64() * 2.0 * mean_gap as f64) as u64
}
"##,
        expect: &[],
    },
    Fixture {
        // `crash_node` kills pods through the nexuses from inside the
        // owner family — chaos.rs is on the allowed lists, so N1 stays
        // quiet; it still maintains the incremental indices.
        name: "n1_chaos_owner_file_clean",
        path: "rust/src/cluster/chaos.rs",
        src: r##"
impl Cluster {
    pub fn crash_pod(&mut self, pid: PodId, dep: DeploymentId, spec: &NodeSpec) {
        let nid = self.pods[pid.0 as usize].node;
        self.nodes[nid.0 as usize].unbind(pid, dep, spec);
        self.set_phase(pid, PodPhase::Gone);
    }
}
"##,
        expect: &[],
    },
    Fixture {
        // …but a fault injector living anywhere else may not reach the
        // same nexuses: crashes route through `Cluster::crash_node`.
        name: "n1_chaos_outside_owner_fires",
        path: "rust/src/experiments/fixture.rs",
        src: r##"
pub fn hard_kill(cluster: &mut Cluster, pid: PodId) {
    cluster.set_phase(pid, PodPhase::Gone);
}
"##,
        expect: &["N1"],
    },
    Fixture {
        // Chaos handlers run on the arrival→complete hot path (crash
        // events interleave with request traffic), so P1's panic ban
        // applies: stale-event tolerance, not unwrap.
        name: "p1_chaos_unwrap_fires",
        path: "rust/src/cluster/chaos.rs",
        src: r##"
pub fn victim(nodes: &[u32], idx: usize) -> u32 {
    *nodes.get(idx).unwrap()
}
"##,
        expect: &["P1"],
    },
    // ---- Sharded engine, continued ----
    Fixture {
        // The hot-path panic ban still applies to the exchange
        // machinery in `sim/shard.rs`: the idiomatic `.lock().unwrap()`
        // is exactly the poison-propagating panic the engine must avoid.
        name: "p1_shard_unwrap_fires",
        path: "rust/src/sim/shard.rs",
        src: r##"
use std::sync::Mutex;

pub fn drain(slot: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut batch = slot.lock().unwrap();
    std::mem::take(&mut batch)
}
"##,
        expect: &["P1"],
    },
    // ---- Forecaster zoo (the four zoo files are inside SIM_SCOPE via
    //      rust/src/forecast/ and individually listed in HOT_SCOPE:
    //      they run inside every PPA tick) ----
    Fixture {
        // Shadow-scoring must clock itself off the observed tick
        // stream, never the wall — a selector that timestamps reviews
        // with `Instant` replays differently on every machine.
        name: "d1_selector_wall_clock_fires",
        path: "rust/src/forecast/selector.rs",
        src: r##"
pub fn review_due(last_review: std::time::Instant) -> bool {
    last_review.elapsed().as_secs() >= 60
}
"##,
        expect: &["D1"],
    },
    Fixture {
        // The real shape: reviews keyed off the deterministic tick
        // counter, per-model scores in fixed roster order.
        name: "d1_selector_tick_review_clean",
        path: "rust/src/forecast/selector.rs",
        src: r##"
pub fn best_challenger(scores: &[(usize, f64)], incumbent: f64, margin: f64) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for &(idx, mse) in scores {
        if mse < incumbent * (1.0 - margin) && best.is_none_or(|(_, b)| mse < b) {
            best = Some((idx, mse));
        }
    }
    best.map(|(idx, _)| idx)
}
"##,
        expect: &[],
    },
    Fixture {
        // Zoo models predict inside every PPA tick; a panic in the
        // forward pass tears down the run like any other hot-path
        // unwrap. `None` (fall back to the current metric) is the
        // contract for "can't predict".
        name: "p1_tcn_unwrap_fires",
        path: "rust/src/forecast/tcn.rs",
        src: r##"
pub fn forward(window: &[f64], weights: &[f64]) -> f64 {
    let last = window.last().unwrap();
    last + weights.first().copied().unwrap_or(0.0)
}
"##,
        expect: &["P1"],
    },
    // ---- Resilience plane (the deadline/retry/shed path lives in
    //      rust/src/app/, and autoscaler/hybrid.rs is individually
    //      listed in HOT_SCOPE: its override logic runs every tick) ----
    Fixture {
        // The timeout handler must date a deadline expiry off the
        // request's sim-time `created` stamp, never the wall clock — a
        // wall-clocked deadline breaks bit-identical replays outright.
        name: "d1_timeout_wall_clock_fires",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn deadline_expired(deadline_ms: u64) -> bool {
    let wall = std::time::Instant::now();
    wall.elapsed().as_millis() as u64 > deadline_ms
}
"##,
        expect: &["D1"],
    },
    Fixture {
        // The real shape: expiry is pure sim-time arithmetic on the
        // event's scheduled stamp.
        name: "d1_timeout_sim_time_clean",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn deadline_expired(now: u64, created: u64, deadline: u64) -> bool {
    now >= created.saturating_add(deadline)
}
"##,
        expect: &[],
    },
    Fixture {
        // Retry scheduling runs inside the `RequestTimeout` handler —
        // an unwrap on the arena lookup panics the whole run the moment
        // a stale timeout races a completion. Stale handles must be
        // dropped, not unwrapped.
        name: "p1_retry_unwrap_fires",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn backoff_for(attempts: &[u32], idx: usize, base: u64) -> u64 {
    let k = *attempts.get(idx).unwrap();
    base << k.min(16)
}
"##,
        expect: &["P1"],
    },
    Fixture {
        // The real shape: a missing arena entry means the request
        // already completed; the timeout is stale and simply dropped.
        name: "p1_retry_stale_handled_clean",
        path: "rust/src/app/fixture.rs",
        src: r##"
pub fn backoff_for(attempts: &[u32], idx: usize, base: u64) -> Option<u64> {
    let k = *attempts.get(idx)?;
    Some(base << k.min(16))
}
"##,
        expect: &[],
    },
    Fixture {
        // The hybrid scaler's reactive override decides from scraped
        // SLA-violation rates and the forecast guard's z-score — both
        // deterministic tick inputs. A panic there tears down the run
        // on every tick, so P1 applies to hybrid.rs like the zoo files.
        name: "p1_hybrid_unwrap_fires",
        path: "rust/src/autoscaler/hybrid.rs",
        src: r##"
pub fn violation_rate(series: &[f64]) -> f64 {
    *series.last().unwrap()
}
"##,
        expect: &["P1"],
    },
    Fixture {
        // The real shape: an empty series is "no signal", not a panic.
        name: "p1_hybrid_handled_clean",
        path: "rust/src/autoscaler/hybrid.rs",
        src: r##"
pub fn violation_rate(series: &[f64]) -> f64 {
    series.last().copied().unwrap_or(0.0)
}
"##,
        expect: &[],
    },
    Fixture {
        // The real shape: insufficient history is a `None`, and the
        // seasonal index derives from the deterministic row count.
        name: "p1_holt_winters_handled_clean",
        path: "rust/src/forecast/holt_winters.rs",
        src: r##"
pub fn seasonal_index(history_len: usize, season: usize) -> Option<usize> {
    if season == 0 || history_len < 2 * season {
        return None;
    }
    Some(history_len % season)
}
"##,
        expect: &[],
    },
];

/// Run the whole corpus; `Err` lists every mismatching fixture.
pub fn run_all() -> Result<usize, String> {
    let mut failures = Vec::new();
    for f in FIXTURES {
        let diags = lint_source(f.path, f.src);
        let got: Vec<&str> = diags.iter().map(|d| d.rule).collect();
        if got != f.expect {
            failures.push(format!(
                "fixture `{}` ({}): expected rules {:?}, got {:?}\n{}",
                f.name,
                f.path,
                f.expect,
                got,
                diags
                    .iter()
                    .map(|d| format!("  {d}"))
                    .collect::<Vec<_>>()
                    .join("\n"),
            ));
        }
    }
    if failures.is_empty() {
        Ok(FIXTURES.len())
    } else {
        Err(failures.join("\n"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every fixture as one named assertion batch: positives fire,
    /// negatives stay silent, pragmas behave.
    #[test]
    fn corpus_matches_expectations() {
        if let Err(report) = run_all() {
            panic!("fixture corpus mismatch:\n{report}");
        }
    }

    #[test]
    fn corpus_covers_every_rule_both_ways() {
        for rule in ["D1", "D2", "N1", "P1", "S1"] {
            assert!(
                FIXTURES.iter().any(|f| f.expect.contains(&rule)),
                "no positive fixture for {rule}"
            );
        }
        // Each lint rule also needs at least one clean fixture in scope.
        assert!(FIXTURES.iter().any(|f| f.expect.is_empty()));
    }

    #[test]
    fn diagnostics_carry_position_and_text() {
        let f = FIXTURES
            .iter()
            .find(|f| f.name == "d1_instant_fires")
            .unwrap();
        let diags = lint_source(f.path, f.src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 3);
        assert_eq!(diags[0].path, f.path);
        assert!(diags[0].message.contains("Instant"));
    }
}

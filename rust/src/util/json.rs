//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for `artifacts/manifest.json`, config
//! files, and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Path lookup: `get_path(&["adam", "lr"])`.
    pub fn get_path(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get_path(&["c", "d"]).as_bool(), Some(true));
        assert_eq!(v.get_path(&["a", "missing"]), &Json::Null);
    }

    #[test]
    fn parses_string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips_display() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let v = Json::parse(doc).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors_none_on_wrong_type() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_obj().is_none());
        assert!(v.as_str().is_none());
        assert_eq!(v.get("x"), &Json::Null);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}

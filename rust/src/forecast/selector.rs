//! Online champion–challenger model selection.
//!
//! [`ChampionChallenger`] wraps K boxed forecasters for one service. The
//! current *champion* drives the scaling decision; every other member is
//! a *challenger* running shadow-mode: it predicts on the same history
//! each tick, and when the realized vector arrives its squared error on
//! the score metric is folded into the existing Welford
//! [`StreamingStats`] machinery. After every `eval_window` ticks the
//! selector reviews the window and promotes the lowest-MSE challenger —
//! but only past a hysteresis `margin`, so two models trading
//! statistically-even windows never flap the champion back and forth.
//!
//! Determinism contract: selection state is a pure function of the
//! observed metric stream (and the members' own seeded state). There is
//! no wall clock, no ambient randomness, and no dependence on thread or
//! shard layout — each selector instance lives inside one service's
//! scaler, so runs stay bit-identical across repeats, thread counts,
//! and `--shards 1|2|4|8`. With K = 1 the wrapper is exactly
//! transparent: the single member sees the same `predict` / `observe` /
//! `retrain` sequence the bare PPA would deliver, and the confidence
//! gate delegates, so decision logs reproduce the bare run bit-for-bit
//! (covered by `tests/forecast_zoo.rs`).

use super::{Forecaster, UpdatePolicy};
use crate::metrics::{METRIC_DIM, M_CPU};
use crate::stats::StreamingStats;

/// Promotion-review tuning. Plain data; the defaults are what
/// `ForecasterKind::Auto` builds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SelectorConfig {
    /// Ticks between promotion reviews (default 30 ≙ 10 min of 20 s
    /// control loops). A review scores only predictions closed inside
    /// the window, so every model starts each window from zero.
    pub eval_window: usize,
    /// Hysteresis: a challenger is promoted only when its window MSE is
    /// below `champion_mse * (1 - margin)`. Defaults to 0.1 (10%).
    pub margin: f64,
    /// Protocol-vector component the shadow MSE is scored on (default
    /// `M_CPU`, the paper's primary metric).
    pub score_metric: usize,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            eval_window: 30,
            margin: 0.1,
            score_metric: M_CPU,
        }
    }
}

/// One model's cumulative shadow score, for reports.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelScore {
    pub name: String,
    /// Cumulative shadow MSE on the score metric; `None` when the model
    /// never produced a scoreable prediction.
    pub mse: Option<f64>,
    /// Number of closed (prediction, actual) pairs scored.
    pub n: usize,
}

/// Structured record of one promotion decision.
#[derive(Debug, Clone, PartialEq)]
pub struct PromotionRecord {
    /// Tick count at the review that promoted (1-based, in observed
    /// vectors).
    pub tick: u64,
    pub from: String,
    pub to: String,
    /// Window MSE of the outgoing champion (NaN when it never scored).
    pub from_mse: f64,
    /// Window MSE of the incoming champion.
    pub to_mse: f64,
}

/// Snapshot of a selector's state after a run: the final champion, each
/// member's cumulative shadow score, and the promotion log.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectionSummary {
    pub champion: String,
    pub models: Vec<ModelScore>,
    pub promotions: Vec<PromotionRecord>,
}

/// A wrapped forecaster plus its shadow-scoring state.
struct Member {
    model: Box<dyn Forecaster + Send>,
    /// The vector this member predicted for the *next* observed tick.
    pending: Option<[f64; METRIC_DIM]>,
    /// Squared errors closed inside the current review window.
    window: StreamingStats,
    /// Squared errors over the whole run (reported in the sweep JSON).
    total: StreamingStats,
}

/// The selection wrapper. Implements [`Forecaster`] itself, so it slots
/// into the PPA `Evaluator` unchanged.
pub struct ChampionChallenger {
    members: Vec<Member>,
    champion: usize,
    cfg: SelectorConfig,
    label: String,
    /// Observed vectors so far (drives the review cadence).
    ticks: u64,
    promotions: Vec<PromotionRecord>,
}

impl ChampionChallenger {
    /// Wrap `models` (member 0 starts as champion) under `cfg`. Members
    /// must be `Send` so the selector itself stays `Send` — the whole
    /// zoo qualifies; only the PJRT LSTM does not.
    pub fn new(models: Vec<Box<dyn Forecaster + Send>>, cfg: SelectorConfig) -> Self {
        assert!(!models.is_empty(), "champion-challenger needs >= 1 model");
        let label = format!("auto:{}", models.len());
        ChampionChallenger {
            members: models
                .into_iter()
                .map(|model| Member {
                    model,
                    pending: None,
                    window: StreamingStats::new(),
                    total: StreamingStats::new(),
                })
                .collect(),
            champion: 0,
            cfg,
            label,
            ticks: 0,
            promotions: Vec::new(),
        }
    }

    /// Name of the current champion.
    pub fn champion_name(&self) -> &str {
        self.members[self.champion].model.name()
    }

    /// The promotion log so far.
    pub fn promotions(&self) -> &[PromotionRecord] {
        &self.promotions
    }

    /// Review the window: promote the best challenger iff it clears the
    /// hysteresis margin, then reset every window accumulator.
    fn review(&mut self) {
        let incumbent = self.champion;
        let incumbent_scored = !self.members[incumbent].window.is_empty();
        let incumbent_mse = self.members[incumbent].window.mean();
        let mut best: Option<(usize, f64)> = None;
        for (i, member) in self.members.iter().enumerate() {
            if i == incumbent || member.window.is_empty() {
                continue;
            }
            let mse = member.window.mean();
            // A silent champion (no scoreable predictions all window —
            // e.g. an unfitted model) loses to any scoring challenger.
            let clears = !incumbent_scored || mse < incumbent_mse * (1.0 - self.cfg.margin);
            if clears && best.is_none_or(|(_, b)| mse < b) {
                best = Some((i, mse));
            }
        }
        if let Some((winner, mse)) = best {
            self.promotions.push(PromotionRecord {
                tick: self.ticks,
                from: self.members[incumbent].model.name().to_string(),
                to: self.members[winner].model.name().to_string(),
                from_mse: incumbent_mse,
                to_mse: mse,
            });
            self.champion = winner;
        }
        for member in &mut self.members {
            member.window = StreamingStats::new();
        }
    }
}

impl Forecaster for ChampionChallenger {
    fn name(&self) -> &str {
        &self.label
    }

    /// Every member predicts shadow-mode; the champion's prediction is
    /// returned as the selector's own.
    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let mut out = None;
        for (i, member) in self.members.iter_mut().enumerate() {
            let p = member.model.predict(history);
            member.pending = p;
            if i == self.champion {
                out = p;
            }
        }
        out
    }

    /// Forward the update to every member. The selector succeeds when at
    /// least one member retrains (so the shared history file is cleared
    /// exactly as for a bare model); it fails only when every member
    /// fails, propagating the last error.
    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()> {
        let mut last_err = None;
        let mut any_ok = false;
        for member in &mut self.members {
            match member.model.retrain(history, policy) {
                Ok(()) => any_ok = true,
                Err(e) => last_err = Some(e),
            }
        }
        match (any_ok, last_err) {
            (true, _) | (false, None) => Ok(()),
            (false, Some(e)) => Err(e),
        }
    }

    /// Close every pending shadow prediction against the realized
    /// vector, forward the observation, and run a promotion review when
    /// the window fills.
    fn observe(&mut self, actual: &[f64; METRIC_DIM]) {
        let metric = self.cfg.score_metric;
        for member in &mut self.members {
            if let Some(pred) = member.pending.take() {
                let err = pred[metric] - actual[metric];
                member.window.record(err * err);
                member.total.record(err * err);
            }
            member.model.observe(actual);
        }
        self.ticks += 1;
        if self.cfg.eval_window > 0 && self.ticks % self.cfg.eval_window as u64 == 0 {
            self.review();
        }
    }

    /// The confidence gate delegates to the champion, so an `auto:1`
    /// wrapper gates identically to the bare model.
    fn is_bayesian(&self) -> bool {
        self.members[self.champion].model.is_bayesian()
    }

    fn confidence(&self) -> f64 {
        self.members[self.champion].model.confidence()
    }

    fn selection(&self) -> Option<SelectionSummary> {
        Some(SelectionSummary {
            champion: self.champion_name().to_string(),
            models: self
                .members
                .iter()
                .map(|m| ModelScore {
                    name: m.model.name().to_string(),
                    mse: (!m.total.is_empty()).then(|| m.total.mean()),
                    n: m.total.n(),
                })
                .collect(),
            promotions: self.promotions.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecast::NaiveForecaster;

    /// Scripted model: always predicts `actual + bias` one tick ahead of
    /// the deterministic ramp used in the tests, so its shadow MSE is
    /// exactly `bias²`.
    struct Biased {
        name: String,
        bias: f64,
        last: Option<[f64; METRIC_DIM]>,
    }

    impl Biased {
        fn new(name: &str, bias: f64) -> Self {
            Biased {
                name: name.to_string(),
                bias,
                last: None,
            }
        }
    }

    impl Forecaster for Biased {
        fn name(&self) -> &str {
            &self.name
        }
        fn predict(&mut self, _h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
            // Predict the *next* actual (tests feed a constant series)
            // offset by the bias.
            self.last.map(|v| {
                let mut p = v;
                p[M_CPU] += self.bias;
                p
            })
        }
        fn retrain(
            &mut self,
            _h: &[[f64; METRIC_DIM]],
            _p: UpdatePolicy,
        ) -> crate::Result<()> {
            Ok(())
        }
        fn observe(&mut self, actual: &[f64; METRIC_DIM]) {
            self.last = Some(*actual);
        }
    }

    fn drive(sel: &mut ChampionChallenger, ticks: usize) {
        let actual = [50.0; METRIC_DIM];
        for _ in 0..ticks {
            sel.observe(&actual);
            let _ = sel.predict(&[actual]);
        }
    }

    fn cfg(window: usize, margin: f64) -> SelectorConfig {
        SelectorConfig {
            eval_window: window,
            margin,
            score_metric: M_CPU,
        }
    }

    #[test]
    fn clear_winner_is_promoted_once() {
        // Champion bias 10 (MSE 100), challenger bias 1 (MSE 1).
        let mut sel = ChampionChallenger::new(
            vec![
                Box::new(Biased::new("bad", 10.0)),
                Box::new(Biased::new("good", 1.0)),
            ],
            cfg(10, 0.1),
        );
        assert_eq!(sel.champion_name(), "bad");
        drive(&mut sel, 100);
        assert_eq!(sel.champion_name(), "good");
        assert_eq!(sel.promotions().len(), 1, "{:?}", sel.promotions());
        let p = &sel.promotions()[0];
        assert_eq!((p.from.as_str(), p.to.as_str()), ("bad", "good"));
        assert!(p.to_mse < p.from_mse);
        assert_eq!(p.tick, 10, "promoted at the first review");
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        // Two models within the 10% hysteresis band of each other: the
        // marginally-better challenger must never be promoted, no matter
        // how many review windows pass.
        let mut sel = ChampionChallenger::new(
            vec![
                Box::new(Biased::new("a", 10.0)), // MSE 100
                Box::new(Biased::new("b", 9.6)),  // MSE 92.16 > 100*0.9
            ],
            cfg(5, 0.1),
        );
        drive(&mut sel, 200);
        assert_eq!(sel.champion_name(), "a");
        assert!(sel.promotions().is_empty(), "{:?}", sel.promotions());
    }

    #[test]
    fn margin_zero_still_requires_strict_improvement() {
        let mut sel = ChampionChallenger::new(
            vec![
                Box::new(Biased::new("a", 2.0)),
                Box::new(Biased::new("tie", 2.0)),
            ],
            cfg(5, 0.0),
        );
        drive(&mut sel, 100);
        assert_eq!(sel.champion_name(), "a", "ties never flap");
        assert!(sel.promotions().is_empty());
    }

    #[test]
    fn silent_champion_loses_to_scoring_challenger() {
        struct Mute;
        impl Forecaster for Mute {
            fn name(&self) -> &str {
                "mute"
            }
            fn predict(&mut self, _h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
                None
            }
            fn retrain(
                &mut self,
                _h: &[[f64; METRIC_DIM]],
                _p: UpdatePolicy,
            ) -> crate::Result<()> {
                Ok(())
            }
        }
        let mut sel = ChampionChallenger::new(
            vec![Box::new(Mute), Box::new(Biased::new("live", 3.0))],
            cfg(10, 0.1),
        );
        drive(&mut sel, 20);
        assert_eq!(sel.champion_name(), "live");
    }

    #[test]
    fn auto1_is_transparent() {
        // A K=1 wrapper forwards predict/observe verbatim and reports
        // the member's name as champion.
        let mut bare = NaiveForecaster;
        let mut sel =
            ChampionChallenger::new(vec![Box::new(NaiveForecaster)], SelectorConfig::default());
        assert_eq!(sel.name(), "auto:1");
        assert_eq!(sel.champion_name(), "naive-last-value");
        let h = vec![[7.0; METRIC_DIM], [9.0; METRIC_DIM]];
        assert_eq!(sel.predict(&h), bare.predict(&h));
        assert_eq!(sel.is_bayesian(), bare.is_bayesian());
        assert_eq!(sel.confidence(), bare.confidence());
        assert!(sel.retrain(&h, UpdatePolicy::FineTune).is_ok());
    }

    #[test]
    fn retrain_ok_when_any_member_fits() {
        struct Refusenik;
        impl Forecaster for Refusenik {
            fn name(&self) -> &str {
                "refusenik"
            }
            fn predict(&mut self, _h: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
                None
            }
            fn retrain(
                &mut self,
                _h: &[[f64; METRIC_DIM]],
                _p: UpdatePolicy,
            ) -> crate::Result<()> {
                anyhow::bail!("never fits")
            }
        }
        let mut mixed = ChampionChallenger::new(
            vec![Box::new(Refusenik), Box::new(NaiveForecaster)],
            SelectorConfig::default(),
        );
        assert!(mixed.retrain(&[], UpdatePolicy::FineTune).is_ok());
        let mut all_bad = ChampionChallenger::new(
            vec![Box::new(Refusenik), Box::new(Refusenik)],
            SelectorConfig::default(),
        );
        assert!(all_bad.retrain(&[], UpdatePolicy::FineTune).is_err());
    }

    #[test]
    fn selection_summary_reports_scores_and_promotions() {
        let mut sel = ChampionChallenger::new(
            vec![
                Box::new(Biased::new("bad", 4.0)),
                Box::new(Biased::new("good", 1.0)),
            ],
            cfg(10, 0.1),
        );
        drive(&mut sel, 30);
        let s = Forecaster::selection(&sel).expect("selector always has a summary");
        assert_eq!(s.champion, "good");
        assert_eq!(s.models.len(), 2);
        assert_eq!(s.models[0].name, "bad");
        let bad_mse = s.models[0].mse.expect("scored");
        let good_mse = s.models[1].mse.expect("scored");
        assert!((bad_mse - 16.0).abs() < 1e-9, "{bad_mse}");
        assert!((good_mse - 1.0).abs() < 1e-9, "{good_mse}");
        assert_eq!(s.promotions.len(), 1);
    }

    #[test]
    fn reviews_are_deterministic_across_repeats() {
        let build = || {
            ChampionChallenger::new(
                vec![
                    Box::new(Biased::new("a", 5.0)),
                    Box::new(Biased::new("b", 2.0)),
                    Box::new(Biased::new("c", 8.0)),
                ],
                cfg(7, 0.05),
            )
        };
        let mut x = build();
        let mut y = build();
        drive(&mut x, 150);
        drive(&mut y, 150);
        assert_eq!(Forecaster::selection(&x), Forecaster::selection(&y));
    }
}

//! Configuration system: cluster topology (Table 2), application costs,
//! PPA arguments (Table 4), and experiment parameters — loadable from
//! JSON files and shipped as presets mirroring the paper's testbed.

mod presets;

pub use presets::*;

use crate::app::TaskCosts;
use crate::cluster::{Cluster, Deployment, NodeSpec, PodSpec, Selector, Tier};
use crate::forecast::UpdatePolicy;
use crate::sim::{Time, HOUR, MS, SEC};
use crate::util::json::Json;
use anyhow::{bail, Context};

/// One node entry in a cluster config.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub name: String,
    pub tier: Tier,
    pub zone: u32,
    pub cpu_millis: u32,
    pub ram_mb: u32,
    pub reserved_cpu_millis: u32,
    pub reserved_ram_mb: u32,
}

/// One autoscaled deployment entry.
#[derive(Debug, Clone)]
pub struct DeploymentConfig {
    pub name: String,
    pub tier: Tier,
    pub zone: Option<u32>,
    pub pod_cpu_millis: u32,
    pub pod_ram_mb: u32,
    pub min_replicas: usize,
    pub max_replicas: usize,
    pub initial_replicas: usize,
}

/// Full cluster topology.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeConfig>,
    pub deployments: Vec<DeploymentConfig>,
}

impl ClusterConfig {
    /// Materialize a [`Cluster`]; returns it plus deployment ids in
    /// config order.
    pub fn build(&self) -> (Cluster, Vec<crate::cluster::DeploymentId>) {
        let mut cluster = Cluster::new();
        for n in &self.nodes {
            cluster.add_node(
                NodeSpec::new(&n.name, n.tier, n.zone, n.cpu_millis, n.ram_mb)
                    .with_reserved(n.reserved_cpu_millis, n.reserved_ram_mb),
            );
        }
        let mut ids = Vec::new();
        for d in &self.deployments {
            ids.push(cluster.add_deployment(Deployment::new(
                &d.name,
                Selector::new(d.tier, d.zone),
                PodSpec::new(d.pod_cpu_millis, d.pod_ram_mb),
                d.min_replicas,
                d.max_replicas,
            )));
        }
        (cluster, ids)
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.nodes.is_empty() {
            bail!("cluster has no nodes");
        }
        if self.deployments.is_empty() {
            bail!("cluster has no deployments");
        }
        for d in &self.deployments {
            if d.pod_cpu_millis == 0 {
                bail!("deployment {} has zero-CPU pods", d.name);
            }
            if d.min_replicas > d.max_replicas {
                bail!("deployment {}: min > max replicas", d.name);
            }
            // Every deployment must have at least one matching node.
            let sel = Selector::new(d.tier, d.zone);
            let matches = self.nodes.iter().any(|n| {
                sel.matches(
                    &NodeSpec::new(&n.name, n.tier, n.zone, n.cpu_millis, n.ram_mb),
                )
            });
            if !matches {
                bail!("deployment {} matches no node", d.name);
            }
        }
        Ok(())
    }
}

/// A worker-node hardware class for heterogeneous city grids.
///
/// `Medium` is the Table-2 edge worker (2000 mCPU / 2048 MB); `Small`
/// and `Large` halve / double it. All classes keep the Table-2 edge
/// reservation (300 mCPU / 384 MB), so a homogeneous `medium` mix is
/// byte-identical to the classic [`edge_city`] grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeClass {
    /// 1000 mCPU / 1024 MB.
    Small,
    /// 2000 mCPU / 2048 MB — the Table-2 edge worker.
    #[default]
    Medium,
    /// 4000 mCPU / 4096 MB.
    Large,
}

impl NodeClass {
    pub fn cpu_millis(&self) -> u32 {
        match self {
            NodeClass::Small => 1000,
            NodeClass::Medium => 2000,
            NodeClass::Large => 4000,
        }
    }

    pub fn ram_mb(&self) -> u32 {
        match self {
            NodeClass::Small => 1024,
            NodeClass::Medium => 2048,
            NodeClass::Large => 4096,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            NodeClass::Small => "small",
            NodeClass::Medium => "medium",
            NodeClass::Large => "large",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "small" => Ok(NodeClass::Small),
            "medium" => Ok(NodeClass::Medium),
            "large" => Ok(NodeClass::Large),
            other => bail!("unknown node class '{other}' (expected small|medium|large)"),
        }
    }
}

/// Maximum classes a [`ClassMix`] cycles through.
pub const MAX_MIX_CLASSES: usize = 4;

/// Per-zone worker class mix for city grids: worker `i` of every zone
/// gets class `classes[i % len]`. The empty mix (the `Default`) means
/// homogeneous `Medium` workers — the classic grid. Inline storage
/// keeps [`Topology`] `Copy` for the sweep grid axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassMix {
    len: u8,
    classes: [NodeClass; MAX_MIX_CLASSES],
}

impl ClassMix {
    /// A mix cycling through `classes` (1..=[`MAX_MIX_CLASSES`] entries).
    pub fn new(classes: &[NodeClass]) -> crate::Result<Self> {
        if classes.is_empty() {
            bail!("class mix needs at least one class");
        }
        if classes.len() > MAX_MIX_CLASSES {
            bail!(
                "class mix supports at most {MAX_MIX_CLASSES} classes, got {}",
                classes.len()
            );
        }
        let mut arr = [NodeClass::Medium; MAX_MIX_CLASSES];
        arr[..classes.len()].copy_from_slice(classes);
        Ok(ClassMix {
            len: classes.len() as u8,
            classes: arr,
        })
    }

    /// True for the homogeneous default (all workers `Medium`).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The class of worker `i` within its zone.
    pub fn class_for(&self, worker: u32) -> NodeClass {
        if self.len == 0 {
            return NodeClass::Medium;
        }
        self.classes[(worker as usize) % self.len as usize]
    }

    /// Parse `small,large` (comma-separated class names).
    pub fn parse(s: &str) -> crate::Result<Self> {
        let classes: Vec<NodeClass> = s
            .split(',')
            .map(|p| NodeClass::parse(p.trim()))
            .collect::<crate::Result<_>>()?;
        ClassMix::new(&classes)
    }

    /// `small,large` — empty string for the homogeneous default.
    pub fn label(&self) -> String {
        let parts: Vec<&str> = self.classes[..self.len as usize]
            .iter()
            .map(|c| c.label())
            .collect();
        parts.join(",")
    }
}

/// A named cluster-topology descriptor: copyable grid-axis data for the
/// sweep harness (the way [`crate::workload::Scenario`] describes a
/// workload). `parse` accepts `paper`, `city-<zones>`,
/// `city-<zones>x<workers>` and a `:<classes>` suffix on the city forms
/// (e.g. `city-50x4:small,large` — heterogeneous worker classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// The Table-2 testbed: 2 edge zones of 2 workers.
    Paper,
    /// Generated city: `zones` edge zones × `workers_per_zone` nodes
    /// of classes cycling through `mix` (see [`edge_city_with_classes`]).
    EdgeCity {
        zones: u32,
        workers_per_zone: u32,
        mix: ClassMix,
    },
}

impl Topology {
    /// Default worker count per city zone (matches Table 2's 2/zone).
    pub const DEFAULT_CITY_WORKERS: u32 = 2;

    pub fn parse(s: &str) -> crate::Result<Self> {
        if s == "paper" {
            return Ok(Topology::Paper);
        }
        if let Some(rest) = s.strip_prefix("city-") {
            let (dims, mix_str) = match rest.split_once(':') {
                Some((d, m)) => (d, Some(m)),
                None => (rest, None),
            };
            let (zones_str, workers_str) = match dims.split_once('x') {
                Some((z, w)) => (z, Some(w)),
                None => (dims, None),
            };
            let zones: u32 = zones_str
                .parse()
                .ok()
                .filter(|&z| z >= 1)
                .with_context(|| format!("bad zone count in topology '{s}'"))?;
            let workers_per_zone: u32 = match workers_str {
                Some(w) => w
                    .parse()
                    .ok()
                    .filter(|&w| w >= 1)
                    .with_context(|| format!("bad worker count in topology '{s}'"))?,
                None => Self::DEFAULT_CITY_WORKERS,
            };
            let mix = match mix_str {
                Some(m) => ClassMix::parse(m)
                    .with_context(|| format!("bad node-class mix in topology '{s}'"))?,
                None => ClassMix::default(),
            };
            return Ok(Topology::EdgeCity {
                zones,
                workers_per_zone,
                mix,
            });
        }
        bail!(
            "unknown topology '{s}' (expected paper | \
             city-<zones>[x<workers>][:<class,...>])"
        )
    }

    /// Report/JSON label (round-trips through [`Self::parse`]).
    pub fn label(&self) -> String {
        match *self {
            Topology::Paper => "paper".to_string(),
            Topology::EdgeCity {
                zones,
                workers_per_zone,
                mix,
            } => {
                if mix.is_empty() {
                    format!("city-{zones}x{workers_per_zone}")
                } else {
                    format!("city-{zones}x{workers_per_zone}:{}", mix.label())
                }
            }
        }
    }

    /// Materializable cluster config.
    pub fn cluster(&self) -> ClusterConfig {
        match *self {
            Topology::Paper => paper_cluster(),
            Topology::EdgeCity {
                zones,
                workers_per_zone,
                mix,
            } => edge_city_with_classes(zones, workers_per_zone, mix),
        }
    }

    /// The scenario preset library matched to this topology's zones.
    pub fn scenario_presets(&self) -> Vec<(String, crate::workload::Scenario)> {
        match *self {
            Topology::Paper => scenario_presets(),
            Topology::EdgeCity { zones, .. } => city_scenario_presets(zones),
        }
    }
}

/// PPA arguments — Table 4 of the paper.
#[derive(Debug, Clone)]
pub struct PpaArgs {
    /// `ModelType`: "lstm", "arma" or "naive".
    pub model_type: String,
    /// `KeyMetric`: a metric name ("cpu", "ram", "net_in", "net_out",
    /// "req_rate") or protocol-vector index ("0".."4").
    pub key_metric: String,
    /// `ControlInterval` in seconds.
    pub control_interval_secs: u64,
    /// `UpdateInterval` in hours.
    pub update_interval_hours: f64,
    /// `Threashold` (sic, Table 4) on the key metric.
    pub threshold: f64,
    /// Update policy 1/2/3 (§4.2.3).
    pub update_policy: u8,
    /// Confidence threshold for Bayesian models.
    pub confidence_threshold: f64,
}

impl Default for PpaArgs {
    fn default() -> Self {
        PpaArgs {
            model_type: "lstm".into(),
            key_metric: "cpu".into(),
            control_interval_secs: 20,
            update_interval_hours: 1.0,
            threshold: 70.0,
            update_policy: 3,
            confidence_threshold: 0.5,
        }
    }
}

impl PpaArgs {
    pub fn key_metric_index(&self) -> crate::Result<usize> {
        crate::metrics::parse_metric(&self.key_metric)
            .with_context(|| format!("bad KeyMetric '{}'", self.key_metric))
    }

    pub fn update_policy_enum(&self) -> crate::Result<UpdatePolicy> {
        Ok(match self.update_policy {
            1 => UpdatePolicy::KeepSeed,
            2 => UpdatePolicy::RetrainScratch,
            3 => UpdatePolicy::FineTune,
            p => bail!("update policy must be 1..=3, got {p}"),
        })
    }

    pub fn control_interval(&self) -> Time {
        self.control_interval_secs * SEC
    }

    pub fn update_interval(&self) -> Time {
        (self.update_interval_hours * HOUR as f64) as Time
    }

    /// To the runtime PpaConfig (single-spec form: Table 4 has one
    /// `KeyMetric`/`Threashold` pair; multi-metric fleets are built via
    /// [`crate::autoscaler::ScalerRegistry`] / the CLI `--metric` flags).
    pub fn to_ppa_config(&self) -> crate::Result<crate::autoscaler::PpaConfig> {
        Ok(crate::autoscaler::PpaConfig {
            specs: vec![crate::autoscaler::MetricSpec::forecast(
                self.key_metric_index()?,
                self.threshold,
            )],
            control_interval: self.control_interval(),
            update_interval: self.update_interval(),
            update_policy: self.update_policy_enum()?,
            confidence_threshold: self.confidence_threshold,
            behavior: crate::autoscaler::ScalingBehavior::stabilize_down(2 * crate::sim::MIN),
        })
    }
}

// ---------------------------------------------------------------------------
// JSON loading
// ---------------------------------------------------------------------------

fn tier_from(s: &str) -> crate::Result<Tier> {
    match s {
        "cloud" => Ok(Tier::Cloud),
        "edge" => Ok(Tier::Edge),
        other => bail!("unknown tier '{other}'"),
    }
}

impl ClusterConfig {
    pub fn from_json(doc: &Json) -> crate::Result<Self> {
        let mut nodes = Vec::new();
        for n in doc.get("nodes").as_arr().context("nodes array")? {
            nodes.push(NodeConfig {
                name: n.get("name").as_str().context("node.name")?.to_string(),
                tier: tier_from(n.get("tier").as_str().context("node.tier")?)?,
                zone: n.get("zone").as_usize().context("node.zone")? as u32,
                cpu_millis: n.get("cpu_millis").as_usize().context("node.cpu_millis")? as u32,
                ram_mb: n.get("ram_mb").as_usize().context("node.ram_mb")? as u32,
                reserved_cpu_millis: n.get("reserved_cpu_millis").as_usize().unwrap_or(200)
                    as u32,
                reserved_ram_mb: n.get("reserved_ram_mb").as_usize().unwrap_or(256) as u32,
            });
        }
        let mut deployments = Vec::new();
        for d in doc.get("deployments").as_arr().context("deployments")? {
            deployments.push(DeploymentConfig {
                name: d.get("name").as_str().context("dep.name")?.to_string(),
                tier: tier_from(d.get("tier").as_str().context("dep.tier")?)?,
                zone: d.get("zone").as_usize().map(|z| z as u32),
                pod_cpu_millis: d
                    .get("pod_cpu_millis")
                    .as_usize()
                    .context("dep.pod_cpu_millis")? as u32,
                pod_ram_mb: d.get("pod_ram_mb").as_usize().context("dep.pod_ram_mb")? as u32,
                min_replicas: d.get("min_replicas").as_usize().unwrap_or(1),
                max_replicas: d.get("max_replicas").as_usize().unwrap_or(100),
                initial_replicas: d.get("initial_replicas").as_usize().unwrap_or(1),
            });
        }
        let cfg = ClusterConfig { nodes, deployments };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text)?;
        Self::from_json(doc.get("cluster"))
            .or_else(|_| Self::from_json(&doc))
            .with_context(|| format!("parsing cluster config {}", path.display()))
    }
}

impl PpaArgs {
    pub fn from_json(doc: &Json) -> crate::Result<Self> {
        let d = PpaArgs::default();
        let args = PpaArgs {
            model_type: doc
                .get("ModelType")
                .as_str()
                .unwrap_or(&d.model_type)
                .to_string(),
            key_metric: doc
                .get("KeyMetric")
                .as_str()
                .unwrap_or(&d.key_metric)
                .to_string(),
            control_interval_secs: doc
                .get("ControlInterval")
                .as_usize()
                .unwrap_or(d.control_interval_secs as usize) as u64,
            update_interval_hours: doc
                .get("UpdateInterval")
                .as_f64()
                .unwrap_or(d.update_interval_hours),
            threshold: doc.get("Threashold").as_f64().unwrap_or(d.threshold),
            update_policy: doc.get("UpdatePolicy").as_usize().unwrap_or(3) as u8,
            confidence_threshold: doc
                .get("ConfidenceThreshold")
                .as_f64()
                .unwrap_or(d.confidence_threshold),
        };
        // Validate eagerly.
        args.key_metric_index()?;
        args.update_policy_enum()?;
        if args.control_interval_secs == 0 {
            bail!("ControlInterval must be positive");
        }
        Ok(args)
    }
}

/// Task-cost calibration from JSON (optional fields, defaults otherwise).
pub fn costs_from_json(doc: &Json) -> TaskCosts {
    let d = TaskCosts::default();
    TaskCosts {
        sort_core_secs: doc.get("sort_core_secs").as_f64().unwrap_or(d.sort_core_secs),
        eigen_core_secs: doc
            .get("eigen_core_secs")
            .as_f64()
            .unwrap_or(d.eigen_core_secs),
        overhead: doc
            .get("overhead_ms")
            .as_f64()
            .map(|ms| (ms * MS as f64) as Time)
            .unwrap_or(d.overhead),
        network_latency: doc
            .get("network_latency_ms")
            .as_f64()
            .map(|ms| (ms * MS as f64) as Time)
            .unwrap_or(d.network_latency),
        forward_latency: doc
            .get("forward_latency_ms")
            .as_f64()
            .map(|ms| (ms * MS as f64) as Time)
            .unwrap_or(d.forward_latency),
        jitter_std: doc.get("jitter_std").as_f64().unwrap_or(d.jitter_std),
        base_burn_frac: doc
            .get("base_burn_frac")
            .as_f64()
            .unwrap_or(d.base_burn_frac),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_builds_and_validates() {
        let cfg = paper_cluster();
        cfg.validate().unwrap();
        let (cluster, ids) = cfg.build();
        assert_eq!(cluster.nodes.len(), 7); // 1 control + 2 cloud + 4 edge
        assert_eq!(ids.len(), 3); // z1, z2, cloud
    }

    #[test]
    fn cluster_json_roundtrip() {
        let text = r#"{
          "nodes": [
            {"name": "c1", "tier": "cloud", "zone": 0, "cpu_millis": 3000, "ram_mb": 3072},
            {"name": "e1", "tier": "edge", "zone": 1, "cpu_millis": 2000, "ram_mb": 2048}
          ],
          "deployments": [
            {"name": "edge-z1", "tier": "edge", "zone": 1,
             "pod_cpu_millis": 500, "pod_ram_mb": 256},
            {"name": "cloud", "tier": "cloud",
             "pod_cpu_millis": 1000, "pod_ram_mb": 512, "max_replicas": 8}
          ]
        }"#;
        let cfg = ClusterConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.deployments[1].max_replicas, 8);
        assert_eq!(cfg.deployments[0].zone, Some(1));
        assert_eq!(cfg.deployments[1].zone, None);
    }

    #[test]
    fn invalid_cluster_rejected() {
        // Deployment matches no node.
        let text = r#"{
          "nodes": [{"name": "c1", "tier": "cloud", "zone": 0, "cpu_millis": 3000, "ram_mb": 3072}],
          "deployments": [{"name": "edge", "tier": "edge", "pod_cpu_millis": 500, "pod_ram_mb": 256}]
        }"#;
        assert!(ClusterConfig::from_json(&Json::parse(text).unwrap()).is_err());
    }

    #[test]
    fn ppa_args_table4_mapping() {
        let doc = Json::parse(
            r#"{"ModelType": "arma", "KeyMetric": "req_rate", "ControlInterval": 30,
                "UpdateInterval": 2, "Threashold": 4.5, "UpdatePolicy": 2}"#,
        )
        .unwrap();
        let args = PpaArgs::from_json(&doc).unwrap();
        assert_eq!(args.model_type, "arma");
        assert_eq!(args.key_metric_index().unwrap(), crate::metrics::M_REQ_RATE);
        assert_eq!(args.control_interval(), 30 * SEC);
        assert_eq!(args.update_interval(), 2 * HOUR);
        assert_eq!(
            args.update_policy_enum().unwrap(),
            UpdatePolicy::RetrainScratch
        );
        assert!((args.threshold - 4.5).abs() < 1e-12);
        // The runtime config is the single-spec pipeline form.
        let cfg = args.to_ppa_config().unwrap();
        assert_eq!(cfg.specs.len(), 1);
        assert_eq!(cfg.specs[0].metric, crate::metrics::M_REQ_RATE);
        assert!((cfg.specs[0].target - 4.5).abs() < 1e-12);
    }

    #[test]
    fn ppa_args_key_metric_by_index() {
        // Satellite: indices accepted anywhere names are.
        let doc = Json::parse(r#"{"KeyMetric": "4"}"#).unwrap();
        let args = PpaArgs::from_json(&doc).unwrap();
        assert_eq!(args.key_metric_index().unwrap(), crate::metrics::M_REQ_RATE);
    }

    #[test]
    fn ppa_args_bad_values_rejected() {
        let doc = Json::parse(r#"{"KeyMetric": "bogus"}"#).unwrap();
        assert!(PpaArgs::from_json(&doc).is_err());
        let doc = Json::parse(r#"{"UpdatePolicy": 5}"#).unwrap();
        assert!(PpaArgs::from_json(&doc).is_err());
        let doc = Json::parse(r#"{"ControlInterval": 0}"#).unwrap();
        assert!(PpaArgs::from_json(&doc).is_err());
    }

    #[test]
    fn topology_parse_and_build() {
        assert_eq!(Topology::parse("paper").unwrap(), Topology::Paper);
        assert_eq!(
            Topology::parse("city-50").unwrap(),
            Topology::EdgeCity {
                zones: 50,
                workers_per_zone: 2,
                mix: ClassMix::default()
            }
        );
        assert_eq!(
            Topology::parse("city-12x3").unwrap(),
            Topology::EdgeCity {
                zones: 12,
                workers_per_zone: 3,
                mix: ClassMix::default()
            }
        );
        assert!(Topology::parse("city-0").is_err());
        assert!(Topology::parse("city-5x0").is_err());
        assert!(Topology::parse("mesh").is_err());
        assert_eq!(Topology::parse("city-12x3").unwrap().label(), "city-12x3");

        let city = Topology::parse("city-9").unwrap();
        let cluster = city.cluster();
        cluster.validate().unwrap();
        assert_eq!(cluster.deployments.len(), 10);
        let presets = city.scenario_presets();
        assert!(presets.iter().all(|(n, _)| n.starts_with("city9-")));
        // The paper topology keeps the Table-2 preset library.
        assert_eq!(
            Topology::Paper.scenario_presets().len(),
            scenario_presets().len()
        );
    }

    #[test]
    fn topology_class_mix_parse_label_and_build() {
        // Round-trip and explicit structure.
        let t = Topology::parse("city-50x4:small,large").unwrap();
        assert_eq!(
            t,
            Topology::EdgeCity {
                zones: 50,
                workers_per_zone: 4,
                mix: ClassMix::new(&[NodeClass::Small, NodeClass::Large]).unwrap()
            }
        );
        assert_eq!(t.label(), "city-50x4:small,large");
        assert_eq!(Topology::parse(&t.label()).unwrap(), t);
        // Classes also attach to the short city form.
        let short = Topology::parse("city-3:large").unwrap();
        assert_eq!(short.label(), "city-3x2:large");
        // Bad class names and over-long mixes are rejected.
        assert!(Topology::parse("city-4:tiny").is_err());
        assert!(Topology::parse("city-4:small,small,small,small,small").is_err());
        assert!(ClassMix::parse("").is_err());

        // The mix cycles per worker within each zone.
        let mix = ClassMix::parse("small,large").unwrap();
        assert_eq!(mix.class_for(0), NodeClass::Small);
        assert_eq!(mix.class_for(1), NodeClass::Large);
        assert_eq!(mix.class_for(2), NodeClass::Small);
        // Empty mix is homogeneous Medium (the classic grid).
        assert_eq!(ClassMix::default().class_for(7), NodeClass::Medium);

        // The built cluster carries the heterogeneous specs...
        let cfg = Topology::parse("city-2x2:small,large").unwrap().cluster();
        cfg.validate().unwrap();
        let edge: Vec<(u32, u32)> = cfg
            .nodes
            .iter()
            .filter(|n| n.tier == Tier::Edge)
            .map(|n| (n.cpu_millis, n.ram_mb))
            .collect();
        assert_eq!(
            edge,
            vec![(1000, 1024), (4000, 4096), (1000, 1024), (4000, 4096)]
        );
        // ...while the homogeneous medium mix is byte-identical to the
        // classic grid (back-compat with pre-mix sweeps).
        let classic = Topology::parse("city-4x3").unwrap().cluster();
        let medium = Topology::parse("city-4x3:medium").unwrap().cluster();
        assert_eq!(format!("{classic:?}"), format!("{medium:?}"));
    }

    #[test]
    fn costs_json_defaults_and_overrides() {
        let d = costs_from_json(&Json::parse("{}").unwrap());
        assert!((d.sort_core_secs - TaskCosts::default().sort_core_secs).abs() < 1e-12);
        let c = costs_from_json(&Json::parse(r#"{"sort_core_secs": 0.5, "overhead_ms": 10}"#).unwrap());
        assert!((c.sort_core_secs - 0.5).abs() < 1e-12);
        assert_eq!(c.overhead, 10 * MS);
    }
}

//! The reactive Horizontal Pod Autoscaler baseline — Kubernetes' default
//! semantics on the shared decision pipeline: Eq 1 per [`MetricSpec`] on
//! the *current* metric values with a ±10% tolerance band, the max
//! recommendation across metrics, and the [`ScalingBehavior`] stage
//! (default: a 5-minute scale-down stabilization window, mirroring
//! `--horizontal-pod-autoscaler-downscale-stabilization`).

use super::behavior::{BehaviorState, ScalingBehavior};
use super::spec::{MetricSource, MetricSpec, Recommendation};
use super::{combine_recommendations, eq1_replicas, Autoscaler, ScaleDecision};
use crate::cluster::{Cluster, DeploymentId};
use crate::metrics::MetricsPipeline;
use crate::sim::{ServiceId, Time, MIN, SEC};

/// HPA configuration (defaults match upstream Kubernetes).
#[derive(Debug, Clone)]
pub struct HpaConfig {
    /// Metric targets, combined max-wins. The HPA is reactive: every
    /// spec is read from the current scrape regardless of its
    /// [`MetricSpec::source`].
    pub specs: Vec<MetricSpec>,
    /// Control-loop period (upstream sync period: 15 s).
    pub sync_period: Time,
    /// Per metric, no action when the ratio is within ±tolerance of 1
    /// (upstream 0.1).
    pub tolerance: f64,
    /// Scaling behavior (upstream default: 5-min scale-down window).
    pub behavior: ScalingBehavior,
}

impl Default for HpaConfig {
    fn default() -> Self {
        HpaConfig {
            specs: vec![MetricSpec::current(crate::metrics::M_CPU, 70.0)],
            sync_period: 15 * SEC,
            tolerance: 0.1,
            behavior: ScalingBehavior::stabilize_down(5 * MIN),
        }
    }
}

/// The reactive baseline autoscaler.
#[derive(Debug)]
pub struct Hpa {
    cfg: HpaConfig,
    state: BehaviorState,
}

impl Hpa {
    pub fn new(cfg: HpaConfig) -> Self {
        assert!(!cfg.specs.is_empty(), "HPA needs >= 1 metric spec");
        Hpa {
            cfg,
            state: BehaviorState::new(),
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(HpaConfig::default())
    }

    /// Paper-faithful variant: pure Eq 1 on one metric, no tolerance, no
    /// behavior clamps (used by the ablation bench to quantify what
    /// stabilization contributes).
    pub fn pure_eq1(threshold: f64, sync_period: Time) -> Self {
        Self::new(HpaConfig {
            specs: vec![MetricSpec::current(crate::metrics::M_CPU, threshold)],
            sync_period,
            tolerance: 0.0,
            behavior: ScalingBehavior::stabilize_down(0),
        })
    }
}

impl Autoscaler for Hpa {
    fn name(&self) -> &str {
        "hpa"
    }

    fn control_interval(&self) -> Time {
        self.cfg.sync_period
    }

    fn specs(&self) -> &[MetricSpec] {
        &self.cfg.specs
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        let current = cluster.live_replicas(target).max(1);

        // Stage 1: one recommendation per spec, always from the current
        // scrape, with the upstream tolerance band applied per metric.
        let mut recommendations = Vec::with_capacity(self.cfg.specs.len());
        for spec in &self.cfg.specs {
            let value = metrics.latest_metric(service, spec.metric);
            let ratio = value / (spec.target * current as f64);
            let desired = if (ratio - 1.0).abs() <= self.cfg.tolerance {
                current
            } else {
                eq1_replicas(value, spec.target).max(1)
            };
            recommendations.push(Recommendation {
                metric: spec.metric,
                target: spec.target,
                value,
                source: MetricSource::Current,
                predicted: None,
                desired,
            });
        }

        // Stage 2: max over metrics, min-replica floor.
        let combined =
            combine_recommendations(&recommendations, cluster.min_replicas(target), None);

        // Stage 3: shared behavior clamp.
        let desired = self.state.apply(now, combined, current, &self.cfg.behavior);

        ScaleDecision {
            desired,
            key_value: recommendations[0].value,
            predicted: None,
            used_fallback: false,
            recommendations,
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{App, TaskCosts};
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector, Tier};
    use crate::metrics::{MetricsPipeline, M_CPU, M_REQ_RATE, METRIC_DIM};
    use crate::sim::{EventQueue, ServiceId};
    use crate::util::rng::Pcg64;

    fn world_with_min(
        cpu_sum: f64,
        replicas: usize,
        min_replicas: usize,
    ) -> (Cluster, MetricsPipeline) {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e", Tier::Edge, 1, 8000, 8192));
        let dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            min_replicas,
            16,
        ));
        let cloud = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1, 0);
        cluster.reconcile(dep, replicas, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let crate::sim::Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
            }
        }
        let app = App::new(TaskCosts::default(), &[(1, dep)], cloud);
        let mut mp = MetricsPipeline::new(10 * SEC, app.services.len());
        // Inject a synthetic latest vector.
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = cpu_sum;
        mp.test_set_latest(ServiceId(0), v, replicas);
        (cluster, mp)
    }

    fn world_with_cpu(cpu_sum: f64, replicas: usize) -> (Cluster, MetricsPipeline) {
        world_with_min(cpu_sum, replicas, 1)
    }

    #[test]
    fn scales_up_per_eq1() {
        let (cluster, mp) = world_with_cpu(350.0, 2);
        let mut hpa = Hpa::with_defaults();
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 5); // ceil(350/70)
        assert_eq!(d.recommendations.len(), 1);
        assert_eq!(d.recommendations[0].desired, 5);
        assert_eq!(d.recommendations[0].source, MetricSource::Current);
    }

    #[test]
    fn tolerance_band_holds() {
        // 2 replicas at 145 total (72.5 each): ratio 1.036, inside ±0.1.
        let (cluster, mp) = world_with_cpu(145.0, 2);
        let mut hpa = Hpa::with_defaults();
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 2, "within tolerance — no action");
    }

    #[test]
    fn scale_down_stabilized() {
        let (cluster, mp) = world_with_cpu(70.0, 4);
        let mut hpa = Hpa::with_defaults();
        // Earlier in the window the load was high → desired 5.
        let (c2, mp2) = world_with_cpu(350.0, 4);
        let d0 = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp2, &c2);
        assert_eq!(d0.desired, 5);
        // 1 min later load collapsed; stabilization keeps replicas.
        let d1 = hpa.evaluate(60 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d1.desired, 4, "held by stabilization (min with current)");
        // After the window passes, scale-down proceeds.
        let d2 = hpa.evaluate(7 * MIN, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d2.desired, 1); // ceil(70/70)
    }

    #[test]
    fn pure_eq1_mode_reacts_immediately() {
        let (cluster, mp) = world_with_cpu(70.0, 4);
        let mut hpa = Hpa::pure_eq1(70.0, 20 * SEC);
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 1);
    }

    #[test]
    fn zero_metric_keeps_min_one() {
        let (cluster, mp) = world_with_cpu(0.0, 1);
        let mut hpa = Hpa::pure_eq1(70.0, 20 * SEC);
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 1);
    }

    #[test]
    fn dead_metric_clamped_to_min_replicas() {
        // Regression (scale-to-zero leak): NaN/zero metrics recommend 0;
        // the combine stage must respect the deployment's replica floor.
        let (cluster, mut mp) = world_with_min(0.0, 2, 2);
        let mut v = [f64::NAN; METRIC_DIM];
        v[M_CPU] = f64::NAN;
        mp.test_set_latest(ServiceId(0), v, 2);
        let mut hpa = Hpa::pure_eq1(70.0, 20 * SEC);
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 2, "min_replicas floor, not 0 or 1");
    }

    #[test]
    fn multi_metric_takes_max() {
        // cpu alone wants 1 replica; req_rate alone wants 4 — max wins.
        let (cluster, mut mp) = world_with_cpu(70.0, 2);
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = 70.0;
        v[M_REQ_RATE] = 8.0;
        mp.test_set_latest(ServiceId(0), v, 2);
        let mut hpa = Hpa::new(HpaConfig {
            specs: vec![
                MetricSpec::current(M_CPU, 70.0),
                MetricSpec::current(M_REQ_RATE, 2.0),
            ],
            behavior: ScalingBehavior::stabilize_down(0),
            tolerance: 0.0,
            ..HpaConfig::default()
        });
        let d = hpa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.recommendations[0].desired, 1);
        assert_eq!(d.recommendations[1].desired, 4);
        assert_eq!(d.desired, 4, "combined max over metrics");
        assert_eq!(d.key_value, 70.0, "primary metric value reported");
    }
}

//! The pod scheduler: K8s default-profile shape — a `PodFitsResources` +
//! node-selector filter stage, then a `LeastAllocated` score stage.
//! Deterministic tie-break on node index keeps runs reproducible.
//!
//! Two entry points share the score stage: [`schedule`] scans every
//! node against the deployment's selector (the retained reference
//! path, used by `QueryMode::Scan` and for standalone deployments),
//! while [`schedule_over`] runs the same filter/score over a
//! pre-computed ascending candidate list — the deployment's cached
//! matching-node index, which skips the selector test entirely.
//! Candidate lists are built in node-index order, so both paths pick
//! the same node (same score comparison, same tie-break).

use super::{Deployment, Node, PodSpec};
use crate::sim::NodeId;

/// Pick the best node for a pod of `dep`, or `None` if unschedulable.
/// Full scan: every node is tested against the deployment's selector.
pub fn schedule(nodes: &[Node], dep: &Deployment, spec: PodSpec) -> Option<NodeId> {
    let mut best: Option<(f64, usize)> = None;
    for (idx, node) in nodes.iter().enumerate() {
        // Filter stage (down nodes never pass — they are also absent
        // from the cached matching lists `schedule_over` runs on).
        if !node.up || !dep.selector.matches(&node.spec) || !node.fits(spec) {
            continue;
        }
        // Score stage: least allocated after placement (lower = better).
        let score = node.score_after(spec);
        match best {
            Some((s, _)) if s <= score => {}
            _ => best = Some((score, idx)),
        }
    }
    best.map(|(_, idx)| NodeId(idx as u32))
}

/// [`schedule`] over a pre-filtered candidate list (ascending node
/// indices, selector already applied): only `PodFitsResources` and the
/// `LeastAllocated` score run per candidate.
pub fn schedule_over(nodes: &[Node], candidates: &[NodeId], spec: PodSpec) -> Option<NodeId> {
    let mut best: Option<(f64, NodeId)> = None;
    for &nid in candidates {
        let node = &nodes[nid.0 as usize];
        if !node.fits(spec) {
            continue;
        }
        let score = node.score_after(spec);
        match best {
            Some((s, _)) if s <= score => {}
            _ => best = Some((score, nid)),
        }
    }
    best.map(|(_, nid)| nid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DeploymentId, NodeSpec, Selector, Tier};
    use crate::sim::PodId;

    fn dep(selector: Selector) -> Deployment {
        Deployment::new("d", selector, PodSpec::new(500, 256), 0, 100)
    }

    /// The candidate list `Cluster::add_deployment` would cache.
    fn matching(nodes: &[Node], d: &Deployment) -> Vec<NodeId> {
        nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| d.selector.matches(&n.spec))
            .map(|(i, _)| NodeId(i as u32))
            .collect()
    }

    #[test]
    fn filters_by_selector() {
        let nodes = vec![
            Node::new(NodeSpec::new("c", Tier::Cloud, 0, 3000, 3072)),
            Node::new(NodeSpec::new("e", Tier::Edge, 1, 2000, 2048)),
        ];
        let d = dep(Selector::new(Tier::Edge, Some(1)));
        assert_eq!(
            schedule(&nodes, &d, d.pod_spec),
            Some(NodeId(1)),
            "must skip the cloud node"
        );
    }

    #[test]
    fn prefers_least_allocated() {
        let mut nodes = vec![
            Node::new(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048)),
            Node::new(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048)),
        ];
        let d = dep(Selector::new(Tier::Edge, None));
        nodes[0].bind(PodId(0), DeploymentId(0), d.pod_spec);
        assert_eq!(schedule(&nodes, &d, d.pod_spec), Some(NodeId(1)));
    }

    #[test]
    fn spreads_round_robin_under_equal_load() {
        let mut nodes = vec![
            Node::new(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048)),
            Node::new(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048)),
        ];
        let d = dep(Selector::new(Tier::Edge, None));
        let mut placements = Vec::new();
        for i in 0..4 {
            let n = schedule(&nodes, &d, d.pod_spec).unwrap();
            nodes[n.0 as usize].bind(PodId(i), DeploymentId(0), d.pod_spec);
            placements.push(n.0);
        }
        assert_eq!(placements, vec![0, 1, 0, 1]);
    }

    #[test]
    fn none_when_full() {
        let mut nodes = vec![Node::new(NodeSpec::new("e", Tier::Edge, 1, 700, 2048))];
        let d = dep(Selector::new(Tier::Edge, None));
        nodes[0].bind(PodId(0), DeploymentId(0), d.pod_spec); // 500 of 500 allocatable
        assert_eq!(schedule(&nodes, &d, d.pod_spec), None);
    }

    #[test]
    fn candidate_list_path_matches_full_scan() {
        // schedule_over on the cached matching list must pick the node
        // the selector-scanning schedule picks, at every load state.
        let mut nodes = vec![
            Node::new(NodeSpec::new("c", Tier::Cloud, 0, 3000, 3072)),
            Node::new(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048)),
            Node::new(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048)),
            Node::new(NodeSpec::new("e3", Tier::Edge, 2, 2000, 2048)),
        ];
        let d = dep(Selector::new(Tier::Edge, Some(1)));
        let candidates = matching(&nodes, &d);
        assert_eq!(candidates, vec![NodeId(1), NodeId(2)]);
        for i in 0..7 {
            let scan = schedule(&nodes, &d, d.pod_spec);
            let indexed = schedule_over(&nodes, &candidates, d.pod_spec);
            assert_eq!(scan, indexed, "placement {i} diverged");
            match indexed {
                Some(n) => nodes[n.0 as usize].bind(PodId(i), DeploymentId(0), d.pod_spec),
                None => break,
            }
        }
        // Both full: both report unschedulable.
        assert_eq!(schedule(&nodes, &d, d.pod_spec), None);
        assert_eq!(schedule_over(&nodes, &candidates, d.pod_spec), None);
    }
}

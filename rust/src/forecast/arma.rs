//! ARMA(1,1) forecaster — the paper's baseline model (§5.3.1, Eq 3):
//!
//! `y_t = μ + ε_t + θ₁ ε_{t-1} + φ₁ y_{t-1}`
//!
//! Fitted from scratch per series by conditional-sum-of-squares (CSS) —
//! minimizing the sum of squared one-step residuals over (μ, φ, θ) with
//! Nelder–Mead — the same estimator statsmodels' `ARMA.fit` defaults to
//! in CSS mode. One independent model per protocol metric, matching the
//! protocol's "predict all input variables".

use super::{Forecaster, UpdatePolicy};
use crate::metrics::METRIC_DIM;
use crate::util::nelder_mead;

/// Fitted ARMA(1,1) parameters for one series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArmaParams {
    pub mu: f64,
    pub phi: f64,
    pub theta: f64,
}

impl ArmaParams {
    /// CSS residuals over `series`; returns (residuals, sum of squares).
    fn residuals(&self, series: &[f64]) -> (Vec<f64>, f64) {
        let mut eps = Vec::with_capacity(series.len());
        let mut prev_eps = 0.0;
        let mut css = 0.0;
        for (t, &y) in series.iter().enumerate() {
            let pred = if t == 0 {
                self.mu
            } else {
                self.mu + self.phi * (series[t - 1] - self.mu) + self.theta * prev_eps
            };
            let e = y - pred;
            css += e * e;
            eps.push(e);
            prev_eps = e;
        }
        (eps, css)
    }

    /// One-step-ahead forecast given the last observation and residual.
    pub fn forecast(&self, last_y: f64, last_eps: f64) -> f64 {
        self.mu + self.phi * (last_y - self.mu) + self.theta * last_eps
    }
}

/// Fit ARMA(1,1) to a series by CSS. Stationarity/invertibility is
/// encouraged by penalizing |φ|,|θ| ≥ 1.
pub fn fit_arma(series: &[f64]) -> Option<ArmaParams> {
    if series.len() < 8 {
        return None;
    }
    let mean = series.iter().sum::<f64>() / series.len() as f64;
    let objective = |p: &[f64]| {
        let params = ArmaParams {
            mu: p[0],
            phi: p[1],
            theta: p[2],
        };
        let mut penalty = 0.0;
        if p[1].abs() >= 0.999 {
            penalty += 1e6 * (p[1].abs() - 0.999);
        }
        if p[2].abs() >= 0.999 {
            penalty += 1e6 * (p[2].abs() - 0.999);
        }
        let (_, css) = params.residuals(series);
        css + penalty
    };
    let (best, _) = nelder_mead::minimize(objective, &[mean, 0.5, 0.1], 0.3, 1e-10, 800);
    let params = ArmaParams {
        mu: best[0],
        phi: best[1].clamp(-0.998, 0.998),
        theta: best[2].clamp(-0.998, 0.998),
    };
    params.mu.is_finite().then_some(params)
}

/// Per-metric ARMA(1,1) forecaster.
#[derive(Debug, Default)]
pub struct ArmaForecaster {
    models: Option<[ArmaParams; METRIC_DIM]>,
}

impl ArmaForecaster {
    pub fn new() -> Self {
        ArmaForecaster { models: None }
    }

    /// Pretrain on a seed history (the injected seed model).
    pub fn pretrained(history: &[[f64; METRIC_DIM]]) -> Self {
        let mut f = Self::new();
        let _ = f.retrain(history, UpdatePolicy::RetrainScratch);
        f
    }

    fn series(history: &[[f64; METRIC_DIM]], feature: usize) -> Vec<f64> {
        history.iter().map(|r| r[feature]).collect()
    }
}

impl Forecaster for ArmaForecaster {
    fn name(&self) -> &str {
        "arma(1,1)"
    }

    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let models = self.models.as_ref()?;
        if history.len() < 2 {
            return None;
        }
        let mut out = [0.0; METRIC_DIM];
        for f in 0..METRIC_DIM {
            let series = Self::series(history, f);
            let (eps, _) = models[f].residuals(&series);
            out[f] = models[f]
                .forecast(*series.last().unwrap(), *eps.last().unwrap())
                .max(0.0); // metrics are non-negative
        }
        Some(out)
    }

    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()> {
        if policy == UpdatePolicy::KeepSeed && self.models.is_some() {
            return Ok(());
        }
        // Both scratch and fine-tune re-run CSS (refitting IS the update
        // for a closed-form-ish model; there is no gradient state to keep).
        let mut fitted = [ArmaParams {
            mu: 0.0,
            phi: 0.0,
            theta: 0.0,
        }; METRIC_DIM];
        for f in 0..METRIC_DIM {
            let series = Self::series(history, f);
            match fit_arma(&series) {
                Some(p) => fitted[f] = p,
                None => anyhow::bail!("history too short to fit ARMA ({} rows)", history.len()),
            }
        }
        self.models = Some(fitted);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Simulate an ARMA(1,1) process.
    fn simulate(params: ArmaParams, n: usize, noise: f64, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed, 0);
        let mut ys = Vec::with_capacity(n);
        let mut prev_y = params.mu;
        let mut prev_e = 0.0;
        for _ in 0..n {
            let e = rng.normal() * noise;
            let y = params.mu + params.phi * (prev_y - params.mu) + params.theta * prev_e + e;
            ys.push(y);
            prev_y = y;
            prev_e = e;
        }
        ys
    }

    #[test]
    fn recovers_known_process() {
        let truth = ArmaParams {
            mu: 50.0,
            phi: 0.7,
            theta: 0.3,
        };
        let series = simulate(truth, 2000, 2.0, 42);
        let fit = fit_arma(&series).unwrap();
        assert!((fit.mu - truth.mu).abs() < 2.0, "mu={}", fit.mu);
        assert!((fit.phi - truth.phi).abs() < 0.12, "phi={}", fit.phi);
        assert!((fit.theta - truth.theta).abs() < 0.2, "theta={}", fit.theta);
    }

    #[test]
    fn forecast_beats_mean_on_ar_process() {
        let truth = ArmaParams {
            mu: 100.0,
            phi: 0.9,
            theta: 0.0,
        };
        let series = simulate(truth, 1500, 3.0, 7);
        let (train, test) = series.split_at(1000);
        let fit = fit_arma(train).unwrap();

        // Walk the test set with 1-step forecasts.
        let mut history: Vec<f64> = train.to_vec();
        let mut mse_model = 0.0;
        let mut mse_mean = 0.0;
        let mean = train.iter().sum::<f64>() / train.len() as f64;
        for &y in test {
            let (eps, _) = fit.residuals(&history);
            let pred = fit.forecast(*history.last().unwrap(), *eps.last().unwrap());
            mse_model += (pred - y) * (pred - y);
            mse_mean += (mean - y) * (mean - y);
            history.push(y);
        }
        assert!(
            mse_model < 0.5 * mse_mean,
            "model {mse_model} vs mean {mse_mean}"
        );
    }

    #[test]
    fn too_short_history_fails_gracefully() {
        assert!(fit_arma(&[1.0, 2.0, 3.0]).is_none());
        let mut f = ArmaForecaster::new();
        assert!(f.predict(&[[1.0; METRIC_DIM]; 4]).is_none()); // no model yet
        assert!(f
            .retrain(&[[1.0; METRIC_DIM]; 3], UpdatePolicy::RetrainScratch)
            .is_err());
    }

    #[test]
    fn forecaster_multivariate_roundtrip() {
        let mut rng = Pcg64::new(3, 1);
        let history: Vec<[f64; METRIC_DIM]> = (0..300)
            .map(|i| {
                let base = 50.0 + 20.0 * (i as f64 / 30.0).sin();
                let mut row = [0.0; METRIC_DIM];
                for (f, r) in row.iter_mut().enumerate() {
                    *r = base * (f + 1) as f64 + rng.normal() * 2.0;
                }
                row
            })
            .collect();
        let mut f = ArmaForecaster::pretrained(&history[..250]);
        let pred = f.predict(&history[..250]).unwrap();
        // Prediction should be in the ballpark of the next actual row.
        for (p, a) in pred.iter().zip(&history[250]) {
            let rel = (p - a).abs() / a.abs().max(1.0);
            assert!(rel < 0.5, "pred {p} vs actual {a}");
        }
    }

    #[test]
    fn keep_seed_policy_preserves_model() {
        let series_hist: Vec<[f64; METRIC_DIM]> =
            (0..100).map(|i| [(i % 10) as f64 + 1.0; METRIC_DIM]).collect();
        let mut f = ArmaForecaster::pretrained(&series_hist);
        let before = f.models;
        f.retrain(&series_hist[..50], UpdatePolicy::KeepSeed).unwrap();
        assert_eq!(f.models, before);
        f.retrain(&series_hist, UpdatePolicy::RetrainScratch).unwrap();
        // scratch refits (may or may not equal; just must exist)
        assert!(f.models.is_some());
    }

    #[test]
    fn predictions_nonnegative() {
        let history: Vec<[f64; METRIC_DIM]> = (0..60)
            .map(|i| [((i % 5) as f64 * 0.01); METRIC_DIM])
            .collect();
        let mut f = ArmaForecaster::pretrained(&history);
        let pred = f.predict(&history).unwrap();
        assert!(pred.iter().all(|&v| v >= 0.0));
    }
}

//! The LSTM forecaster — the paper's optimal predictive model, executed
//! entirely through the AOT-compiled JAX/Pallas artifacts via PJRT.
//!
//! Prediction: scale the last `seq_len` metric rows, run the `predict`
//! artifact, inverse-scale. Updating: the three paper policies map to
//! (1) no-op, (2) re-init params + retrain on the history file,
//! (3) extra `train_epoch` dispatches from the current parameters.

use super::window::{latest_window, WindowDataset};
use super::{Forecaster, MinMaxScaler, Scaler, UpdatePolicy};
use crate::metrics::METRIC_DIM;
use crate::runtime::{AdamState, LstmParams, LstmRuntime};
use crate::util::rng::Pcg64;
use std::rc::Rc;

/// `train_epoch` dispatches for a from-scratch (re)train. Each dispatch
/// runs `epoch_batches x batch` samples (16 x 32 = 512 by default).
pub const SCRATCH_DISPATCHES: usize = 24;
/// Dispatches for a policy-3 fine-tune ("several extra epochs").
pub const FINETUNE_DISPATCHES: usize = 6;

/// LSTM forecaster state (the PPA's *model file* + *scaler*).
pub struct LstmForecaster {
    runtime: Rc<LstmRuntime>,
    params: LstmParams,
    opt: AdamState,
    scaler: MinMaxScaler,
    seed: u32,
    rng: Pcg64,
    /// Rolling one-step absolute errors (pseudo-confidence source).
    recent_errors: Vec<f64>,
    last_prediction: Option<[f64; METRIC_DIM]>,
}

impl LstmForecaster {
    /// Fresh forecaster with seeded parameters (no pretraining yet).
    pub fn new(runtime: Rc<LstmRuntime>, seed: u32) -> crate::Result<Self> {
        let params = runtime.init(seed)?;
        let opt = AdamState::zeros(runtime.manifest());
        Ok(LstmForecaster {
            runtime,
            params,
            opt,
            scaler: MinMaxScaler::identity(),
            seed,
            rng: Pcg64::new(seed as u64, 17),
            recent_errors: Vec::new(),
            last_prediction: None,
        })
    }

    fn train_dispatches(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        dispatches: usize,
    ) -> crate::Result<f32> {
        let m = self.runtime.manifest();
        let ds = WindowDataset::build(history, m.seq_len, &self.scaler);
        let mut last_loss = f32::NAN;
        for _ in 0..dispatches {
            let Some((xs, ys)) = ds.epoch_batches(m.epoch_batches, m.batch, &mut self.rng)
            else {
                anyhow::bail!(
                    "history too short for LSTM training ({} rows < seq_len {})",
                    history.len(),
                    m.seq_len + 1
                );
            };
            last_loss = self
                .runtime
                .train_epoch(&mut self.params, &mut self.opt, &xs, &ys)?;
        }
        Ok(last_loss)
    }

    /// Record the realized metric row so the forecaster can calibrate its
    /// pseudo-confidence (rolling relative error of recent predictions).
    pub fn observe_actual(&mut self, actual: &[f64; METRIC_DIM]) {
        if let Some(pred) = self.last_prediction.take() {
            let mut rel = 0.0;
            for f in 0..METRIC_DIM {
                let scale = self.scaler.range[f].max(1e-9);
                rel += ((pred[f] - actual[f]) / scale).abs() / METRIC_DIM as f64;
            }
            self.recent_errors.push(rel);
            if self.recent_errors.len() > 30 {
                self.recent_errors.remove(0);
            }
        }
    }

    pub fn seed(&self) -> u32 {
        self.seed
    }
}

impl LstmForecaster {
    /// Pretrain the seed model on an offline history (paper §5.3.1: 10 h
    /// of Random Access on an unconstrained node). Fits the scaler and
    /// runs a from-scratch training pass; returns the final loss.
    pub fn pretrain_on(&mut self, history: &[[f64; METRIC_DIM]]) -> crate::Result<f32> {
        self.scaler = MinMaxScaler::fit(history);
        self.train_dispatches(history, SCRATCH_DISPATCHES)
    }
}

impl Forecaster for LstmForecaster {
    fn name(&self) -> &str {
        "lstm(50)"
    }

    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let m = self.runtime.manifest();
        let window = latest_window(history, m.seq_len, &self.scaler)?;
        let scaled = self.runtime.predict(&self.params, &window).ok()?;
        let mut out = [0.0; METRIC_DIM];
        for f in 0..METRIC_DIM {
            out[f] = self.scaler.inverse(f, scaled[f] as f64).max(0.0);
        }
        self.last_prediction = Some(out);
        Some(out)
    }

    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()> {
        match policy {
            UpdatePolicy::KeepSeed => Ok(()),
            UpdatePolicy::RetrainScratch => {
                // Drop the model: fresh params (new stream), fresh Adam,
                // refit scaler to the new data distribution.
                self.seed = self.seed.wrapping_add(1);
                self.params = self.runtime.init(self.seed)?;
                self.opt = AdamState::zeros(self.runtime.manifest());
                self.scaler = MinMaxScaler::fit(history);
                self.train_dispatches(history, SCRATCH_DISPATCHES)?;
                Ok(())
            }
            UpdatePolicy::FineTune => {
                // Keep params/opt/scaler; extra epochs on the new data.
                self.train_dispatches(history, FINETUNE_DISPATCHES)?;
                Ok(())
            }
        }
    }

    fn observe(&mut self, actual: &[f64; METRIC_DIM]) {
        self.observe_actual(actual);
    }

    fn is_bayesian(&self) -> bool {
        // Pseudo-Bayesian: confidence from rolling empirical error.
        !self.recent_errors.is_empty()
    }

    fn confidence(&self) -> f64 {
        if self.recent_errors.is_empty() {
            return 1.0;
        }
        let mean_rel =
            self.recent_errors.iter().sum::<f64>() / self.recent_errors.len() as f64;
        // Map mean relative error (in scaler std units) to (0, 1].
        (1.0 / (1.0 + mean_rel)).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;

    fn forecaster() -> Option<LstmForecaster> {
        let dir = find_artifacts_dir()?;
        let rt = Rc::new(LstmRuntime::load(&dir).expect("artifacts load"));
        Some(LstmForecaster::new(rt, 7).unwrap())
    }

    fn sine_history(n: usize) -> Vec<[f64; METRIC_DIM]> {
        (0..n)
            .map(|i| {
                let t = i as f64;
                let base = 200.0 + 150.0 * (t / 25.0).sin();
                [
                    base,
                    base * 0.8 + 20.0,
                    base * 2.0,
                    base * 1.5,
                    base / 40.0,
                ]
            })
            .collect()
    }

    #[test]
    fn pretrained_lstm_tracks_sine() {
        let Some(mut f) = forecaster() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let h = sine_history(400);
        let loss = f.pretrain_on(&h[..300]).unwrap();
        assert!(loss.is_finite());

        // Walk forward: predictions should track the actual CPU series
        // much better than the series' own std.
        let mut errs = Vec::new();
        for i in 300..390 {
            let pred = f.predict(&h[..i]).unwrap();
            errs.push((pred[0] - h[i][0]).abs());
        }
        let mae = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mae < 60.0, "LSTM should track the sine; mae={mae}");
    }

    #[test]
    fn short_history_returns_none() {
        let Some(mut f) = forecaster() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        assert!(f.predict(&sine_history(3)).is_none());
    }

    #[test]
    fn fine_tune_improves_on_shifted_distribution() {
        let Some(mut f) = forecaster() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let pre = sine_history(300);
        f.pretrain_on(&pre).unwrap();

        // Shifted regime: the sine moved up by 100.
        let shifted: Vec<[f64; METRIC_DIM]> = sine_history(200)
            .into_iter()
            .map(|mut r| {
                for v in &mut r {
                    *v += 100.0;
                }
                r
            })
            .collect();

        let mae = |f: &mut LstmForecaster| {
            let mut errs = Vec::new();
            for i in 150..190 {
                if let Some(p) = f.predict(&shifted[..i]) {
                    errs.push((p[0] - shifted[i][0]).abs());
                }
            }
            errs.iter().sum::<f64>() / errs.len().max(1) as f64
        };
        let before = mae(&mut f);
        f.retrain(&shifted[..150], UpdatePolicy::FineTune).unwrap();
        let after = mae(&mut f);
        assert!(
            after < before * 1.05,
            "fine-tune should not hurt: {before} -> {after}"
        );
    }

    #[test]
    fn keep_seed_is_noop() {
        let Some(mut f) = forecaster() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let h = sine_history(100);
        f.pretrain_on(&h).unwrap();
        let params_before = f.params.clone();
        f.retrain(&h, UpdatePolicy::KeepSeed).unwrap();
        assert_eq!(f.params, params_before);
    }

    #[test]
    fn confidence_tracks_errors() {
        let Some(mut f) = forecaster() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        assert_eq!(f.confidence(), 1.0);
        assert!(!f.is_bayesian());
        let h = sine_history(100);
        f.pretrain_on(&h).unwrap();
        for i in 50..70 {
            let _ = f.predict(&h[..i]);
            f.observe_actual(&h[i]);
        }
        assert!(f.is_bayesian());
        let c = f.confidence();
        assert!((0.0..=1.0).contains(&c));
    }
}

//! Kubernetes cluster substrate: nodes, pods, deployments, scheduler,
//! and the replica-reconciliation loop.
//!
//! This models exactly the mechanisms the paper's autoscalers interact
//! with: resource-constrained heterogeneous nodes (Table 2), pod
//! lifecycle with container-init delay (the reactive-lag the PPA
//! attacks), a filter+score scheduler (K8s `LeastAllocated`), and
//! deployment replica reconciliation driven by scale requests.
//!
//! # Indexed cluster plane
//!
//! Every hot query is answered by an incrementally maintained index
//! instead of a scan (DESIGN.md §5 has the invariant table):
//!
//! * **Idle-pod ordered set** per deployment — `min_idle_pod` is the
//!   deterministic min-pod-id dispatch choice in O(log n), updated on
//!   every phase and occupancy transition ([`Cluster::start_service`] /
//!   [`Cluster::finish_service`] are the occupancy nexus).
//! * **Phase counters** per deployment — [`Cluster::live_replicas`] and
//!   [`Cluster::count_phase`] are O(1) reads; every phase change flows
//!   through the private `set_phase` nexus.
//! * **Free-slot list** for the pod slab — spawn reuses the lowest Gone
//!   slot without scanning the slab (lowest-first keeps pod ids, and
//!   therefore dispatch order, identical to the original scan).
//! * **Capacity ledger** per node — per-deployment (cpu, ram) aggregates
//!   updated on bind/unbind make [`Cluster::max_replicas`] (the paper's
//!   Algorithm-1 cap) O(matching nodes); the scheduler's filter/score
//!   stages run over each deployment's cached matching-node list.
//!
//! The original scan paths are retained behind [`QueryMode::Scan`]; in
//! debug builds every indexed answer is cross-checked against its scan,
//! and [`Cluster::verify_indices`] rebuilds all indices from scratch and
//! compares (the property tests drive it through randomized
//! reconcile/dispatch/terminate interleavings).

mod chaos;
mod deployment;
mod node;
mod pod;
mod scheduler;

pub use chaos::{
    chaos_net_stream, chaos_pod_stream, chaos_schedule_stream, schedule_node_faults,
    ChaosCounters, ColdStartPlan, CrashLoopPlan, CrashOutcome, FaultPlan, NetChaos, NetDelayPlan,
    NodeCrashPlan, PodChaos,
};
pub use deployment::{Deployment, DeploymentId, Selector};
pub use node::{Node, NodeSpec, Tier};
pub use pod::{Pod, PodPhase, PodSpec};

use crate::sim::{Event, EventQueue, NodeId, PodId, RequestId, Time, SEC};
use crate::util::rng::Pcg64;
use std::collections::BTreeSet;

/// Pod container-init delay bounds on constrained edge devices (layer
/// unpack + runtime start + worker warm-up): the paper's protocol pins
/// this to "generally ... less than one time interval of control loops"
/// (§4.2.2), i.e. up to ~20 s — this reactive lag is exactly what
/// proactive scaling attacks.
pub const INIT_DELAY_MIN: Time = 10 * SEC;
pub const INIT_DELAY_MAX: Time = 20 * SEC;
/// Graceful-termination lag for an idle pod.
pub const TERMINATION_GRACE: Time = SEC;

/// Which implementation answers cluster queries (idle-pod dispatch
/// choice, replica counts, slab slot choice, the Algorithm-1 capacity
/// cap, scheduler candidates).
///
/// `Indexed` reads the incrementally maintained indices; `Scan` answers
/// with the original full scans. The indices are maintained in either
/// mode and both are decision-bit-identical — debug builds cross-check
/// every indexed answer against its scan, and the golden-equivalence
/// suite pins whole-run equality — so `Scan` is the retained baseline
/// for tests and the hot-path benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueryMode {
    /// Incremental indices (the default).
    #[default]
    Indexed,
    /// Original scan-everything paths (reference baseline).
    Scan,
}

/// The simulated cluster state.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    pub pods: Vec<Pod>, // slab: Pod::phase == Gone marks free entries
    pub deployments: Vec<Deployment>,
    /// Free pod-slab slots (`phase == Gone`), popped lowest-first so
    /// slot reuse matches the original first-Gone scan bit-for-bit.
    free_slots: BTreeSet<u32>,
    mode: QueryMode,
    /// Installed cold-start / crash-loop perturbation (`None` — the
    /// default — leaves `try_place` byte-identical to fault-free runs).
    pod_chaos: Option<PodChaos>,
    /// Cost-ledger churn counter: total pods ever spawned (scale-ups
    /// and crash replacements; crash-loop restarts extend an existing
    /// pod's init instead of spawning). Pure observation — no behavior
    /// reads it.
    pub pod_churn: u64,
}

impl Cluster {
    pub fn new() -> Self {
        Cluster {
            nodes: Vec::new(),
            pods: Vec::new(),
            deployments: Vec::new(),
            free_slots: BTreeSet::new(),
            mode: QueryMode::Indexed,
            pod_chaos: None,
            pod_churn: 0,
        }
    }

    /// Switch between the indexed query plane and the retained scan
    /// baseline (see [`QueryMode`]). Safe at any point: the indices are
    /// maintained regardless of mode.
    pub fn set_query_mode(&mut self, mode: QueryMode) {
        self.mode = mode;
    }

    pub fn query_mode(&self) -> QueryMode {
        self.mode
    }

    pub fn add_node(&mut self, spec: NodeSpec) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        let node = Node::new(spec);
        // Keep every deployment's matching-node cache current; the new
        // node has the highest index, so ascending order is preserved.
        for dep in &mut self.deployments {
            if dep.selector.matches(&node.spec) {
                dep.matching_nodes.push(id);
            }
        }
        self.nodes.push(node);
        id
    }

    /// Register a deployment. Its selector is considered fixed from
    /// here on (the cached matching-node list would go stale otherwise).
    pub fn add_deployment(&mut self, mut dep: Deployment) -> DeploymentId {
        let id = DeploymentId(self.deployments.len() as u32);
        // A deployment cloned from another cluster must not import that
        // cluster's pod membership or index state.
        dep.pods.clear();
        dep.phase_counts = [0; 4];
        dep.idle_pods.clear();
        dep.matching_nodes = self.scan_matching_nodes(&dep.selector);
        self.deployments.push(dep);
        id
    }

    pub fn pod(&self, id: PodId) -> &Pod {
        &self.pods[id.0 as usize]
    }

    pub fn pod_mut(&mut self, id: PodId) -> &mut Pod {
        &mut self.pods[id.0 as usize]
    }

    pub fn deployment(&self, id: DeploymentId) -> &Deployment {
        &self.deployments[id.0 as usize]
    }

    /// Split borrow for exporters: the pods slab mutably (busy-time
    /// accounting drains per-pod accumulators) alongside the deployment
    /// table immutably (pod-id membership lists). Lets the metrics scrape
    /// walk `deployment.pods` in place instead of cloning the list to
    /// satisfy the borrow checker.
    pub fn split_pods_deployments(&mut self) -> (&mut [Pod], &[Deployment]) {
        (&mut self.pods, &self.deployments)
    }

    /// Running pods of a deployment (the ones a service can dispatch to).
    pub fn running_pods(&self, dep: DeploymentId) -> impl Iterator<Item = &Pod> + '_ {
        self.deployments[dep.0 as usize]
            .pods
            .iter()
            .map(|&p| self.pod(p))
            .filter(|p| p.phase == PodPhase::Running)
    }

    /// Count of pods in a phase for a deployment — an O(1) counter read
    /// (`Gone` pods are never listed by a deployment, so that count is 0).
    pub fn count_phase(&self, dep: DeploymentId, phase: PodPhase) -> usize {
        let n = match self.mode {
            QueryMode::Indexed => {
                if phase == PodPhase::Gone {
                    0
                } else {
                    self.deployments[dep.0 as usize].phase_counts[phase as usize]
                }
            }
            QueryMode::Scan => self.scan_count_phase(dep, phase),
        };
        debug_assert_eq!(
            n,
            self.scan_count_phase(dep, phase),
            "phase-counter drift ({phase:?})"
        );
        n
    }

    /// Live replicas (everything not terminating/gone) — what HPA's
    /// `currentReplicas` sees. O(1) from the phase counters.
    pub fn live_replicas(&self, dep: DeploymentId) -> usize {
        let n = match self.mode {
            QueryMode::Indexed => {
                let c = &self.deployments[dep.0 as usize].phase_counts;
                c[PodPhase::Pending as usize]
                    + c[PodPhase::Initializing as usize]
                    + c[PodPhase::Running as usize]
            }
            QueryMode::Scan => self.scan_live_replicas(dep),
        };
        debug_assert_eq!(n, self.scan_live_replicas(dep), "live-replica drift");
        n
    }

    /// The deployment's configured replica floor (the autoscalers'
    /// combine stage clamps decisions to this, closing the
    /// scale-to-zero leak on dead metrics).
    pub fn min_replicas(&self, dep: DeploymentId) -> usize {
        self.deployments[dep.0 as usize].min_replicas
    }

    /// The idle Running pod with the lowest id, if any — the
    /// deterministic dispatch choice (`App::dispatch` pops it and marks
    /// it busy via [`Cluster::start_service`]). O(log n) from the
    /// per-deployment idle-pod ordered set.
    pub fn min_idle_pod(&self, dep: DeploymentId) -> Option<PodId> {
        let pick = match self.mode {
            QueryMode::Indexed => self.deployments[dep.0 as usize].idle_pods.first().copied(),
            QueryMode::Scan => self.scan_min_idle_pod(dep),
        };
        debug_assert_eq!(pick, self.scan_min_idle_pod(dep), "idle-set drift");
        pick
    }

    /// Mark a pod busy on `request` starting at `now`, maintaining the
    /// idle-pod set. All occupancy transitions must go through this and
    /// [`Cluster::finish_service`] (not `Pod::start_service` directly).
    pub fn start_service(&mut self, pid: PodId, request: RequestId, now: Time) {
        let pod = &mut self.pods[pid.0 as usize];
        pod.start_service(request, now);
        let dep = pod.deployment;
        self.deployments[dep.0 as usize].idle_pods.remove(&pid);
    }

    /// Mark a pod's current request finished at `now`. Running pods
    /// re-enter the idle-pod set; draining (Terminating) pods do not.
    pub fn finish_service(&mut self, pid: PodId, now: Time) -> Option<RequestId> {
        let pod = &mut self.pods[pid.0 as usize];
        let req = pod.finish_service(now);
        let dep = pod.deployment;
        let idle_again = pod.phase == PodPhase::Running;
        if idle_again {
            self.deployments[dep.0 as usize].idle_pods.insert(pid);
        }
        req
    }

    /// The "limitation-aware" cap (paper Algorithm 1): the maximum number
    /// of replicas of `dep` the matching nodes can physically host,
    /// accounting for resources used by other deployments' pods.
    /// O(matching nodes) from the per-node capacity ledger.
    pub fn max_replicas(&self, dep: DeploymentId) -> usize {
        let cap = match self.mode {
            QueryMode::Indexed => self.indexed_max_replicas(dep),
            QueryMode::Scan => self.scan_max_replicas(dep),
        };
        debug_assert_eq!(cap, self.scan_max_replicas(dep), "capacity-cache drift");
        cap
    }

    fn indexed_max_replicas(&self, dep: DeploymentId) -> usize {
        let d = &self.deployments[dep.0 as usize];
        let mut total = 0usize;
        for &nid in &d.matching_nodes {
            let node = &self.nodes[nid.0 as usize];
            // Capacity minus what OTHER deployments' pods occupy: the
            // node totals minus this deployment's ledger share.
            let (own_cpu, own_ram) = node.alloc_for(dep);
            let other_cpu = node.alloc_cpu.saturating_sub(own_cpu);
            let other_ram = node.alloc_ram.saturating_sub(own_ram);
            let free_cpu = node.spec.allocatable_cpu().saturating_sub(other_cpu);
            let free_ram = node.spec.allocatable_ram().saturating_sub(other_ram);
            let by_cpu = free_cpu / d.pod_spec.cpu_millis.max(1);
            let by_ram = free_ram / d.pod_spec.ram_mb.max(1);
            total += by_cpu.min(by_ram) as usize;
        }
        total
    }

    /// Reconcile a deployment to `desired` replicas. Creates pods (through
    /// the scheduler, with init delay) and/or terminates surplus pods
    /// (Pending first, then newest Running; busy pods drain).
    ///
    /// This is the single entry point both autoscalers use — it is the
    /// Kubernetes control-plane's "handle scaling requests" step (§3.2.3).
    pub fn reconcile(
        &mut self,
        dep: DeploymentId,
        desired: usize,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let desired = desired
            .max(self.deployments[dep.0 as usize].min_replicas)
            .min(self.deployments[dep.0 as usize].max_replicas);
        let current = self.live_replicas(dep);
        self.deployments[dep.0 as usize].desired_replicas = desired;

        if desired > current {
            for _ in 0..(desired - current) {
                self.spawn_pod(dep, queue, rng);
            }
        } else if desired < current {
            self.terminate_surplus(dep, current - desired, queue);
        }
    }

    fn spawn_pod(&mut self, dep: DeploymentId, queue: &mut EventQueue, rng: &mut Pcg64) {
        self.pod_churn += 1;
        let spec = self.deployments[dep.0 as usize].pod_spec;
        // Slab allocation: reuse the lowest Gone slot if available.
        let slot = match self.mode {
            QueryMode::Indexed => self.free_slots.first().copied(),
            QueryMode::Scan => self.scan_free_slot(),
        };
        debug_assert_eq!(
            self.free_slots.first().copied(),
            self.scan_free_slot(),
            "free-slot drift"
        );
        let pid = match slot {
            Some(i) => {
                self.free_slots.remove(&i);
                let id = PodId(i);
                self.pods[i as usize] = Pod::new(id, dep, spec, queue.now());
                id
            }
            None => {
                let id = PodId(self.pods.len() as u32);
                self.pods.push(Pod::new(id, dep, spec, queue.now()));
                id
            }
        };
        let d = &mut self.deployments[dep.0 as usize];
        d.pods.push(pid);
        d.phase_counts[PodPhase::Pending as usize] += 1;

        // Unschedulable pods stay Pending; re-tried on next reconcile.
        self.try_place(pid, queue, rng);
    }

    /// Run the scheduler for a Pending pod; on success bind it and start
    /// container init. Returns whether the pod was placed.
    fn try_place(&mut self, pid: PodId, queue: &mut EventQueue, rng: &mut Pcg64) -> bool {
        let dep = self.pods[pid.0 as usize].deployment;
        let spec = self.pods[pid.0 as usize].spec;
        let choice = match self.mode {
            QueryMode::Indexed => scheduler::schedule_over(
                &self.nodes,
                &self.deployments[dep.0 as usize].matching_nodes,
                spec,
            ),
            QueryMode::Scan => {
                scheduler::schedule(&self.nodes, &self.deployments[dep.0 as usize], spec)
            }
        };
        match choice {
            Some(node_id) => {
                self.nodes[node_id.0 as usize].bind(pid, dep, spec);
                self.pods[pid.0 as usize].node = Some(node_id);
                self.set_phase(pid, PodPhase::Initializing);
                // The base delay always comes off the engine stream (so
                // an empty fault plan stays bit-identical); chaos only
                // perturbs it afterwards from its own stream.
                let mut delay = rng.int_range(INIT_DELAY_MIN, INIT_DELAY_MAX + 1);
                if let Some(pc) = &mut self.pod_chaos {
                    delay = pc.perturb_init_delay(delay);
                }
                queue.schedule_in(delay, Event::PodRunning { pod: pid });
                true
            }
            None => false,
        }
    }

    fn terminate_surplus(&mut self, dep: DeploymentId, n: usize, queue: &mut EventQueue) {
        // Victim order: Pending, then Initializing, then newest Running idle,
        // then newest Running busy (drained).
        let mut victims: Vec<PodId> = Vec::with_capacity(n);
        let mut candidates: Vec<PodId> = self.deployments[dep.0 as usize]
            .pods
            .iter()
            .copied()
            .filter(|&p| {
                matches!(
                    self.pod(p).phase,
                    PodPhase::Pending | PodPhase::Initializing | PodPhase::Running
                )
            })
            .collect();
        candidates.sort_by_key(|&p| {
            let pod = self.pod(p);
            let phase_rank = match pod.phase {
                PodPhase::Pending => 0u8,
                PodPhase::Initializing => 1,
                PodPhase::Running if pod.current_request.is_none() => 2,
                PodPhase::Running => 3,
                _ => 4,
            };
            // Newest first within a rank.
            (phase_rank, u64::MAX - pod.created)
        });
        victims.extend(candidates.into_iter().take(n));

        for pid in victims {
            match self.pods[pid.0 as usize].phase {
                PodPhase::Pending => {
                    self.set_phase(pid, PodPhase::Gone);
                    self.detach(pid, dep);
                }
                PodPhase::Initializing => {
                    self.set_phase(pid, PodPhase::Terminating);
                    queue.schedule_in(TERMINATION_GRACE, Event::PodTerminated { pod: pid });
                }
                PodPhase::Running => {
                    let busy = self.pods[pid.0 as usize].current_request.is_some();
                    self.set_phase(pid, PodPhase::Terminating);
                    if !busy {
                        queue.schedule_in(
                            TERMINATION_GRACE,
                            Event::PodTerminated { pod: pid },
                        );
                    }
                    // Busy pods drain: the ServiceComplete handler emits
                    // PodTerminated when the in-flight request finishes.
                }
                _ => {}
            }
        }
    }

    /// Handle `PodRunning`: Initializing → Running (no-op if the pod was
    /// terminated while initializing).
    pub fn on_pod_running(&mut self, pid: PodId) -> bool {
        if self.pods[pid.0 as usize].phase == PodPhase::Initializing {
            self.set_phase(pid, PodPhase::Running);
            true
        } else {
            false
        }
    }

    /// Handle `PodTerminated`: release node resources, free the slab slot.
    /// Tolerates stale events: if the pod is not draining (a crash
    /// already freed it, or the slot was recycled), this is a no-op —
    /// on the fault-free path exactly one `PodTerminated` fires per
    /// Terminating incarnation, so the guard never triggers there.
    pub fn on_pod_terminated(&mut self, pid: PodId) {
        if self.pods[pid.0 as usize].phase != PodPhase::Terminating {
            return;
        }
        let dep = self.pods[pid.0 as usize].deployment;
        let node = self.pods[pid.0 as usize].node;
        if let Some(nid) = node {
            let spec = self.pods[pid.0 as usize].spec;
            self.nodes[nid.0 as usize].unbind(pid, dep, spec);
        }
        self.set_phase(pid, PodPhase::Gone);
        self.detach(pid, dep);
    }

    fn detach(&mut self, pid: PodId, dep: DeploymentId) {
        let pods = &mut self.deployments[dep.0 as usize].pods;
        if let Some(idx) = pods.iter().position(|&p| p == pid) {
            pods.swap_remove(idx);
        }
    }

    /// The single phase-transition nexus: every `Pod::phase` change in
    /// the cluster goes through here so the phase counters, the
    /// idle-pod set and the free-slot list stay consistent.
    fn set_phase(&mut self, pid: PodId, to: PodPhase) {
        let pod = &mut self.pods[pid.0 as usize];
        let from = pod.phase;
        debug_assert_ne!(from, PodPhase::Gone, "transition out of a freed slot");
        if from == to {
            return;
        }
        pod.phase = to;
        let dep = pod.deployment;
        let idle = pod.current_request.is_none();
        let d = &mut self.deployments[dep.0 as usize];
        d.phase_counts[from as usize] -= 1;
        if to == PodPhase::Gone {
            self.free_slots.insert(pid.0);
        } else {
            d.phase_counts[to as usize] += 1;
        }
        if from == PodPhase::Running {
            d.idle_pods.remove(&pid);
        }
        if to == PodPhase::Running && idle {
            d.idle_pods.insert(pid);
        }
    }

    /// Retry scheduling for Pending pods (called per reconcile tick).
    /// The phase counters skip deployments with nothing Pending — the
    /// steady-state common case — instead of scanning the whole slab.
    pub fn retry_pending(&mut self, queue: &mut EventQueue, rng: &mut Pcg64) {
        let mut pending: Vec<PodId> = Vec::new();
        match self.mode {
            QueryMode::Indexed => {
                for dep in &self.deployments {
                    if dep.phase_counts[PodPhase::Pending as usize] == 0 {
                        continue;
                    }
                    pending.extend(
                        dep.pods
                            .iter()
                            .copied()
                            .filter(|&p| self.pod(p).phase == PodPhase::Pending),
                    );
                }
                // Ascending pod id == the original slab-scan retry order.
                pending.sort_unstable();
            }
            QueryMode::Scan => {
                pending.extend(
                    self.pods
                        .iter()
                        .filter(|p| p.phase == PodPhase::Pending)
                        .map(|p| p.id),
                );
            }
        }
        for pid in pending {
            self.try_place(pid, queue, rng);
        }
    }

    // -----------------------------------------------------------------
    // Retained scan paths (the pre-index implementations): the `Scan`
    // query mode answers from these, and debug builds cross-check every
    // indexed answer against them.
    // -----------------------------------------------------------------

    fn scan_count_phase(&self, dep: DeploymentId, phase: PodPhase) -> usize {
        self.deployments[dep.0 as usize]
            .pods
            .iter()
            .filter(|&&p| self.pod(p).phase == phase)
            .count()
    }

    fn scan_live_replicas(&self, dep: DeploymentId) -> usize {
        self.deployments[dep.0 as usize]
            .pods
            .iter()
            .filter(|&&p| {
                matches!(
                    self.pod(p).phase,
                    PodPhase::Pending | PodPhase::Initializing | PodPhase::Running
                )
            })
            .count()
    }

    fn scan_min_idle_pod(&self, dep: DeploymentId) -> Option<PodId> {
        self.running_pods(dep)
            .filter(|p| p.current_request.is_none())
            .map(|p| p.id)
            .min()
    }

    fn scan_free_slot(&self) -> Option<u32> {
        self.pods
            .iter()
            .position(|p| p.phase == PodPhase::Gone)
            .map(|i| i as u32)
    }

    /// *Up* nodes matching `selector`, ascending by index — the single
    /// definition behind both the matching-node cache builder
    /// (`add_deployment`) and the `verify_indices` checker. Crashed
    /// nodes are excluded until they rejoin.
    fn scan_matching_nodes(&self, selector: &Selector) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.up && selector.matches(&n.spec))
            .map(|(i, _)| NodeId(i as u32))
            .collect()
    }

    fn scan_max_replicas(&self, dep: DeploymentId) -> usize {
        let d = &self.deployments[dep.0 as usize];
        let mut total = 0usize;
        for node in &self.nodes {
            if !node.up || !d.selector.matches(&node.spec) {
                continue;
            }
            // Capacity minus what OTHER deployments' pods occupy.
            let mut other_cpu = 0u32;
            let mut other_ram = 0u32;
            for &pid in &node.pods {
                let p = self.pod(pid);
                if p.deployment != dep && p.phase != PodPhase::Gone {
                    other_cpu += p.spec.cpu_millis;
                    other_ram += p.spec.ram_mb;
                }
            }
            let free_cpu = node.spec.allocatable_cpu().saturating_sub(other_cpu);
            let free_ram = node.spec.allocatable_ram().saturating_sub(other_ram);
            let by_cpu = free_cpu / d.pod_spec.cpu_millis.max(1);
            let by_ram = free_ram / d.pod_spec.ram_mb.max(1);
            total += by_cpu.min(by_ram) as usize;
        }
        total
    }

    /// Rebuild every index from a from-scratch scan and compare —
    /// panics on any drift. Driven by the multi-seed property tests
    /// after randomized reconcile/dispatch/terminate interleavings.
    pub fn verify_indices(&self) {
        // Free-slot list == slab scan of Gone slots.
        let scan_free: BTreeSet<u32> = self
            .pods
            .iter()
            .enumerate()
            .filter(|(_, p)| p.phase == PodPhase::Gone)
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(self.free_slots, scan_free, "free-slot list drift");

        for (di, dep) in self.deployments.iter().enumerate() {
            let id = DeploymentId(di as u32);
            for phase in [
                PodPhase::Pending,
                PodPhase::Initializing,
                PodPhase::Running,
                PodPhase::Terminating,
            ] {
                assert_eq!(
                    dep.phase_counts[phase as usize],
                    self.scan_count_phase(id, phase),
                    "dep {di}: {phase:?} counter drift"
                );
            }
            let scan_idle: BTreeSet<PodId> = dep
                .pods
                .iter()
                .copied()
                .filter(|&p| self.pod(p).is_idle_running())
                .collect();
            assert_eq!(dep.idle_pods, scan_idle, "dep {di}: idle-set drift");
            assert_eq!(
                dep.matching_nodes,
                self.scan_matching_nodes(&dep.selector),
                "dep {di}: matching-node cache drift"
            );
            assert_eq!(
                self.indexed_max_replicas(id),
                self.scan_max_replicas(id),
                "dep {di}: capacity-cache drift"
            );
        }

        // Node ledgers == per-deployment sums over each node's pods.
        for (ni, node) in self.nodes.iter().enumerate() {
            for di in 0..self.deployments.len() {
                let id = DeploymentId(di as u32);
                let mut cpu = 0u32;
                let mut ram = 0u32;
                for &pid in &node.pods {
                    let p = self.pod(pid);
                    if p.deployment == id {
                        cpu += p.spec.cpu_millis;
                        ram += p.spec.ram_mb;
                    }
                }
                assert_eq!(
                    node.alloc_for(id),
                    (cpu, ram),
                    "node {ni}: ledger drift for dep {di}"
                );
            }
        }
    }
}

impl Default for Cluster {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cluster() -> (Cluster, EventQueue, Pcg64) {
        let mut c = Cluster::new();
        c.add_node(NodeSpec::new("edge-1", Tier::Edge, 1, 2000, 2048));
        c.add_node(NodeSpec::new("edge-2", Tier::Edge, 1, 2000, 2048));
        let dep = Deployment::new(
            "edge-workers",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            1,
            16,
        );
        c.add_deployment(dep);
        (c, EventQueue::new(), Pcg64::new(1, 0))
    }

    fn drain_inits(c: &mut Cluster, q: &mut EventQueue) {
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::PodRunning { pod } => {
                    c.on_pod_running(pod);
                }
                Event::PodTerminated { pod } => {
                    c.on_pod_terminated(pod);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn scale_up_schedules_and_runs_pods() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 3, &mut q, &mut rng);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Initializing), 3);
        drain_inits(&mut c, &mut q);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Running), 3);
        // Resources allocated on nodes.
        let alloc: u32 = c.nodes.iter().map(|n| n.alloc_cpu).sum();
        assert_eq!(alloc, 3 * 500);
        c.verify_indices();
    }

    #[test]
    fn init_delay_within_bounds() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        let t = q.peek_time().unwrap();
        assert!((INIT_DELAY_MIN..=INIT_DELAY_MAX).contains(&t), "{t}");
    }

    #[test]
    fn scale_down_removes_newest_first() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 4, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        c.reconcile(DeploymentId(0), 2, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Running), 2);
        let alloc: u32 = c.nodes.iter().map(|n| n.alloc_cpu).sum();
        assert_eq!(alloc, 2 * 500);
        c.verify_indices();
    }

    #[test]
    fn unschedulable_pods_stay_pending_then_retry() {
        let (mut c, mut q, mut rng) = test_cluster();
        // 2 nodes x 1800m allocatable / 500m = 3 per node = 6; ask for 10.
        c.reconcile(DeploymentId(0), 10, &mut q, &mut rng);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Pending), 4);
        drain_inits(&mut c, &mut q);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Running), 6);
        // Cluster still full: pending pods stay pending after a retry.
        c.reconcile(DeploymentId(0), 10, &mut q, &mut rng); // no-op, still full
        c.retry_pending(&mut q, &mut rng);
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Pending), 4);
        c.verify_indices();
    }

    #[test]
    fn max_replicas_respects_capacity_and_other_pods() {
        let (mut c, mut q, mut rng) = test_cluster();
        // 1800m allocatable per node -> 3 x 500m pods per node.
        assert_eq!(c.max_replicas(DeploymentId(0)), 6);
        // A second deployment taking 1000m per node shrinks it to 800m
        // free -> 1 slot per node.
        let other = Deployment::new(
            "other",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(1000, 512),
            0,
            4,
        );
        let other_id = c.add_deployment(other);
        c.reconcile(other_id, 2, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        assert_eq!(c.max_replicas(DeploymentId(0)), 2);
        c.verify_indices();
    }

    #[test]
    fn reconcile_clamps_to_min_max() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 0, &mut q, &mut rng);
        assert_eq!(c.live_replicas(DeploymentId(0)), 1); // min_replicas
        c.reconcile(DeploymentId(0), 100, &mut q, &mut rng);
        assert_eq!(c.deployments[0].desired_replicas, 16); // max_replicas
    }

    #[test]
    fn busy_pod_drains_on_scale_down() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 2, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        // Mark both busy (through the cluster, so the idle set follows).
        let pods: Vec<PodId> = c.deployments[0].pods.clone();
        for (i, &p) in pods.iter().enumerate() {
            c.start_service(p, RequestId::new(7 + i as u32, 0), q.now());
        }
        assert_eq!(c.min_idle_pod(DeploymentId(0)), None);
        c.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        // No PodTerminated scheduled yet (busy drain).
        assert_eq!(c.count_phase(DeploymentId(0), PodPhase::Terminating), 1);
        assert!(q.is_empty());
        c.verify_indices();
    }

    #[test]
    fn slab_reuses_slots() {
        let (mut c, mut q, mut rng) = test_cluster();
        c.reconcile(DeploymentId(0), 3, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        let slots_before = c.pods.len();
        c.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        c.reconcile(DeploymentId(0), 3, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        assert_eq!(c.pods.len(), slots_before, "slab should reuse Gone slots");
        c.verify_indices();
    }

    #[test]
    fn min_idle_pod_tracks_occupancy() {
        let (mut c, mut q, mut rng) = test_cluster();
        let dep = DeploymentId(0);
        c.reconcile(dep, 3, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        // Lowest-id pod first, deterministically.
        let first = c.min_idle_pod(dep).unwrap();
        assert_eq!(first, PodId(0));
        c.start_service(first, RequestId::new(1, 0), q.now());
        let second = c.min_idle_pod(dep).unwrap();
        assert_eq!(second, PodId(1));
        c.start_service(second, RequestId::new(2, 0), q.now());
        assert_eq!(c.min_idle_pod(dep), Some(PodId(2)));
        // Completion puts the lowest id back in front.
        assert_eq!(c.finish_service(first, q.now()), Some(RequestId::new(1, 0)));
        assert_eq!(c.min_idle_pod(dep), Some(PodId(0)));
        c.verify_indices();
    }

    #[test]
    fn surplus_victim_ordering() {
        // Victim order regression: Pending first, then Initializing
        // (newest first), and Running pods only after those.
        let (mut c, mut q, mut rng) = test_cluster();
        let dep = DeploymentId(0);
        c.reconcile(dep, 2, &mut q, &mut rng);
        drain_inits(&mut c, &mut q); // 2 oldest pods now Running
        c.reconcile(dep, 7, &mut q, &mut rng); // capacity 6: 4 Init + 1 Pending
        assert_eq!(c.count_phase(dep, PodPhase::Pending), 1);
        assert_eq!(c.count_phase(dep, PodPhase::Initializing), 4);
        let pending: Vec<PodId> = c
            .pods
            .iter()
            .filter(|p| p.phase == PodPhase::Pending)
            .map(|p| p.id)
            .collect();
        c.reconcile(dep, 3, &mut q, &mut rng); // terminate 4 of 7
        // The Pending pod went first (straight to Gone)...
        assert_eq!(c.pod(pending[0]).phase, PodPhase::Gone);
        // ...then 3 of the 4 Initializing pods; Running pods survive.
        assert_eq!(c.count_phase(dep, PodPhase::Terminating), 3);
        assert_eq!(c.count_phase(dep, PodPhase::Initializing), 1);
        assert_eq!(c.count_phase(dep, PodPhase::Running), 2);
        c.verify_indices();

        // Next scale-down: the surviving Initializing pod goes before
        // any Running pod, and an idle Running pod goes before a busy
        // one — the busy pod is the last-resort victim and survives.
        let busy = c.min_idle_pod(dep).unwrap();
        c.start_service(busy, RequestId::new(1, 0), q.now());
        c.reconcile(dep, 1, &mut q, &mut rng); // live 3 -> terminate 2
        assert_eq!(c.count_phase(dep, PodPhase::Initializing), 0);
        assert_eq!(c.pod(busy).phase, PodPhase::Running, "busy pod victimized last");
        assert_eq!(c.live_replicas(dep), 1);
        assert_eq!(c.min_idle_pod(dep), None, "the survivor is the busy pod");
        c.verify_indices();
    }

    #[test]
    fn drain_then_terminate_keeps_indices_consistent() {
        let (mut c, mut q, mut rng) = test_cluster();
        let dep = DeploymentId(0);
        c.reconcile(dep, 2, &mut q, &mut rng);
        drain_inits(&mut c, &mut q);
        let a = c.min_idle_pod(dep).unwrap();
        c.start_service(a, RequestId::new(1, 0), q.now());
        let b = c.min_idle_pod(dep).unwrap();
        c.start_service(b, RequestId::new(2, 0), q.now());
        assert_eq!(c.min_idle_pod(dep), None);
        // Scale to zero while both are busy — both drain.
        c.deployments[0].min_replicas = 0;
        c.reconcile(dep, 0, &mut q, &mut rng);
        assert_eq!(c.count_phase(dep, PodPhase::Terminating), 2);
        assert!(q.is_empty(), "busy pods drain: no PodTerminated yet");
        c.verify_indices();
        // First request completes; the draining pod must not re-enter
        // the idle set, and termination frees its slot.
        assert_eq!(c.finish_service(a, q.now()), Some(RequestId::new(1, 0)));
        assert_eq!(c.min_idle_pod(dep), None);
        c.on_pod_terminated(a);
        assert_eq!(c.pod(a).phase, PodPhase::Gone);
        assert_eq!(c.live_replicas(dep), 0);
        c.verify_indices();
        c.finish_service(b, q.now());
        c.on_pod_terminated(b);
        c.verify_indices();
        // Freed slots are reused lowest-first on the next scale-up.
        c.deployments[0].min_replicas = 1;
        c.reconcile(dep, 1, &mut q, &mut rng);
        assert_eq!(c.pods.len(), 2, "slab slot reused, not grown");
        assert_eq!(
            c.deployments[0].pods,
            vec![PodId(0)],
            "lowest free slot first"
        );
        c.verify_indices();
    }

    #[test]
    fn scan_and_indexed_modes_make_identical_choices() {
        let build = |mode: QueryMode| -> Vec<(u32, PodPhase, Option<NodeId>)> {
            let (mut c, mut q, mut rng) = test_cluster();
            c.set_query_mode(mode);
            c.reconcile(DeploymentId(0), 5, &mut q, &mut rng);
            drain_inits(&mut c, &mut q);
            c.reconcile(DeploymentId(0), 2, &mut q, &mut rng);
            drain_inits(&mut c, &mut q);
            c.reconcile(DeploymentId(0), 4, &mut q, &mut rng);
            drain_inits(&mut c, &mut q);
            c.verify_indices();
            c.pods.iter().map(|p| (p.id.0, p.phase, p.node)).collect()
        };
        assert_eq!(build(QueryMode::Indexed), build(QueryMode::Scan));
    }

    #[test]
    fn matching_node_cache_follows_node_additions() {
        let mut c = Cluster::new();
        c.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        let dep = c.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            0,
            16,
        ));
        // Nodes added after the deployment still join its cache.
        c.add_node(NodeSpec::new("c1", Tier::Cloud, 0, 3000, 3072));
        c.add_node(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048));
        assert_eq!(c.max_replicas(dep), 6, "both zone-1 edge nodes count");
        c.verify_indices();
    }
}

//! CLI entry point: `cargo run -p detlint [-- FLAGS] [PATH…]`.
//!
//! ```text
//! detlint                 lint the workspace (exit 1 on any violation)
//! detlint --list-rules    print the rule registry and exit
//! detlint --json          emit diagnostics as a JSON array
//! detlint --self-test     replay the embedded fixture corpus
//! detlint --root DIR      lint a different workspace root
//! detlint PATH…           lint only the given files/directories
//! ```

use detlint::diagnostics::to_json;
use detlint::{fixtures, lint_repo, rel_label, rules};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn usage() -> &'static str {
    "usage: detlint [--list-rules | --self-test] [--json] [--root DIR] [PATH…]"
}

/// Workspace root: two levels above this crate's manifest
/// (`tools/detlint` → repo root), so `cargo run -p detlint` works from
/// anywhere inside the workspace.
fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("tools/detlint always sits two levels under the workspace root")
        .to_path_buf()
}

fn main() -> ExitCode {
    let mut list_rules = false;
    let mut self_test = false;
    let mut json = false;
    let mut root = default_root();
    let mut targets: Vec<PathBuf> = Vec::new();

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--list-rules" => list_rules = true,
            "--self-test" => self_test = true,
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("--root needs a directory\n{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("unknown flag `{flag}`\n{}", usage());
                return ExitCode::from(2);
            }
            path => targets.push(PathBuf::from(path)),
        }
    }

    if list_rules {
        for rule in rules::RULES {
            println!("{}  {}", rule.id, rule.title);
            println!("      scope: {}", rule.scope);
            println!("      why:   {}", rule.rationale);
        }
        return ExitCode::SUCCESS;
    }

    if self_test {
        return match fixtures::run_all() {
            Ok(n) => {
                println!("detlint self-test: {n} fixtures ok");
                ExitCode::SUCCESS
            }
            Err(report) => {
                eprintln!("detlint self-test FAILED:\n{report}");
                ExitCode::FAILURE
            }
        };
    }

    let result = if targets.is_empty() {
        lint_repo(&root)
    } else {
        lint_targets(&root, &targets)
    };
    let diags = match result {
        Ok(d) => d,
        Err(err) => {
            eprintln!("detlint: io error: {err}");
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", to_json(&diags));
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        if !json {
            println!("detlint: clean");
        }
        ExitCode::SUCCESS
    } else {
        if !json {
            eprintln!("detlint: {} violation(s)", diags.len());
        }
        ExitCode::FAILURE
    }
}

/// Lint explicit files or directories (paths resolved against `root`
/// when relative, scopes still matched repo-relative).
fn lint_targets(
    root: &Path,
    targets: &[PathBuf],
) -> std::io::Result<Vec<detlint::diagnostics::Diagnostic>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for target in targets {
        let path = if target.is_absolute() {
            target.clone()
        } else {
            root.join(target)
        };
        if path.is_dir() {
            for file in detlint::collect_rs_files(root)? {
                if file.starts_with(&path) {
                    files.push(file);
                }
            }
        } else {
            files.push(path);
        }
    }
    files.sort();
    files.dedup();
    let mut diags = Vec::new();
    for file in files {
        let src = std::fs::read_to_string(&file)?;
        diags.extend(rules::lint_source(&rel_label(root, &file), &src));
    }
    Ok(diags)
}

//! Scenario-matrix driver: the `model_comparison`-style example for the
//! parallel sweep harness. Runs PPA (ARMA, trained online, plus the naive
//! last-value model) against HPA over a topology's full preset scenario
//! library — the Table-2 presets on `paper`, the generated N-zone
//! composites on `city-N[xW]` — across several seeds, in parallel, and
//! writes a JSON report.
//!
//! ```bash
//! cargo run --release --example scenario_sweep              # 30 min cells, 4 seeds, paper
//! cargo run --release --example scenario_sweep -- 60 8      # 60 min cells, 8 seeds
//! cargo run --release --example scenario_sweep -- 30 2 city-50   # city-scale grid
//! ```

use ppa_edge::config::Topology;
use ppa_edge::experiments::{run_sweep, AutoscalerKind, SweepConfig};
use ppa_edge::report;
use ppa_edge::sim::CoreKind;

fn main() -> anyhow::Result<()> {
    let minutes: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    let n_seeds: u64 = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    let topology = match std::env::args().nth(3) {
        Some(s) => Topology::parse(&s)?,
        None => Topology::Paper,
    };

    let cfg = SweepConfig {
        topology,
        scenarios: topology.scenario_presets(),
        scalers: vec![
            AutoscalerKind::Hpa,
            AutoscalerKind::PpaArma,
            AutoscalerKind::PpaNaive,
        ],
        seeds: (0..n_seeds).map(|i| 2021 + i).collect(),
        minutes,
        threads: 0, // one worker per core
        core: CoreKind::Calendar,
    };
    println!(
        "scenario sweep: {} scenarios x {} autoscalers x {} seeds on {} ({} sim-minutes per cell)",
        cfg.scenarios.len(),
        cfg.scalers.len(),
        cfg.seeds.len(),
        topology.label(),
        minutes
    );

    let result = run_sweep(&cfg)?;
    report::print_sweep(&result);

    let out = std::path::Path::new("target/experiments/scenario_sweep.json");
    result.write_json(out)?;
    println!("json report: {}", out.display());
    Ok(())
}

//! The example edge application (paper §5.1): a two-tier CPU-intensive
//! service.
//!
//! Requests arrive at their origin edge zone's entrypoint. *Sort* tasks
//! (cheap, `n log n`) are handled by that zone's edge worker pool; *Eigen*
//! tasks (expensive, `n³`) are forwarded to the cloud worker pool. Each
//! worker pool is one autoscaled deployment plus a shared FIFO task queue
//! (the Celery broker); worker pods are single-slot (Celery concurrency 1).
//!
//! # Hot-path data structures
//!
//! The arrival→complete path is hash-free, never scans a pod list, and
//! does not allocate at steady state beyond amortized idle-set node
//! churn (the `BTreeSet` may free/reallocate a node when a pool
//! oscillates between zero and one idle pod):
//!
//! * **In-flight requests** live in a [`RequestArena`] — a generational
//!   slab addressed by [`RequestId`] (slot index + generation). Events
//!   carry the copyable handle; a handle goes stale the moment its
//!   request completes, so late/duplicate events miss instead of
//!   aliasing a recycled slot (see the `arena` module docs for the
//!   generation rules).
//! * **Dispatch** pops the idle Running pod with the lowest id from the
//!   deployment's idle-pod ordered set ([`Cluster::min_idle_pod`], an
//!   O(log n) read maintained on every phase/occupancy transition) —
//!   the same deterministic min-pod-id choice the old per-request
//!   `running_pods` scan made, without walking the pool. Occupancy
//!   changes go through [`Cluster::start_service`] /
//!   [`Cluster::finish_service`] so the set stays exact.
//! * **Zone routing** resolves the origin zone to its edge service
//!   through a dense `Vec` (zones are contiguous indices) — no hash on
//!   the submit path.
//! * **Completed requests** stream into [`ResponseStats`] — per-task
//!   Welford moments + log-histogram quantiles
//!   ([`crate::stats::StreamingStats`]) in constant memory. The
//!   unbounded per-request log is **opt-in** via
//!   [`App::retain_responses`]; only the paper-figure harnesses (which
//!   need exact traces for Welch tests and CSV dumps) enable it.

mod arena;
mod request;

pub use arena::RequestArena;
pub use request::{
    Priority, PriorityMix, Request, ResponseRecord, SlaConfig, SlaPolicy, TaskType,
};

use crate::cluster::{Cluster, NetChaos, PodPhase};
use crate::sim::{Event, EventQueue, PodId, RequestId, ServiceId, Time, MIN, MS};
use crate::stats::StreamingStats;
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// Calibrated task costs. The paper gives complexities (Sort: 1e4 ops,
/// Eigen: 1e9 ops) and measures ~0.5 s / ~13.6 s end-to-end responses on
/// its Celery workers; we express costs in core-seconds so the same task
/// takes proportionally longer on smaller pods (DESIGN.md §Substitutions).
#[derive(Debug, Clone, Copy)]
pub struct TaskCosts {
    /// Core-seconds for a Sort task (3000-element array).
    pub sort_core_secs: f64,
    /// Core-seconds for an Eigen task (1000x1000 matrix).
    pub eigen_core_secs: f64,
    /// Per-request dispatch overhead **executed on the worker pod**
    /// (broker fetch + deserialization + result publish) — occupies the
    /// pod and burns its CPU, like a Celery worker.
    pub overhead: Time,
    /// Client→entrypoint network latency (not pod time).
    pub network_latency: Time,
    /// Extra one-way latency for edge→cloud forwarding of Eigen tasks.
    pub forward_latency: Time,
    /// Multiplicative service-time jitter std (lognormal-ish via normal).
    pub jitter_std: f64,
    /// Fraction of a pod's CPU request burned continuously while Running
    /// (interpreter, broker polling, exporter sidecar) — this is what
    /// keeps real Celery pods from ever reading 0% CPU and is included in
    /// the utilization metric the autoscalers see.
    pub base_burn_frac: f64,
}

impl Default for TaskCosts {
    /// Calibrated against the paper's measured scales (see
    /// `examples/calibrate.rs` and DESIGN.md §Substitutions): Sort
    /// ≈ 0.59 s and Eigen ≈ 14 s mean response under HPA on the Table-2
    /// cluster.
    fn default() -> Self {
        TaskCosts {
            // Chosen so the NASA peak keeps the cloud Eigen pool at
            // ~60-70% of its 4-pod capacity: the paper scaled its
            // workload "so that the peak ... does not exceed resource
            // limitations" (§5.2.2) — under saturation the CPU metric
            // clips at 100%/pod and no CPU-keyed autoscaler can see
            // residual demand.
            sort_core_secs: 0.12,
            eigen_core_secs: 5.5,
            overhead: 250 * MS,
            network_latency: 20 * MS,
            forward_latency: 40 * MS,
            jitter_std: 0.05,
            // Must stay below threshold/(2*100) = 0.35 of the 70% Eq-1
            // target: at 0.5 the idle-pod CPU sum alone makes k=3 an
            // absorbing replica state (ceil(50k/70) == k) and both
            // autoscalers get pinned high.
            base_burn_frac: 0.30,
        }
    }
}

/// Per-service (per worker pool) traffic counters, drained at each scrape.
#[derive(Debug, Default, Clone, Copy)]
pub struct TrafficCounters {
    pub arrivals: u64,
    pub net_in_bytes: u64,
    pub net_out_bytes: u64,
    /// SLA violations observed since the last scrape (always 0 without
    /// an installed policy) — feeds the `<svc>.sla_violations` rate
    /// series the hybrid scaler's reactive override watches.
    pub sla_violations: u64,
}

/// One worker pool: an autoscaled deployment + its shared FIFO queue.
#[derive(Debug)]
pub struct Service {
    pub id: ServiceId,
    pub name: String,
    pub deployment: crate::cluster::DeploymentId,
    pub queue: VecDeque<RequestId>,
    pub counters: TrafficCounters,
}

/// Request payload sizes for network metrics (bytes).
const SORT_IN: u64 = 24_000; // 3000 x i64
const SORT_OUT: u64 = 24_000;
const EIGEN_IN: u64 = 8_000_000; // 1000x1000 f64
const EIGEN_OUT: u64 = 16_000;

/// Streaming per-task response statistics: what every consumer that
/// only needs counts / moments / quantiles reads instead of a full
/// per-request log. Constant memory, deterministic (see
/// [`crate::stats::StreamingStats`]).
#[derive(Debug, Clone, Default)]
pub struct ResponseStats {
    pub sort: StreamingStats,
    pub eigen: StreamingStats,
}

impl ResponseStats {
    fn record(&mut self, task: TaskType, secs: f64) {
        match task {
            TaskType::Sort => self.sort.record(secs),
            TaskType::Eigen => self.eigen.record(secs),
        }
    }

    /// Total completed requests across task types.
    pub fn completed(&self) -> usize {
        self.sort.n() + self.eigen.n()
    }

    /// Bit-exact digest of both task streams — equal iff two runs
    /// completed the same requests with the same timings in the same
    /// order (the determinism-test comparison primitive).
    pub fn fingerprint(&self) -> String {
        format!(
            "sort[{}] eigen[{}]",
            self.sort.fingerprint(),
            self.eigen.fingerprint()
        )
    }
}

/// Dedicated RNG stream for the resilience plane of world `world`
/// (monolith = world 0): priority draws and retry jitter. Disjoint
/// from the engine streams (1–3), the sharded per-world streams (10+)
/// and the chaos bands (1–3 million), so installing an SLA policy
/// never perturbs engine or chaos randomness.
pub fn sla_stream(world: u32) -> u64 {
    4_000_000 + world as u64
}

/// Resilience-plane event counters (all zero on SLA-free runs). The
/// shard merge adds counters in world order; `violation_minutes` is
/// each world's count of distinct sim-minutes containing ≥ 1
/// violation, summed across worlds.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct SlaCounters {
    /// Deadline expiries observed (= retries + violations).
    pub timeouts: u64,
    /// Retries scheduled (budget still available at expiry).
    pub retries: u64,
    /// Requests dropped with a spent retry budget.
    pub violations: u64,
    /// `Batch` arrivals shed by admission control.
    pub shed: u64,
    /// Distinct sim-minutes with ≥ 1 violation (SLA breach duration —
    /// the Pareto table's y-axis).
    pub violation_minutes: u64,
}

impl SlaCounters {
    pub fn merge(&mut self, other: &SlaCounters) {
        self.timeouts += other.timeouts;
        self.retries += other.retries;
        self.violations += other.violations;
        self.shed += other.shed;
        self.violation_minutes += other.violation_minutes;
    }

    pub fn is_zero(&self) -> bool {
        *self == SlaCounters::default()
    }
}

/// End-of-run resilience summary: the counters plus per-class response
/// stats (indexed by [`Priority::index`]). All-zero/empty on SLA-free
/// runs.
#[derive(Debug, Default, Clone)]
pub struct SlaSummary {
    pub counters: SlaCounters,
    pub class_stats: [StreamingStats; Priority::COUNT],
}

impl SlaSummary {
    /// Fold in another world's summary (called in shard world order so
    /// the merged digest is deterministic).
    pub fn merge(&mut self, other: &SlaSummary) {
        self.counters.merge(&other.counters);
        for (mine, theirs) in self.class_stats.iter_mut().zip(&other.class_stats) {
            mine.merge(theirs);
        }
    }
}

/// Per-app resilience state — present only when a policy is installed
/// (see [`App::install_sla`]; absence is a strict no-op).
#[derive(Debug)]
struct SlaRuntime {
    policy: SlaPolicy,
    mix: PriorityMix,
    /// The dedicated [`sla_stream`] RNG: priority draws + retry jitter.
    rng: Pcg64,
    counters: SlaCounters,
    class_stats: [StreamingStats; Priority::COUNT],
    /// Last sim-minute already counted into `violation_minutes`.
    last_violation_minute: Option<Time>,
}

/// An Eigen task leaving an edge shard for the shared cloud pool: the
/// plain-data record exchanged between shard worlds at barrier ticks
/// (see [`crate::sim::shard`]). Carries everything the cloud world
/// needs to reconstruct the request with monolith semantics — the
/// response clock starts at `submitted`, the arrival lands at
/// `submitted + network_latency + forward_latency`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ForwardedTask {
    pub origin_zone: u32,
    /// Client submit time at the edge (the request's `created` stamp).
    pub submitted: Time,
    /// Priority class drawn in the *edge* world's SLA stream (so the
    /// draw schedule is shard-count-invariant); `Standard` without a
    /// policy. The cloud world applies its own shed/deadline logic to
    /// the delivered request.
    pub priority: Priority,
}

/// The application: services, the in-flight request arena, streaming
/// response statistics (plus the opt-in exact log).
#[derive(Debug)]
pub struct App {
    pub services: Vec<Service>,
    pub costs: TaskCosts,
    /// Dense zone table: `edge_service_by_zone[zone]` is the edge
    /// service handling that zone's Sort tasks (zones are contiguous
    /// indices, so this replaces a per-submit hash lookup).
    edge_service_by_zone: Vec<Option<ServiceId>>,
    cloud_service: ServiceId,
    in_flight: RequestArena,
    /// `Some` on an edge-shard app: Eigen submits are captured here as
    /// [`ForwardedTask`]s (instead of routing to a local cloud service)
    /// for delivery into the cloud world at the next barrier. `None` on
    /// monolith and cloud-shard apps.
    forward_outbox: Option<Vec<ForwardedTask>>,
    /// Streaming per-task response statistics (always on, O(1) memory).
    pub stats: ResponseStats,
    /// Exact completed-request log — `None` (off) by default; enabled by
    /// [`App::retain_responses`] for harnesses that need full traces.
    response_log: Option<Vec<ResponseRecord>>,
    /// Chaos plane: extra edge→cloud delay drawn per Eigen forward.
    /// `None` (the default) leaves the forward path untouched. In the
    /// sharded engine only the cloud world installs this — the edge
    /// shards intercept Eigen submits into the outbox without a draw,
    /// so the draw order is the (shard-count-invariant) merge order.
    net_chaos: Option<NetChaos>,
    /// Resilience plane: deadlines, retries, priority shedding. `None`
    /// (the default) is a strict no-op — no RNG, no timeout events, no
    /// priority draws — keeping SLA-free runs byte-identical to
    /// pre-resilience builds.
    sla: Option<SlaRuntime>,
}

impl App {
    /// Build the app over deployments already registered in the cluster.
    /// `edge` maps zone -> deployment; `cloud` is the Eigen pool.
    pub fn new(
        costs: TaskCosts,
        edge: &[(u32, crate::cluster::DeploymentId)],
        cloud: crate::cluster::DeploymentId,
    ) -> Self {
        let mut services = Vec::new();
        let mut edge_service_by_zone: Vec<Option<ServiceId>> = Vec::new();
        for &(zone, dep) in edge {
            let id = ServiceId(services.len() as u32);
            services.push(Service {
                id,
                name: format!("edge-workers-z{zone}"),
                deployment: dep,
                queue: VecDeque::new(),
                counters: TrafficCounters::default(),
            });
            let z = zone as usize;
            if edge_service_by_zone.len() <= z {
                edge_service_by_zone.resize(z + 1, None);
            }
            edge_service_by_zone[z] = Some(id);
        }
        let cloud_service = ServiceId(services.len() as u32);
        services.push(Service {
            id: cloud_service,
            name: "cloud-workers".to_string(),
            deployment: cloud,
            queue: VecDeque::new(),
            counters: TrafficCounters::default(),
        });
        App {
            services,
            costs,
            edge_service_by_zone,
            cloud_service,
            in_flight: RequestArena::new(),
            forward_outbox: None,
            stats: ResponseStats::default(),
            response_log: None,
            net_chaos: None,
            sla: None,
        }
    }

    /// A single-zone edge-shard app: one edge service, no local cloud
    /// pool. Eigen submits are intercepted into the forward outbox (see
    /// [`App::take_forwards`]) and return an inert sentinel handle —
    /// the real request materializes in the cloud world via
    /// [`App::deliver_forward`].
    pub fn new_edge_shard(
        costs: TaskCosts,
        zone: u32,
        dep: crate::cluster::DeploymentId,
    ) -> Self {
        let id = ServiceId(0);
        let mut edge_service_by_zone = vec![None; zone as usize + 1];
        edge_service_by_zone[zone as usize] = Some(id);
        App {
            services: vec![Service {
                id,
                name: format!("edge-workers-z{zone}"),
                deployment: dep,
                queue: VecDeque::new(),
                counters: TrafficCounters::default(),
            }],
            costs,
            edge_service_by_zone,
            // Never read: Eigen submits are intercepted by the outbox
            // before the cloud route resolves.
            cloud_service: ServiceId(u32::MAX),
            in_flight: RequestArena::new(),
            forward_outbox: Some(Vec::new()),
            stats: ResponseStats::default(),
            response_log: None,
            net_chaos: None,
            sla: None,
        }
    }

    /// A cloud-shard app: only the shared Eigen pool. Requests arrive
    /// exclusively through [`App::deliver_forward`].
    pub fn new_cloud_shard(costs: TaskCosts, cloud: crate::cluster::DeploymentId) -> Self {
        let cloud_service = ServiceId(0);
        App {
            services: vec![Service {
                id: cloud_service,
                name: "cloud-workers".to_string(),
                deployment: cloud,
                queue: VecDeque::new(),
                counters: TrafficCounters::default(),
            }],
            costs,
            edge_service_by_zone: Vec::new(),
            cloud_service,
            in_flight: RequestArena::new(),
            forward_outbox: None,
            stats: ResponseStats::default(),
            response_log: None,
            net_chaos: None,
            sla: None,
        }
    }

    /// Drain the edge-shard forward outbox (empty for monolith and
    /// cloud-shard apps). Entries are in submit order, so their
    /// `submitted` times are non-decreasing.
    pub fn take_forwards(&mut self) -> Vec<ForwardedTask> {
        match &mut self.forward_outbox {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }

    /// Materialize a forwarded Eigen task in this (cloud-shard) app.
    /// Counters are attributed at delivery time; the arrival is
    /// scheduled at the absolute time the monolith would have used
    /// (`submitted + network_latency + forward_latency`), which the
    /// barrier protocol guarantees is still in this world's future.
    pub fn deliver_forward(&mut self, fwd: ForwardedTask, queue: &mut EventQueue) {
        let service = self.cloud_service;
        // Admission control at the cloud ingress: Batch forwards are
        // shed against the cloud queue depth (deliveries arrive in the
        // deterministic barrier merge order, so the depth seen here is
        // shard-count-invariant).
        if let Some(sla) = &mut self.sla {
            if fwd.priority == Priority::Batch
                && self.services[service.0 as usize].queue.len() > sla.policy.shed_queue_depth
            {
                sla.counters.shed += 1;
                return;
            }
        }
        let id = self.in_flight.insert(Request {
            task: TaskType::Eigen,
            origin_zone: fwd.origin_zone,
            service,
            created: fwd.submitted,
            priority: fwd.priority,
            attempts: 0,
        });
        self.services[service.0 as usize].counters.arrivals += 1;
        self.services[service.0 as usize].counters.net_in_bytes += EIGEN_IN;
        let mut latency = self.costs.network_latency + self.costs.forward_latency;
        if let Some(nc) = &mut self.net_chaos {
            // Extra delay ≥ 0 only pushes the arrival later, so the
            // barrier protocol's future-window guarantee still holds.
            latency = latency.saturating_add(nc.draw_extra());
        }
        queue.schedule_at(
            fwd.submitted.saturating_add(latency),
            Event::RequestArrival { request_id: id },
        );
        if let Some(sla) = &self.sla {
            // Same absolute deadline the monolith uses (`created +
            // deadline`); if the forward already overran it, the queue
            // clamps the event to now and the retry path takes over.
            queue.schedule_at(
                fwd.submitted.saturating_add(sla.policy.deadline),
                Event::RequestTimeout { request_id: id },
            );
        }
    }

    /// Install (or clear) the chaos-plane extra forward delay. `None`
    /// (the default) keeps the forward path bit-identical to fault-free
    /// runs. Monolith worlds install it unconditionally; sharded runs
    /// install it only on the cloud world (see the field docs).
    pub fn set_net_chaos(&mut self, chaos: Option<NetChaos>) {
        self.net_chaos = chaos;
    }

    /// Install the resilience plane: per-request priorities, deadlines,
    /// retry/backoff and `Batch` shedding per `cfg`. Call before the
    /// run. When never called the plane is a strict no-op (no RNG
    /// construction, no timeout events, no priority draws), so SLA-free
    /// runs stay byte-identical to pre-resilience builds. All SLA
    /// randomness comes from the dedicated [`sla_stream`] of `world`
    /// (monolith = 0), never from the engine streams.
    pub fn install_sla(&mut self, cfg: &SlaConfig, seed: u64, world: u32) {
        self.sla = Some(SlaRuntime {
            policy: cfg.policy,
            mix: cfg.mix,
            rng: Pcg64::new(seed, sla_stream(world)),
            counters: SlaCounters::default(),
            class_stats: Default::default(),
            last_violation_minute: None,
        });
    }

    /// Whether an SLA policy is installed.
    pub fn sla_active(&self) -> bool {
        self.sla.is_some()
    }

    /// End-of-run resilience summary (all-zero default when no policy
    /// is installed). Non-destructive clone.
    pub fn sla_summary(&self) -> SlaSummary {
        match &self.sla {
            Some(s) => SlaSummary {
                counters: s.counters,
                class_stats: s.class_stats.clone(),
            },
            None => SlaSummary::default(),
        }
    }

    /// Turn on the exact per-request log (unbounded memory — for the
    /// paper-figure harnesses and trace dumps; sweeps stay streaming).
    pub fn retain_responses(&mut self) {
        if self.response_log.is_none() {
            self.response_log = Some(Vec::new());
        }
    }

    /// The exact completed-request log, if [`App::retain_responses`] was
    /// called before the run.
    pub fn response_log(&self) -> Option<&[ResponseRecord]> {
        self.response_log.as_deref()
    }

    /// Total completed requests (from the streaming stats — always
    /// available, log or no log).
    pub fn completed(&self) -> usize {
        self.stats.completed()
    }

    /// Number of requests currently in flight (arena occupancy).
    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    pub fn service(&self, id: ServiceId) -> &Service {
        &self.services[id.0 as usize]
    }

    /// Total queue depth across services (back-pressure indicator).
    pub fn queued_total(&self) -> usize {
        self.services.iter().map(|s| s.queue.len()).sum()
    }

    /// A client submits a task from `zone` at `now`. Routes per the paper:
    /// Sort → that zone's edge pool; Eigen → the cloud pool (with forward
    /// latency). Returns the request's generational handle.
    pub fn submit(
        &mut self,
        task: TaskType,
        zone: u32,
        now: Time,
        queue: &mut EventQueue,
    ) -> RequestId {
        // Resilience plane: exactly one priority draw per submit (the
        // stream advance schedule is independent of routing, shedding
        // and the mix values); constant `Standard` without a policy.
        let priority = match &mut self.sla {
            Some(sla) => sla.mix.draw(&mut sla.rng),
            None => Priority::Standard,
        };
        // Edge-shard interception: the Eigen task belongs to the cloud
        // world; record the crossing and hand back an inert stale-shaped
        // handle (no arena slot — lookups on it miss like any stale id).
        if task == TaskType::Eigen {
            if let Some(outbox) = &mut self.forward_outbox {
                outbox.push(ForwardedTask {
                    origin_zone: zone,
                    submitted: now,
                    priority,
                });
                return RequestId::new(u32::MAX, u32::MAX);
            }
        }
        let (service, mut latency, bytes_in) = match task {
            TaskType::Sort => {
                // detlint: allow(P1) — an unknown zone is a config-construction bug; fail loudly at the ingress boundary instead of silently misrouting traffic
                let svc = self
                    .edge_service_by_zone
                    .get(zone as usize)
                    .copied()
                    .flatten()
                    .expect("unknown origin zone");
                (svc, self.costs.network_latency, SORT_IN)
            }
            TaskType::Eigen => (
                self.cloud_service,
                self.costs.network_latency + self.costs.forward_latency,
                EIGEN_IN,
            ),
        };
        if task == TaskType::Eigen {
            if let Some(nc) = &mut self.net_chaos {
                // Monolith-only: chaos on the edge→cloud forward hop.
                latency = latency.saturating_add(nc.draw_extra());
            }
        }
        // Admission control: shed Batch arrivals (never Critical or
        // Standard) while the target queue is over the policy depth.
        if let Some(sla) = &mut self.sla {
            if priority == Priority::Batch
                && self.services[service.0 as usize].queue.len() > sla.policy.shed_queue_depth
            {
                sla.counters.shed += 1;
                return RequestId::new(u32::MAX, u32::MAX);
            }
        }
        let id = self.in_flight.insert(Request {
            task,
            origin_zone: zone,
            service,
            created: now,
            priority,
            attempts: 0,
        });
        self.services[service.0 as usize].counters.arrivals += 1;
        self.services[service.0 as usize].counters.net_in_bytes += bytes_in;
        queue.schedule_in(latency, Event::RequestArrival { request_id: id });
        if let Some(sla) = &self.sla {
            queue.schedule_at(
                now.saturating_add(sla.policy.deadline),
                Event::RequestTimeout { request_id: id },
            );
        }
        id
    }

    /// `RequestArrival` handler: enqueue at the service and try dispatch.
    /// Stale handles (generation mismatch — the request was cancelled or
    /// already completed) are dropped silently.
    pub fn on_arrival(
        &mut self,
        request_id: RequestId,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let service = match self.in_flight.get(request_id) {
            Some(r) => r.service,
            None => return, // stale handle
        };
        self.services[service.0 as usize].queue.push_back(request_id);
        self.dispatch(service, cluster, queue, rng);
    }

    /// Pull queued work onto idle running pods of the service's deployment.
    pub fn dispatch(
        &mut self,
        service: ServiceId,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let dep = self.services[service.0 as usize].deployment;
        loop {
            if self.services[service.0 as usize].queue.is_empty() {
                return;
            }
            // Deterministic idle-pod choice: lowest pod id, popped from
            // the deployment's idle-pod ordered set in O(log n) — the
            // same pod the old per-request `running_pods` scan picked.
            let Some(pid) = cluster.min_idle_pod(dep) else { return };
            let Some(req_id) = self.services[service.0 as usize].queue.pop_front() else {
                // Unreachable: emptiness was checked at the top of the
                // loop and nothing pops between there and here.
                return;
            };
            let Some(task) = self.in_flight.get(req_id).map(|r| r.task) else {
                // Stale handle (the request completed or was cancelled
                // while queued): drop it and keep pulling work.
                continue;
            };
            cluster.start_service(pid, req_id, queue.now());
            let cpu_millis = cluster.pod(pid).spec.cpu_millis;
            let service_time = self.service_time(task, cpu_millis, rng);
            queue.schedule_in(
                service_time,
                Event::ServiceComplete {
                    pod: pid,
                    request_id: req_id,
                },
            );
        }
    }

    /// Pod occupancy of `task` on a pod with `cpu_millis` CPU: dispatch
    /// overhead (on-pod) plus compute scaled by the pod's CPU share.
    fn service_time(&self, task: TaskType, cpu_millis: u32, rng: &mut Pcg64) -> Time {
        let core_secs = match task {
            TaskType::Sort => self.costs.sort_core_secs,
            TaskType::Eigen => self.costs.eigen_core_secs,
        };
        let cores = cpu_millis as f64 / 1000.0;
        let jitter = (1.0 + self.costs.jitter_std * rng.normal()).max(0.5);
        self.costs.overhead + crate::sim::from_secs(core_secs / cores * jitter)
    }

    /// `ServiceComplete` handler: stream the response into the stats
    /// (and the exact log when retained), free (or drain) the pod, and
    /// keep the queue moving. Removing the request from the arena bumps
    /// its slot generation, so the handle goes stale here.
    pub fn on_complete(
        &mut self,
        pid: PodId,
        request_id: RequestId,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let now = queue.now();
        // Stale-event guard: if this pod is no longer servicing this
        // request (its node crashed and the request was re-queued under
        // a fresh handle, or the slot was recycled), drop the event. On
        // the fault-free path the pod always holds exactly this request
        // here, so the guard never fires there.
        if cluster.pod(pid).current_request != Some(request_id) {
            return;
        }
        // Through the cluster so the idle-pod set re-admits the pod.
        let finished = cluster.finish_service(pid, now);
        debug_assert_eq!(finished, Some(request_id));
        let draining = cluster.pod(pid).phase == PodPhase::Terminating;
        if draining {
            queue.schedule_in(
                crate::cluster::TERMINATION_GRACE,
                Event::PodTerminated { pod: pid },
            );
        }

        if let Some(req) = self.in_flight.remove(request_id) {
            let out = match req.task {
                TaskType::Sort => SORT_OUT,
                TaskType::Eigen => EIGEN_OUT,
            };
            self.services[req.service.0 as usize].counters.net_out_bytes += out;
            let record = ResponseRecord {
                task: req.task,
                origin_zone: req.origin_zone,
                created: req.created,
                completed: now,
            };
            self.stats.record(req.task, record.response_secs());
            if let Some(sla) = &mut self.sla {
                sla.class_stats[req.priority.index()].record(record.response_secs());
            }
            if let Some(log) = &mut self.response_log {
                log.push(record);
            }
            // Keep the queue moving — even when this pod is draining,
            // another pod may be idle.
            self.dispatch(req.service, cluster, queue, rng);
        } else {
            // Abandoned attempt: the deadline expired while this pod
            // was serving, so the arena entry moved to a fresh retry
            // handle (or was violation-dropped) — the work is wasted
            // but the pod just went idle, so keep its pool moving.
            // Unreachable without an SLA policy: nothing else removes
            // an entry while its pod still holds `current_request`.
            let dep = cluster.pod(pid).deployment;
            if let Some(svc) = self.services.iter().position(|s| s.deployment == dep) {
                self.dispatch(ServiceId(svc as u32), cluster, queue, rng);
            }
        }
    }

    /// `RequestTimeout` handler — the resilience plane's deadline
    /// logic. A stale handle (the request completed, or an earlier
    /// timeout already moved it) is a silent no-op. A live request past
    /// its deadline is retried under a fresh generational handle after
    /// deterministic exponential backoff (`backoff_base * 2^(k-1)` plus
    /// jitter uniform in `[0, backoff_base)` from the SLA stream), or
    /// counted as an SLA violation and dropped once the retry budget is
    /// spent. In-service requests are abandoned client-side: the pod
    /// keeps burning until its `ServiceComplete`, which then misses the
    /// arena and only re-dispatches the pool.
    pub fn on_timeout(&mut self, request_id: RequestId, queue: &mut EventQueue) {
        let Some(sla) = &mut self.sla else {
            return; // stray event — only possible if a policy was never installed
        };
        let Some(req) = self.in_flight.get(request_id).copied() else {
            return; // stale handle: completed (or already retried) in time
        };
        let now = queue.now();
        sla.counters.timeouts += 1;
        if req.attempts >= sla.policy.max_retries {
            // Budget spent: violation. Dropping the arena entry stales
            // the queued handle / pending ServiceComplete.
            sla.counters.violations += 1;
            let minute = now / MIN;
            if sla.last_violation_minute != Some(minute) {
                sla.last_violation_minute = Some(minute);
                sla.counters.violation_minutes += 1;
            }
            self.services[req.service.0 as usize].counters.sla_violations += 1;
            self.in_flight.remove(request_id);
            return;
        }
        // Retry: stale the old handle, re-enter under a fresh one.
        sla.counters.retries += 1;
        let shift = req.attempts.min(20);
        let backoff = sla.policy.backoff_base.saturating_mul(1u64 << shift);
        let jitter = sla.rng.below(sla.policy.backoff_base.max(1));
        let delay = backoff.saturating_add(jitter);
        let mut retry = req;
        retry.attempts += 1;
        self.in_flight.remove(request_id);
        let fresh = self.in_flight.insert(retry);
        let arrive_at = now.saturating_add(delay);
        queue.schedule_at(arrive_at, Event::RequestArrival { request_id: fresh });
        queue.schedule_at(
            arrive_at.saturating_add(sla.policy.deadline),
            Event::RequestTimeout { request_id: fresh },
        );
    }

    /// Re-queue requests orphaned by a node crash: each orphan is
    /// removed from the arena (its old handle — and any in-queue
    /// `ServiceComplete` carrying it — goes stale) and re-inserted
    /// under a fresh generational handle at the back of its service's
    /// queue, keeping the original `created` stamp so the response time
    /// includes the outage. Touched services are then re-dispatched.
    pub fn requeue_orphans(
        &mut self,
        orphans: &[RequestId],
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let mut touched: Vec<ServiceId> = Vec::new();
        for &old in orphans {
            let Some(req) = self.in_flight.remove(old) else {
                continue; // already stale (double-crash paranoia)
            };
            let service = req.service;
            let fresh = self.in_flight.insert(req);
            self.services[service.0 as usize].queue.push_back(fresh);
            if !touched.contains(&service) {
                touched.push(service);
            }
        }
        for service in touched {
            self.dispatch(service, cluster, queue, rng);
        }
    }

    /// Drain traffic counters for a scrape (returns per-service snapshot).
    pub fn take_counters(&mut self) -> Vec<TrafficCounters> {
        self.services
            .iter_mut()
            .map(|s| std::mem::take(&mut s.counters))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, DeploymentId, NodeSpec, PodSpec, Selector, Tier};
    use crate::sim::SEC;

    fn world() -> (App, Cluster, EventQueue, Pcg64) {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        cluster.add_node(NodeSpec::new("c1", Tier::Cloud, 0, 3000, 3072));
        let edge_dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            1,
            8,
        ));
        let cloud_dep = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Cloud, None),
            PodSpec::new(1000, 512),
            1,
            8,
        ));
        let mut app = App::new(TaskCosts::default(), &[(1, edge_dep)], cloud_dep);
        // Tests inspect individual responses, so keep the exact log too.
        app.retain_responses();
        (app, cluster, EventQueue::new(), Pcg64::new(42, 7))
    }

    /// Run the event loop to exhaustion, handling app/cluster events.
    fn run(app: &mut App, cluster: &mut Cluster, q: &mut EventQueue, rng: &mut Pcg64) {
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::RequestArrival { request_id } => {
                    app.on_arrival(request_id, cluster, q, rng)
                }
                Event::ServiceComplete { pod, request_id } => {
                    app.on_complete(pod, request_id, cluster, q, rng)
                }
                Event::PodRunning { pod } => {
                    if cluster.on_pod_running(pod) {
                        // A fresh pod may unblock a queue.
                        let dep = cluster.pod(pod).deployment;
                        let svc = app
                            .services
                            .iter()
                            .find(|s| s.deployment == dep)
                            .map(|s| s.id);
                        if let Some(svc) = svc {
                            app.dispatch(svc, cluster, q, rng);
                        }
                    }
                }
                Event::PodTerminated { pod } => cluster.on_pod_terminated(pod),
                _ => {}
            }
        }
    }

    fn log(app: &App) -> &[ResponseRecord] {
        app.response_log().expect("test worlds retain the log")
    }

    #[test]
    fn sort_request_completes_with_expected_latency() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        cluster.reconcile(DeploymentId(1), 1, &mut q, &mut rng);
        app.submit(TaskType::Sort, 1, 0, &mut q);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 1);
        let r = &log(&app)[0];
        // 0.2 core-sec on 500m = 0.4 s (+80 ms overhead + init wait).
        let resp = r.response_secs();
        assert!(resp > 0.4 && resp < 15.0, "resp={resp}");
        assert_eq!(r.task, TaskType::Sort);
        // Streaming stats saw the same response.
        assert_eq!(app.stats.sort.n(), 1);
        assert!((app.stats.sort.mean() - resp).abs() < 1e-12);
    }

    #[test]
    fn eigen_routes_to_cloud() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        cluster.reconcile(DeploymentId(1), 1, &mut q, &mut rng);
        app.submit(TaskType::Eigen, 1, 0, &mut q);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 1);
        // 5.5 core-sec on 1000m ≈ 5.5 s service.
        let resp = log(&app)[0].response_secs();
        assert!(resp > 5.0, "resp={resp}");
        assert_eq!(app.stats.eigen.n(), 1);
        // Cloud service counted the arrival.
        assert_eq!(app.services[1].counters.arrivals, 1);
        assert!(app.services[1].counters.net_in_bytes >= EIGEN_IN);
    }

    #[test]
    fn fifo_queueing_when_single_pod() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        for _ in 0..3 {
            app.submit(TaskType::Sort, 1, 0, &mut q);
        }
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 3);
        // Sequential service: responses strictly increasing.
        let times: Vec<f64> = log(&app).iter().map(|r| r.response_secs()).collect();
        assert!(times.windows(2).all(|w| w[1] > w[0]), "{times:?}");
    }

    #[test]
    fn more_replicas_cut_queueing() {
        let measure = |replicas: usize| {
            let (mut app, mut cluster, mut q, mut rng) = world();
            cluster.reconcile(DeploymentId(0), replicas, &mut q, &mut rng);
            // Let pods come up first.
            run(&mut app, &mut cluster, &mut q, &mut rng);
            for _ in 0..6 {
                app.submit(TaskType::Sort, 1, q.now(), &mut q);
            }
            run(&mut app, &mut cluster, &mut q, &mut rng);
            app.stats.sort.mean()
        };
        let slow = measure(1);
        let fast = measure(3);
        assert!(
            fast < slow * 0.7,
            "3 replicas should be much faster: {fast} vs {slow}"
        );
    }

    #[test]
    fn draining_pod_finishes_then_terminates() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        // Bring pod up.
        while let Some((_, ev)) = q.pop() {
            if let Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
                break;
            }
        }
        app.submit(TaskType::Sort, 1, q.now(), &mut q);
        // Arrival event then dispatch.
        if let Some((_, Event::RequestArrival { request_id })) = q.pop() {
            app.on_arrival(request_id, &mut cluster, &mut q, &mut rng);
        }
        // Scale to zero while busy (min_replicas=1 clamps to 1... use 0-min dep)
        cluster.deployments[0].min_replicas = 0;
        cluster.reconcile(DeploymentId(0), 0, &mut q, &mut rng);
        assert_eq!(cluster.count_phase(DeploymentId(0), PodPhase::Terminating), 1);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 1, "in-flight request must finish");
        assert_eq!(cluster.live_replicas(DeploymentId(0)), 0);
    }

    #[test]
    fn counters_drain_on_take() {
        let (mut app, _cluster, mut q, _rng) = world();
        app.submit(TaskType::Sort, 1, 0, &mut q);
        app.submit(TaskType::Sort, 1, 0, &mut q);
        let snap = app.take_counters();
        assert_eq!(snap[0].arrivals, 2);
        let snap2 = app.take_counters();
        assert_eq!(snap2[0].arrivals, 0);
    }

    #[test]
    fn service_time_scales_with_cpu() {
        let (app, _c, _q, mut rng) = world();
        // Compute portion scales ~4x between 500m and 2000m; the fixed
        // dispatch overhead does not.
        let ovh = app.costs.overhead;
        let t_small = app.service_time(TaskType::Sort, 500, &mut rng) - ovh;
        let t_big = app.service_time(TaskType::Sort, 2000, &mut rng) - ovh;
        assert!(
            t_small > 3 * t_big,
            "compute on 500m should be ~4x slower than 2000m: {t_small} vs {t_big}"
        );
        let _ = SEC;
    }

    #[test]
    fn stale_arrival_is_dropped() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        let id = app.submit(TaskType::Sort, 1, 0, &mut q);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 1);
        // The handle is stale now (slot generation bumped on complete):
        // replaying its arrival must be a no-op, not a double-enqueue.
        app.on_arrival(id, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.queued_total(), 0);
        assert!(q.is_empty());
        assert_eq!(app.completed(), 1);
    }

    #[test]
    fn arena_recycles_slots_at_steady_state() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        // Sequential rounds: each request completes before the next is
        // submitted, so the arena never holds more than one live slot.
        for _ in 0..20 {
            app.submit(TaskType::Sort, 1, q.now(), &mut q);
            run(&mut app, &mut cluster, &mut q, &mut rng);
        }
        assert_eq!(app.completed(), 20);
        assert_eq!(app.in_flight_len(), 0);
    }

    #[test]
    fn edge_shard_intercepts_eigen_and_cloud_shard_delivers() {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        cluster.add_node(NodeSpec::new("c1", Tier::Cloud, 0, 3000, 3072));
        let edge_dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            1,
            8,
        ));
        let cloud_dep = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Cloud, None),
            PodSpec::new(1000, 512),
            1,
            8,
        ));
        let mut rng = Pcg64::new(3, 1);

        // Edge shard: the Eigen submit crosses into the outbox — no
        // local arrival event, no local counters, inert handle.
        let mut edge = App::new_edge_shard(TaskCosts::default(), 1, edge_dep);
        let mut eq = EventQueue::new();
        let id = edge.submit(TaskType::Eigen, 1, 5 * SEC, &mut eq);
        assert!(eq.is_empty());
        assert_eq!(edge.services[0].counters.arrivals, 0);
        edge.on_arrival(id, &mut cluster, &mut eq, &mut rng); // stale-shaped: no-op
        assert_eq!(edge.queued_total(), 0);
        let fwds = edge.take_forwards();
        assert_eq!(
            fwds,
            vec![ForwardedTask {
                origin_zone: 1,
                submitted: 5 * SEC,
                priority: Priority::Standard,
            }]
        );
        assert!(edge.take_forwards().is_empty(), "outbox drains");
        // Sort still routes locally.
        edge.submit(TaskType::Sort, 1, 5 * SEC, &mut eq);
        assert_eq!(eq.len(), 1);
        assert_eq!(edge.services[0].counters.arrivals, 1);

        // Cloud shard: delivery reconstructs the request with the
        // monolith's arrival time and created stamp.
        let mut cloud = App::new_cloud_shard(TaskCosts::default(), cloud_dep);
        let mut cq = EventQueue::new();
        cluster.reconcile(cloud_dep, 1, &mut cq, &mut rng);
        run(&mut cloud, &mut cluster, &mut cq, &mut rng); // pod comes up
        cloud.deliver_forward(fwds[0], &mut cq);
        assert_eq!(cloud.services[0].counters.arrivals, 1);
        assert!(cloud.services[0].counters.net_in_bytes >= EIGEN_IN);
        let delta = TaskCosts::default().network_latency + TaskCosts::default().forward_latency;
        assert_eq!(cq.peek_time(), Some(5 * SEC + delta));
        run(&mut cloud, &mut cluster, &mut cq, &mut rng);
        assert_eq!(cloud.completed(), 1);
        // Response clock started at the edge submit time.
        assert!(cloud.stats.eigen.mean() > crate::sim::to_secs(delta));
    }

    /// Run the loop like `run` but also dispatch `RequestTimeout`.
    fn run_sla(app: &mut App, cluster: &mut Cluster, q: &mut EventQueue, rng: &mut Pcg64) {
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::RequestArrival { request_id } => {
                    app.on_arrival(request_id, cluster, q, rng)
                }
                Event::ServiceComplete { pod, request_id } => {
                    app.on_complete(pod, request_id, cluster, q, rng)
                }
                Event::RequestTimeout { request_id } => app.on_timeout(request_id, q),
                Event::PodRunning { pod } => {
                    if cluster.on_pod_running(pod) {
                        let dep = cluster.pod(pod).deployment;
                        let svc = app
                            .services
                            .iter()
                            .find(|s| s.deployment == dep)
                            .map(|s| s.id);
                        if let Some(svc) = svc {
                            app.dispatch(svc, cluster, q, rng);
                        }
                    }
                }
                Event::PodTerminated { pod } => cluster.on_pod_terminated(pod),
                _ => {}
            }
        }
    }

    fn lenient_sla() -> SlaConfig {
        SlaConfig::new(SlaPolicy {
            deadline: 60 * SEC,
            max_retries: 2,
            backoff_base: 100 * crate::sim::MS,
            shed_queue_depth: 1_000_000,
        })
    }

    #[test]
    fn absent_sla_policy_is_strict_noop() {
        // The golden no-op invariant at unit scope: a never-installed
        // policy means no timeout events, Standard priorities, and an
        // all-zero summary.
        let (mut app, mut cluster, mut q, mut rng) = world();
        assert!(!app.sla_active());
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        app.submit(TaskType::Sort, 1, 0, &mut q);
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 1);
        let s = app.sla_summary();
        assert!(s.counters.is_zero());
        assert_eq!(s.class_stats[Priority::Standard.index()].n(), 0);
    }

    #[test]
    fn fast_completion_under_sla_records_class_stats_without_violations() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        app.install_sla(&lenient_sla(), 42, 0);
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        for _ in 0..5 {
            app.submit(TaskType::Sort, 1, 0, &mut q);
        }
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.completed(), 5);
        assert_eq!(app.in_flight_len(), 0);
        let s = app.sla_summary();
        assert_eq!(s.counters.violations, 0);
        assert_eq!(s.counters.timeouts, 0, "60s deadline never expires");
        let classed: usize = s.class_stats.iter().map(|c| c.n()).sum();
        assert_eq!(classed, 5, "every completion lands in its class stream");
    }

    #[test]
    fn spent_retry_budget_counts_violation_and_drops() {
        // One pod, zero retries, deadline far below the queueing delay:
        // late requests are violation-dropped, and conservation holds
        // (completions + violations == submissions).
        let (mut app, mut cluster, mut q, mut rng) = world();
        app.install_sla(
            &SlaConfig::new(SlaPolicy {
                deadline: 700 * crate::sim::MS,
                max_retries: 0,
                backoff_base: 50 * crate::sim::MS,
                shed_queue_depth: 1_000_000,
            }),
            42,
            0,
        );
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        // Bring the pod up first so the deadline races queueing only.
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        let n = 6;
        for _ in 0..n {
            app.submit(TaskType::Sort, 1, q.now(), &mut q);
        }
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        let s = app.sla_summary();
        assert!(s.counters.violations > 0, "sequential service must violate");
        assert_eq!(s.counters.retries, 0, "no budget, no retries");
        assert_eq!(s.counters.timeouts, s.counters.violations);
        assert!(s.counters.violation_minutes >= 1);
        assert_eq!(
            app.completed() + s.counters.violations as usize,
            n,
            "no request silently lost"
        );
        assert_eq!(app.in_flight_len(), 0);
    }

    #[test]
    fn retries_rearrive_with_backoff_then_violate_when_budget_spent() {
        // Three cheap Sorts complete well inside the 2 s deadline; one
        // Eigen has no cloud pods at all, so it times out while queued,
        // burns its full retry budget (3 retries at growing backoff),
        // then counts one violation — conservation exact throughout.
        let (mut app, mut cluster, mut q, mut rng) = world();
        app.install_sla(
            &SlaConfig::new(SlaPolicy {
                deadline: 2 * SEC,
                max_retries: 3,
                backoff_base: 200 * crate::sim::MS,
                shed_queue_depth: 1_000_000,
            }),
            7,
            0,
        );
        cluster.reconcile(DeploymentId(0), 2, &mut q, &mut rng);
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        for i in 0..4 {
            let task = if i == 0 { TaskType::Eigen } else { TaskType::Sort };
            app.submit(task, 1, q.now(), &mut q);
        }
        run_sla(&mut app, &mut cluster, &mut q, &mut rng);
        let s = app.sla_summary();
        assert_eq!(app.completed(), 3, "the Sorts complete in time");
        assert_eq!(s.counters.violations, 1, "the podless Eigen violates");
        assert_eq!(s.counters.retries, 3, "full budget burned first");
        assert_eq!(
            s.counters.timeouts,
            s.counters.retries + s.counters.violations
        );
        assert_eq!(
            app.completed() + s.counters.violations as usize,
            4,
            "completions + violations balance submissions"
        );
        assert_eq!(app.in_flight_len(), 0, "no request stuck in the arena");
    }

    #[test]
    fn batch_arrivals_shed_over_queue_depth_but_critical_never() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        // all-Batch mix, shed depth 0: with anything queued, new Batch
        // arrivals are dropped at admission.
        app.install_sla(
            &SlaConfig {
                policy: SlaPolicy {
                    deadline: 60 * SEC,
                    max_retries: 1,
                    backoff_base: 100 * crate::sim::MS,
                    shed_queue_depth: 0,
                },
                mix: PriorityMix {
                    critical: 0.0,
                    standard: 0.0,
                    batch: 1.0,
                },
            },
            11,
            0,
        );
        // No pods: everything queues.
        let n = 5;
        for _ in 0..n {
            app.submit(TaskType::Sort, 1, q.now(), &mut q);
            // Process the pending arrival so the queue depth is visible
            // to the next submit's admission check.
            while let Some((_, ev)) = q.pop() {
                if let Event::RequestArrival { request_id } = ev {
                    app.on_arrival(request_id, &mut cluster, &mut q, &mut rng);
                }
            }
        }
        let s = app.sla_summary();
        assert!(s.counters.shed > 0, "deep queue must shed Batch arrivals");
        assert_eq!(app.queued_total() as u64 + s.counters.shed, n);

        // Same setup, all-Critical mix: nothing is ever shed.
        let (mut app2, mut cluster2, mut q2, mut rng2) = world();
        app2.install_sla(
            &SlaConfig {
                policy: SlaPolicy {
                    deadline: 60 * SEC,
                    max_retries: 1,
                    backoff_base: 100 * crate::sim::MS,
                    shed_queue_depth: 0,
                },
                mix: PriorityMix {
                    critical: 1.0,
                    standard: 0.0,
                    batch: 0.0,
                },
            },
            11,
            0,
        );
        for _ in 0..n {
            app2.submit(TaskType::Sort, 1, q2.now(), &mut q2);
            while let Some((_, ev)) = q2.pop() {
                if let Event::RequestArrival { request_id } = ev {
                    app2.on_arrival(request_id, &mut cluster2, &mut q2, &mut rng2);
                }
            }
        }
        assert_eq!(app2.sla_summary().counters.shed, 0, "Critical never shed");
        assert_eq!(app2.queued_total(), n as usize);
    }

    #[test]
    fn sla_runs_are_deterministic_per_seed() {
        let run_once = || {
            let (mut app, mut cluster, mut q, mut rng) = world();
            app.install_sla(
                &SlaConfig::new(SlaPolicy {
                    deadline: SEC,
                    max_retries: 2,
                    backoff_base: 100 * crate::sim::MS,
                    shed_queue_depth: 2,
                }),
                1234,
                0,
            );
            cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
            for i in 0..20 {
                let task = if i % 5 == 0 { TaskType::Eigen } else { TaskType::Sort };
                app.submit(task, 1, q.now(), &mut q);
            }
            run_sla(&mut app, &mut cluster, &mut q, &mut rng);
            let s = app.sla_summary();
            format!(
                "{}|{:?}|{}|{}|{}",
                app.stats.fingerprint(),
                s.counters,
                s.class_stats[0].fingerprint(),
                s.class_stats[1].fingerprint(),
                s.class_stats[2].fingerprint(),
            )
        };
        assert_eq!(run_once(), run_once(), "bit-identical SLA runs per seed");
    }

    #[test]
    fn streaming_stats_match_retained_log() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 2, &mut q, &mut rng);
        cluster.reconcile(DeploymentId(1), 1, &mut q, &mut rng);
        for i in 0..12 {
            let task = if i % 4 == 0 { TaskType::Eigen } else { TaskType::Sort };
            app.submit(task, 1, 0, &mut q);
        }
        run(&mut app, &mut cluster, &mut q, &mut rng);
        let sorts: Vec<f64> = log(&app)
            .iter()
            .filter(|r| r.task == TaskType::Sort)
            .map(|r| r.response_secs())
            .collect();
        let batch = crate::stats::summarize(&sorts);
        assert_eq!(app.stats.sort.n(), batch.n);
        assert!((app.stats.sort.mean() - batch.mean).abs() < 1e-9);
        assert_eq!(app.stats.sort.max(), batch.max);
        assert_eq!(app.stats.completed(), log(&app).len());
    }
}

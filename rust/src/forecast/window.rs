//! Sliding-window dataset assembly for the LSTM: turns the metrics
//! history file into `(window → next-row)` training pairs and builds the
//! flattened f32 buffers the AOT artifacts expect.

use super::Scaler;
use crate::metrics::METRIC_DIM;
use crate::util::rng::Pcg64;

/// A supervised dataset of scaled windows.
#[derive(Debug, Clone)]
pub struct WindowDataset {
    /// Flattened inputs: `n * seq_len * METRIC_DIM`.
    pub xs: Vec<f32>,
    /// Flattened targets: `n * METRIC_DIM`.
    pub ys: Vec<f32>,
    pub n: usize,
    pub seq_len: usize,
}

impl WindowDataset {
    /// Build all `(history[i-seq_len..i] → history[i])` pairs, scaled.
    pub fn build<S: Scaler + ?Sized>(
        history: &[[f64; METRIC_DIM]],
        seq_len: usize,
        scaler: &S,
    ) -> Self {
        let n = history.len().saturating_sub(seq_len);
        let mut xs = Vec::with_capacity(n * seq_len * METRIC_DIM);
        let mut ys = Vec::with_capacity(n * METRIC_DIM);
        for i in seq_len..history.len() {
            for row in &history[i - seq_len..i] {
                let t = scaler.transform(row);
                xs.extend(t.iter().map(|&v| v as f32));
            }
            let t = scaler.transform(&history[i]);
            ys.extend(t.iter().map(|&v| v as f32));
        }
        WindowDataset {
            xs,
            ys,
            n,
            seq_len,
        }
    }

    /// Assemble `k` minibatches of `batch` samples (with replacement when
    /// the dataset is smaller than a batch; shuffled otherwise) into the
    /// contiguous buffers `train_epoch` expects: `(k*batch*seq*dim)` /
    /// `(k*batch*dim)`.
    pub fn epoch_batches(
        &self,
        k: usize,
        batch: usize,
        rng: &mut Pcg64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        if self.n == 0 {
            return None;
        }
        let x_stride = self.seq_len * METRIC_DIM;
        let mut xs = Vec::with_capacity(k * batch * x_stride);
        let mut ys = Vec::with_capacity(k * batch * METRIC_DIM);

        // Shuffled index pool, refilled as needed (sampling without
        // replacement within a pass, with replacement across passes).
        let mut pool: Vec<usize> = (0..self.n).collect();
        let mut pos = pool.len(); // force shuffle on first use
        for _ in 0..k * batch {
            if pos == pool.len() {
                // Fisher–Yates.
                for i in (1..pool.len()).rev() {
                    let j = rng.below(i as u64 + 1) as usize;
                    pool.swap(i, j);
                }
                pos = 0;
            }
            let idx = pool[pos];
            pos += 1;
            xs.extend_from_slice(&self.xs[idx * x_stride..(idx + 1) * x_stride]);
            ys.extend_from_slice(&self.ys[idx * METRIC_DIM..(idx + 1) * METRIC_DIM]);
        }
        Some((xs, ys))
    }
}

/// The latest scaled window (model input for prediction), or `None` if
/// history is shorter than `seq_len`.
pub fn latest_window<S: Scaler + ?Sized>(
    history: &[[f64; METRIC_DIM]],
    seq_len: usize,
    scaler: &S,
) -> Option<Vec<f32>> {
    if history.len() < seq_len {
        return None;
    }
    let mut out = Vec::with_capacity(seq_len * METRIC_DIM);
    for row in &history[history.len() - seq_len..] {
        let t = scaler.transform(row);
        out.extend(t.iter().map(|&v| v as f32));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecast::StandardScaler;

    fn history(n: usize) -> Vec<[f64; METRIC_DIM]> {
        (0..n)
            .map(|i| {
                let x = i as f64;
                [x, x + 1.0, x + 2.0, x + 3.0, x + 4.0]
            })
            .collect()
    }

    #[test]
    fn builds_all_pairs() {
        let h = history(10);
        let ds = WindowDataset::build(&h, 3, &StandardScaler::identity());
        assert_eq!(ds.n, 7);
        assert_eq!(ds.xs.len(), 7 * 3 * METRIC_DIM);
        assert_eq!(ds.ys.len(), 7 * METRIC_DIM);
        // First pair: window rows 0..3, target row 3.
        assert_eq!(ds.xs[0], 0.0);
        assert_eq!(ds.ys[0], 3.0);
        // Last pair targets row 9.
        assert_eq!(ds.ys[(ds.n - 1) * METRIC_DIM], 9.0);
    }

    #[test]
    fn short_history_yields_empty() {
        let h = history(3);
        let ds = WindowDataset::build(&h, 8, &StandardScaler::identity());
        assert_eq!(ds.n, 0);
        let mut rng = Pcg64::new(1, 0);
        assert!(ds.epoch_batches(2, 4, &mut rng).is_none());
    }

    #[test]
    fn epoch_batches_shapes() {
        let h = history(50);
        let ds = WindowDataset::build(&h, 4, &StandardScaler::identity());
        let mut rng = Pcg64::new(1, 0);
        let (xs, ys) = ds.epoch_batches(3, 8, &mut rng).unwrap();
        assert_eq!(xs.len(), 3 * 8 * 4 * METRIC_DIM);
        assert_eq!(ys.len(), 3 * 8 * METRIC_DIM);
    }

    #[test]
    fn epoch_batches_with_replacement_small_dataset() {
        let h = history(6); // n = 2 with seq_len 4
        let ds = WindowDataset::build(&h, 4, &StandardScaler::identity());
        assert_eq!(ds.n, 2);
        let mut rng = Pcg64::new(2, 0);
        let (xs, _ys) = ds.epoch_batches(1, 8, &mut rng).unwrap();
        assert_eq!(xs.len(), 8 * 4 * METRIC_DIM);
    }

    #[test]
    fn latest_window_is_suffix() {
        let h = history(12);
        let w = latest_window(&h, 3, &StandardScaler::identity()).unwrap();
        assert_eq!(w.len(), 3 * METRIC_DIM);
        assert_eq!(w[0], 9.0); // row 9 feature 0
        assert_eq!(w[METRIC_DIM], 10.0);
        assert!(latest_window(&h[..2], 3, &StandardScaler::identity()).is_none());
    }

    #[test]
    fn scaling_applied() {
        let h = history(20);
        let scaler = StandardScaler::fit(&h);
        let ds = WindowDataset::build(&h, 2, &scaler);
        // Scaled values should be bounded (z-scores of a linear ramp).
        assert!(ds.xs.iter().all(|&v| v.abs() < 3.0));
    }
}

//! Small self-contained substrates: JSON parsing, deterministic RNG,
//! CSV output, and a derivative-free optimizer.
//!
//! These exist because the build environment resolves crates offline from
//! a fixed cache (the `xla` closure only) — no serde, no rand, no argmin.
//! Each is implemented from scratch with its own tests.

pub mod csv;
pub mod json;
pub mod nelder_mead;
pub mod rng;

/// The single sanctioned wall-clock read in the workspace.
///
/// Experiment harnesses (`main.rs`, `experiments/sweep.rs`) time
/// themselves through this helper so simulation modules stay
/// mechanically clock-free: the determinism lint (`cargo run -p
/// detlint`, rule D1) forbids `Instant`/`SystemTime` in sim code, and
/// this is the one annotated escape. The returned `Instant` must only
/// feed operator-facing reporting (`elapsed()` in run summaries) —
/// never simulation state, which advances exclusively on `sim::Time`.
// detlint: allow(D1) — harness wall-clock timing for run reports; never feeds simulation state
#[allow(clippy::disallowed_methods)]
pub fn wallclock() -> std::time::Instant {
    std::time::Instant::now()
}

/// Clamp helper for f64 that also guards NaN (returns `lo`); infinities
/// clamp to the nearest bound.
pub fn clamp_finite(x: f64, lo: f64, hi: f64) -> f64 {
    if x.is_nan() {
        return lo;
    }
    x.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_finite_basics() {
        assert_eq!(clamp_finite(0.5, 0.0, 1.0), 0.5);
        assert_eq!(clamp_finite(-2.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp_finite(7.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp_finite(f64::NAN, 0.0, 1.0), 0.0);
        assert_eq!(clamp_finite(f64::INFINITY, 0.0, 1.0), 1.0);
    }
}

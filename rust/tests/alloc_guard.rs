//! Steady-state allocation guard for the metrics hot path.
//!
//! The interned-metrics refactor promises that `MetricsPipeline::scrape`
//! performs **zero heap allocations** at steady state: series handles are
//! pre-registered (no `format!` keys), pod lists are walked in place (no
//! clone), and per-service counters are drained by `mem::take` (no Vec).
//! This binary pins that with a counting global allocator: after a short
//! warm-up, hundreds of scrape ticks must not allocate once.
//!
//! Single-test file on purpose: the allocation counter is process-global,
//! so no other test may run concurrently in this binary.

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::Hpa;
use ppa_edge::config::paper_cluster;
use ppa_edge::experiments::SimWorld;
use ppa_edge::sim::{MIN, SEC};
use ppa_edge::workload::{Generator, RandomAccessGen};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Counts every allocation (alloc/realloc/alloc_zeroed) it forwards.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_scrape_allocates_nothing() {
    // Assemble a busy Table-2 world: pods running, requests flowing.
    let cfg = paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 17);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(2)));
    for svc in 0..world.app.services.len() {
        world.add_scaler(Box::new(Hpa::with_defaults()), svc);
    }
    world.run_until(5 * MIN);

    // Warm up the scrape path (ring deques are pre-sized, interner is
    // fully populated at build; a few ticks settle any lazy OS paging).
    let mut t = 5 * MIN;
    for _ in 0..8 {
        t += 10 * SEC;
        world.metrics.scrape(t, &mut world.cluster, &mut world.app);
    }

    // Measure: 300 scrape ticks, not one allocation. 300 samples stay far
    // below the 1024-slot initial deque capacity, so ring growth cannot
    // legitimately allocate here either.
    let series_before = world.metrics.tsdb.series_count();
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..300 {
        t += 10 * SEC;
        world.metrics.scrape(t, &mut world.cluster, &mut world.app);
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        allocs, 0,
        "steady-state scrape must be allocation-free (saw {allocs} allocations \
         over 300 ticks; the legacy path did 8+ per service per tick)"
    );
    assert_eq!(
        world.metrics.tsdb.series_count(),
        series_before,
        "scrape must never intern new series"
    );
}

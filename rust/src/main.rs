//! `ppa-edge` — CLI launcher for the PPA reproduction.
//!
//! ```text
//! ppa-edge experiment <fig6|fig7|fig8|fig9-10|nasa|all> [--minutes N]
//!          [--hours H] [--pretrain-hours H] [--seed S]
//! ppa-edge run [--scaler hpa|ppa] [--model lstm|arma|naive]
//!          [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
//!          [--metric name:target[:src]]... [--behavior rules]
//!          [--minutes N] [--seed S] [--shards S] [--chaos preset]
//! ppa-edge sweep [--minutes N] [--seeds K] [--threads T]
//!          [--topology paper|city-N[xW][:classes]] [--scenarios a,b,..]
//!          [--scalers hpa,ppa-arma,..] [--core calendar|heap]
//!          [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
//!          [--metric name:target[:src]]... [--behavior rules]
//!          [--shards S] [--chaos preset] [--node-classes list]
//!          [--out FILE]
//! ppa-edge info
//! ```
//!
//! Every subcommand and flag is documented in `docs/CLI.md` (repo
//! root); `ppa-edge --help` prints the same usage text.
//!
//! (clap is unavailable in the offline crate set; argument parsing is a
//! small hand-rolled matcher.)

use anyhow::{bail, Context};
use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::{
    Autoscaler, Hpa, HpaConfig, MetricSource, MetricSpec, ScalerPolicy, ScalerRegistry,
    ScalingBehavior,
};
use ppa_edge::experiments::{
    self, fig6_trace, fig7_model_comparison, fig8_update_policies, fig9_fig10_key_metric,
    nasa_eval, run_sweep, AutoscalerKind, FigParams, ModelKind, NasaParams, SimWorld,
    SweepConfig,
};
use ppa_edge::forecast::ForecasterKind;
use ppa_edge::report;
use ppa_edge::sim::MIN;
use ppa_edge::stats::summarize;
use ppa_edge::workload::{Generator, NasaTraceConfig, RandomAccessGen};

/// Minimal flag parser: `--key value` pairs after positional args.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let value = argv
                    .get(i + 1)
                    .with_context(|| format!("flag --{key} needs a value"))?;
                flags.push((key.to_string(), value.clone()));
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Every value of a repeatable flag, in order (`--metric cpu:70
    /// --metric req_rate:150`).
    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

const USAGE: &str = "ppa-edge — Proactive Pod Autoscaler reproduction (UCC '21)

USAGE:
  ppa-edge experiment <fig6|fig7|fig8|fig9-10|nasa|all>
           [--minutes N] [--hours H] [--pretrain-hours H] [--seed S]
  ppa-edge run [--scaler hpa|ppa] [--model lstm|arma|naive]
           [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
           [--metric name:target[:current|:forecast]]...
           [--behavior rules] [--minutes N] [--seed S] [--shards S]
           [--chaos none|node-outage|flaky-pods|slow-network|full-storm]
  ppa-edge sweep [--minutes N] [--seeds K] [--threads T]
           [--topology paper|city-N[xW][:classes]] [--scenarios a,b,..]
           [--scalers hpa,ppa-arma,ppa-naive] [--core calendar|heap]
           [--forecaster naive|arma|holt-winters|tcn|lstm-rs|auto:K]
           [--metric name:target[:current|:forecast]]...
           [--behavior rules] [--shards S] [--out FILE]
           [--chaos preset] [--node-classes small,medium,large]
  ppa-edge info
  ppa-edge help | --help | -h

MULTI-METRIC SCALING:
  --metric is repeatable; each spec is name:target (metric names
  cpu|ram|net_in|net_out|req_rate, or an index 0..4) with an optional
  :current|:forecast source (default: forecast under the PPA, and the
  HPA always reads current). Per decision the max desired count across
  metrics wins (K8s HPA combine), e.g.:
    --metric cpu:70 --metric req_rate:150
  --behavior sets the shared scaling-behavior stage, a comma list of
  up-/down- rules: up-window=0s, down-window=5m, up-pods=4/15s,
  up-percent=100/15s, down-select=max|min|disabled, ... ('k8s' as the
  first entry loads the full upstream defaults, later entries override)

EXPERIMENTS (paper figures):
  fig6     scaled NASA trace generation
  fig7     ARMA vs LSTM prediction MSE
  fig8     model-update policies 1/2/3
  fig9-10  key metric: CPU vs request rate
  nasa     the 48 h HPA-vs-PPA evaluation (figs 11-14)
  all      everything above

SWEEP (scenario matrix):
  Fans a (scenario x autoscaler x seed) grid across worker threads and
  writes a JSON report. --topology selects the cluster: 'paper' (Table 2)
  or a generated city, e.g. 'city-50' (50 edge zones x 2 workers) or
  'city-50x4'. Scenarios default to the topology's preset library:
  Table-2 presets (random-access, nasa-trace, diurnal, flash-crowd,
  step-surge, multi-zone-mix) on 'paper'; N-zone composites
  (cityN-diurnal-wave, cityN-flash-mosaic, cityN-step-carpet,
  cityN-rush-hour) on 'city-N'. Autoscalers default to
  hpa,ppa-arma,ppa-naive. --core selects the DES event queue: the fast
  'calendar' bucket queue (default) or the 'heap' reference core —
  results are bit-identical either way. --shards S (run and sweep)
  switches each world onto the sharded engine: zones are split into
  per-zone event cores advancing in conservative lockstep windows
  across S worker threads, and results are bit-identical for any
  S >= 1 (0, the default, keeps the single-queue reference engine).
  City-scale example:
    ppa-edge sweep --topology city-50 --scalers hpa,ppa-arma --seeds 2 --shards 4

FORECASTER ZOO (pure-Rust model axis):
  --forecaster swaps the PPA's prediction model for a zoo member:
  naive | arma | holt-winters (additive-seasonal smoothing) | tcn
  (dilated causal conv, SPSA-fitted) | lstm-rs (pure-Rust LSTM
  inference, no PJRT) | auto:K (online champion–challenger selection
  over the first K of holt-winters, arma, naive, tcn, lstm-rs). Every
  kind is Send, so the whole axis works under --shards and across the
  sweep grid. auto:K shadow-scores every challenger each control tick
  (squared CPU forecast error, streamed), promotes a challenger only
  when it beats the champion by a 10% margin over a 30-tick window
  (hysteresis — no flapping), and reports per-service champions plus
  pooled per-model MSEs in the sweep JSON and report table. Mutually
  exclusive with --model (the paper's axis; the PJRT lstm model stays
  monolith-only — use --forecaster lstm-rs under --shards). Selection
  is deterministic: same cell seed, same champions, any shard count.
  Champion-selection sweep example:
    ppa-edge sweep --topology city-8 --forecaster auto:3 --shards 4

CHAOS (deterministic fault injection):
  --chaos picks a fault-plan preset: none (default), node-outage
  (Poisson node crashes + rejoins), flaky-pods (cold-start latency
  inflation + crash-loops), slow-network (extra edge->cloud delay on
  the Eigen forward path), full-storm (all of the above). Fault
  timings derive from the cell seed on dedicated RNG streams, so a
  faulted run is bit-reproducible across runs, --threads, and
  --shards 1|2|4|8; --chaos none is byte-identical to a build without
  the chaos plane. City workers can be heterogeneous: --node-classes
  small,large cycles hardware classes per zone worker (small =
  1 core/1 GiB, medium = Table-2 worker, large = 4 cores/4 GiB);
  equivalently suffix the topology, e.g. city-8x4:small,large.
  Faulted city sweep example:
    ppa-edge sweep --topology city-8 --node-classes small,large \\
             --chaos full-storm --seeds 2 --shards 4

Full flag reference: docs/CLI.md (including the sweep JSON schema).
Artifacts must exist for LSTM experiments: run `make artifacts`.";

/// The repeatable `--metric` flags as a spec set (None when absent).
/// `default_source` follows the scaler: forecast for the PPA, current
/// for the HPA (which reads every spec reactively anyway).
fn metric_flags(
    args: &Args,
    default_source: MetricSource,
) -> anyhow::Result<Option<Vec<MetricSpec>>> {
    let raw = args.get_all("metric");
    if raw.is_empty() {
        return Ok(None);
    }
    raw.iter()
        .map(|s| MetricSpec::parse(s, default_source))
        .collect::<anyhow::Result<Vec<_>>>()
        .map(Some)
}

/// The `--behavior` flag (None when absent); `default_down_window` seeds
/// the unset fields.
fn behavior_flag(
    args: &Args,
    default_down_window: ppa_edge::sim::Time,
) -> anyhow::Result<Option<ScalingBehavior>> {
    args.get("behavior")
        .map(|s| ScalingBehavior::parse(s, default_down_window))
        .transpose()
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    // `--help`/`-h` anywhere prints usage (before flag parsing, which
    // would otherwise demand a value for `--help`).
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.positional.first().map(String::as_str) {
        Some("experiment") => cmd_experiment(&args),
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("info") => cmd_info(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_info() -> anyhow::Result<()> {
    println!("ppa-edge {}", env!("CARGO_PKG_VERSION"));
    match ppa_edge::runtime::find_artifacts_dir() {
        Some(dir) => {
            let rt = ppa_edge::runtime::LstmRuntime::load(&dir)?;
            let m = rt.manifest();
            println!("artifacts: {}", dir.display());
            println!(
                "model: LSTM({}) in={} out={} seq_len={} batch={} params={}",
                m.hidden_dim,
                m.input_dim,
                m.output_dim,
                m.seq_len,
                m.batch,
                m.param_count()
            );
        }
        None => println!("artifacts: NOT BUILT (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let params = FigParams {
        minutes: args.get_u64("minutes", 200)?,
        pretrain_hours: args.get_f64("pretrain-hours", 10.0)?,
        seed: args.get_u64("seed", 2021)?,
    };
    let nasa_params = NasaParams {
        hours: args.get_f64("hours", 48.0)?,
        pretrain_hours: params.pretrain_hours,
        seed: params.seed,
        trace: NasaTraceConfig::default(),
    };

    let run_fig6 = || -> anyhow::Result<()> {
        let counts = fig6_trace(&NasaTraceConfig::default())?;
        let s = summarize(&counts);
        println!(
            "\n== Fig 6 — scaled NASA trace ==\n  {} minutes, mean {:.1} req/min, peak {:.0}, csv: target/experiments/fig6_nasa_trace.csv",
            counts.len(),
            s.mean,
            s.max
        );
        Ok(())
    };

    match which {
        "fig6" => run_fig6()?,
        "fig7" => report::print_fig7(&fig7_model_comparison(&params)?),
        "fig8" => report::print_fig8(&fig8_update_policies(&params)?),
        "fig9-10" | "fig9" | "fig10" => {
            report::print_fig9_10(&fig9_fig10_key_metric(&params)?)
        }
        "nasa" | "fig11" | "fig12" | "fig13" | "fig14" => {
            report::print_nasa_eval(&nasa_eval(&nasa_params)?)
        }
        "all" => {
            run_fig6()?;
            report::print_fig7(&fig7_model_comparison(&params)?);
            report::print_fig8(&fig8_update_policies(&params)?);
            report::print_fig9_10(&fig9_fig10_key_metric(&params)?);
            report::print_nasa_eval(&nasa_eval(&nasa_params)?);
        }
        other => bail!("unknown experiment '{other}'\n\n{USAGE}"),
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let minutes = args.get_u64("minutes", 30)?;
    let n_seeds = args.get_u64("seeds", 4)?;
    let threads = args.get_u64("threads", 0)? as usize;
    let out = args.get("out").unwrap_or("target/experiments/sweep.json");
    let mut topology =
        ppa_edge::config::Topology::parse(args.get("topology").unwrap_or("paper"))?;
    // `--node-classes small,large` is sugar for the `city-NxW:small,large`
    // topology suffix; it overrides any suffix already present.
    if let Some(list) = args.get("node-classes") {
        let parsed = ppa_edge::config::ClassMix::parse(list)?;
        match &mut topology {
            ppa_edge::config::Topology::EdgeCity { mix, .. } => *mix = parsed,
            _ => bail!("--node-classes needs a city topology (e.g. --topology city-8x4)"),
        }
    }
    let core = ppa_edge::sim::CoreKind::parse(args.get("core").unwrap_or("calendar"))?;
    let shards = args.get_u64("shards", 0)? as usize;
    let chaos = ppa_edge::config::chaos_preset(args.get("chaos").unwrap_or("none"))?;

    // The preset library follows the topology: Table-2 scenarios on
    // `paper`, generated N-zone `cityN-*` composites on `city-N[xW]`.
    let presets = topology.scenario_presets();
    let scenarios = match args.get("scenarios") {
        None => presets,
        Some(list) => {
            let names: Vec<String> = presets.iter().map(|(n, _)| n.clone()).collect();
            let mut picked = Vec::new();
            for name in list.split(',') {
                let name = name.trim();
                let found = presets
                    .iter()
                    .find(|(n, _)| n == name)
                    .with_context(|| {
                        format!("unknown scenario '{name}' (available: {})", names.join(", "))
                    })?;
                picked.push(found.clone());
            }
            picked
        }
    };
    // `--forecaster` swaps every PPA cell's model for a zoo member
    // (both PPA kinds honour it; the HPA ignores it). With the flag set
    // and no explicit `--scalers`, the grid drops to hpa + ppa-arma —
    // the two PPA kinds would otherwise run identical cells.
    let forecaster = args.get("forecaster").map(ForecasterKind::parse).transpose()?;
    let scalers = match args.get("scalers") {
        None if forecaster.is_some() => vec![AutoscalerKind::Hpa, AutoscalerKind::PpaArma],
        None => vec![
            AutoscalerKind::Hpa,
            AutoscalerKind::PpaArma,
            AutoscalerKind::PpaNaive,
        ],
        Some(list) => list
            .split(',')
            .map(|s| AutoscalerKind::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    // `--metric`/`--behavior`/`--forecaster` build a uniform fleet
    // policy for every service of every cell (heterogeneous registries
    // are API-level: see `ScalerRegistry::with_policy`). Unset
    // `--behavior` fields default to the stock K8s values (5-min down
    // window) so an up-rule-only flag cannot silently weaken the HPA
    // baseline's stabilization; without the flag each scaler kind keeps
    // its own default (HPA 5 min, PPA 2 min).
    let specs = metric_flags(args, MetricSource::Forecast)?;
    let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
    let fleet = if specs.is_some() || behavior.is_some() || forecaster.is_some() {
        Some(ScalerRegistry::uniform(ScalerPolicy {
            specs: specs.unwrap_or_else(|| ScalerPolicy::default().specs),
            behavior,
            forecaster,
        }))
    } else {
        None
    };

    let cfg = SweepConfig {
        topology,
        scenarios,
        scalers,
        seeds: (0..n_seeds).map(|i| 1000 + i).collect(),
        minutes,
        threads,
        core,
        fleet,
        shards,
        chaos,
    };

    println!(
        "sweeping {} scenarios x {} autoscalers x {} seeds on topology {}, \
         {} sim-minutes per cell (chaos: {})...",
        cfg.scenarios.len(),
        cfg.scalers.len(),
        cfg.seeds.len(),
        cfg.topology.label(),
        minutes,
        cfg.chaos.label()
    );
    let result = run_sweep(&cfg)?;
    report::print_sweep(&result);
    result.write_json(std::path::Path::new(out))?;
    println!("json report: {out}");
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let minutes = args.get_u64("minutes", 30)?;
    let seed = args.get_u64("seed", 7)?;
    let scaler = args.get("scaler").unwrap_or("ppa");
    // Default to ARMA: it works in every build. LSTM additionally needs
    // the `pjrt` cargo feature and `make artifacts`.
    let model = ModelKind::parse(args.get("model").unwrap_or("arma"))?;
    // `--forecaster` (the pure-Rust zoo axis) replaces `--model` (the
    // paper's axis) wholesale — the two would pick the PPA model twice.
    let forecaster = args.get("forecaster").map(ForecasterKind::parse).transpose()?;
    if forecaster.is_some() {
        if args.get("model").is_some() {
            bail!(
                "--forecaster and --model are mutually exclusive: --model picks the \
                 paper's lstm|arma|naive stack, --forecaster a pure-Rust zoo member"
            );
        }
        if scaler != "ppa" {
            bail!("--forecaster needs --scaler ppa (the HPA runs no prediction model)");
        }
    }
    let shards = args.get_u64("shards", 0)? as usize;
    let chaos = ppa_edge::config::chaos_preset(args.get("chaos").unwrap_or("none"))?;
    if shards >= 1 {
        return cmd_run_sharded(args, minutes, seed, scaler, model, forecaster, shards, &chaos);
    }

    let cfg = ppa_edge::config::paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), seed);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(2)));
    let n_services = world.app.services.len();

    match scaler {
        "hpa" => {
            let specs = metric_flags(args, MetricSource::Current)?;
            let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
            for svc in 0..n_services {
                let mut cfg = HpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                world.add_scaler(Box::new(Hpa::new(cfg)), svc);
            }
        }
        "ppa" if forecaster.is_some() => {
            // Zoo models train online from the live history file (the
            // update loop fits them mid-run) — no pretraining pass.
            let kind = forecaster.unwrap_or(ForecasterKind::Naive);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            for svc in 0..n_services {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                let ppa = ppa_edge::autoscaler::Ppa::new(cfg, kind.build(seed));
                world.add_scaler(Box::new(ppa), svc);
            }
        }
        "ppa" => {
            let runtime = if model == ModelKind::Lstm {
                Some(experiments::try_runtime().context(
                    "LSTM needs the PJRT runtime: add the `xla` dependency, \
                     build with `--features pjrt`, and run `make artifacts` \
                     (see rust/Cargo.toml). arma/naive models need neither.",
                )?)
            } else {
                None
            };
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            println!("collecting pretraining data (1 h sim)...");
            let (hist, _) = experiments::pretrain_histories(1.0, 20, seed);
            for svc in 0..n_services {
                let pre = if svc + 1 == n_services {
                    hist.last().unwrap()
                } else {
                    &hist[0]
                };
                let forecaster =
                    experiments::make_forecaster(model, runtime.as_ref(), pre, seed as u32)?;
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                let ppa = ppa_edge::autoscaler::Ppa::new(cfg, forecaster);
                world.add_scaler(Box::new(ppa), svc);
            }
        }
        other => bail!("unknown scaler '{other}' (hpa|ppa)"),
    }

    world.install_chaos(&chaos, seed, minutes * MIN);
    let model_label = match forecaster {
        Some(kind) => kind.name(),
        None => model.name().to_string(),
    };
    println!(
        "running {minutes} simulated minutes with {scaler} ({model_label}), chaos: {}...",
        chaos.label()
    );
    let wall = ppa_edge::util::wallclock();
    let events = world.run_until(minutes * MIN);
    let elapsed = wall.elapsed();

    // Response stats stream in constant memory (Welford moments +
    // log-histogram percentiles) — no per-request log is retained.
    let stats = &world.app.stats;
    let sort = stats.sort.summary();
    let eigen = stats.eigen.summary();
    let rirs: Vec<f64> = world.rir_log.iter().map(|s| s.rir).collect();
    let rir = summarize(&rirs);
    println!(
        "done: {events} events in {:.2}s ({:.0}x real time)",
        elapsed.as_secs_f64(),
        minutes as f64 * 60.0 / elapsed.as_secs_f64()
    );
    println!(
        "  sort  resp: {:.4} ± {:.4} s (n={}, p95 ≈ {:.4})",
        sort.mean,
        sort.std,
        sort.n,
        stats.sort.quantile(95.0)
    );
    println!(
        "  eigen resp: {:.3} ± {:.3} s (n={}, p95 ≈ {:.3})",
        eigen.mean,
        eigen.std,
        eigen.n,
        stats.eigen.quantile(95.0)
    );
    println!("  RIR: {:.3} ± {:.3}", rir.mean, rir.std);
    for (svc, binding) in world.scalers.iter().enumerate() {
        let ppa = binding.autoscaler.as_any().downcast_ref::<ppa_edge::autoscaler::Ppa>();
        if let Some(selection) = ppa.and_then(|p| p.selection()) {
            print_selection(svc, &selection);
        }
    }
    if !chaos.is_empty() {
        print_chaos_summary(&world.chaos_summary(minutes * MIN));
    }
    Ok(())
}

/// One-line champion–challenger tally for one selecting service.
fn print_selection(svc: usize, s: &ppa_edge::forecast::SelectionSummary) {
    let scores: Vec<String> = s
        .models
        .iter()
        .map(|m| match m.mse {
            Some(mse) => format!("{} {mse:.3}", m.name),
            None => format!("{} -", m.name),
        })
        .collect();
    println!(
        "  service {svc} champion: {} ({} promotion(s); shadow MSE: {})",
        s.champion,
        s.promotions.len(),
        scores.join(", ")
    );
}

/// One-line fault tally for faulted runs (both engines).
fn print_chaos_summary(c: &ppa_edge::cluster::ChaosCounters) {
    println!(
        "  faults: {} crashes / {} rejoins, {} pods killed, {} rescheduled, \
         {} crash-loops, {:.1}s downtime",
        c.crashes,
        c.rejoins,
        c.pods_killed,
        c.pods_rescheduled,
        c.crash_loops,
        ppa_edge::sim::to_secs(c.downtime)
    );
}

/// `run --shards S`: the same paper-topology run on the sharded engine
/// (one event core per zone, conservative lockstep windows). Results
/// are bit-identical for any `S >= 1` but intentionally *not* to the
/// monolith engine (different RNG stream layout — see `sim::shard`).
#[allow(clippy::too_many_arguments)]
fn cmd_run_sharded(
    args: &Args,
    minutes: u64,
    seed: u64,
    scaler: &str,
    model: ModelKind,
    forecaster: Option<ForecasterKind>,
    shards: usize,
    chaos: &ppa_edge::cluster::FaultPlan,
) -> anyhow::Result<()> {
    use ppa_edge::sim::{run_sharded, ShardSpec};

    let cfg = ppa_edge::config::paper_cluster();
    let generators = vec![
        Generator::RandomAccess(RandomAccessGen::new(1)),
        Generator::RandomAccess(RandomAccessGen::new(2)),
    ];
    // World order == service order: edge zones in config order, then the
    // cloud pool; the scaler factory sees the global world index.
    let n_services = cfg.deployments.len();
    let spec = ShardSpec {
        shards,
        core: ppa_edge::sim::CoreKind::parse(args.get("core").unwrap_or("calendar"))?,
        seed,
        costs: TaskCosts::default(),
        end: minutes * MIN,
        record_decisions: false,
        chaos: *chaos,
    };

    let model_label = match forecaster {
        Some(kind) => kind.name(),
        None => model.name().to_string(),
    };
    println!(
        "running {minutes} simulated minutes with {scaler} ({model_label}) on {shards} \
         shard(s), chaos: {}...",
        chaos.label()
    );
    let wall = ppa_edge::util::wallclock();
    let run = match scaler {
        "hpa" => {
            let specs = metric_flags(args, MetricSource::Current)?;
            let behavior = behavior_flag(args, 5 * ppa_edge::sim::MIN)?;
            let factory = |_svc: usize| -> Box<dyn Autoscaler> {
                let mut cfg = HpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(Hpa::new(cfg))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        "ppa" if forecaster.is_some() => {
            // The whole zoo axis is `Send`, so learned models (tcn,
            // lstm-rs, auto:K) build directly on the worker threads —
            // `ForecasterKind::build` is pure, so every shard layout
            // gets a bit-identical model.
            let kind = forecaster.unwrap_or(ForecasterKind::Naive);
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            let factory = |_svc: usize| -> Box<dyn Autoscaler> {
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(ppa_edge::autoscaler::Ppa::new(cfg, kind.build(seed)))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        "ppa" => {
            if model == ModelKind::Lstm {
                bail!(
                    "--shards does not support --model lstm: the PJRT runtime is \
                     shared single-threaded state; use --forecaster lstm-rs (the \
                     pure-Rust LSTM), --model arma|naive, or drop --shards"
                );
            }
            let specs = metric_flags(args, MetricSource::Forecast)?;
            let behavior = behavior_flag(args, 2 * ppa_edge::sim::MIN)?;
            println!("collecting pretraining data (1 h sim)...");
            let (hist, _) = experiments::pretrain_histories(1.0, 20, seed);
            // Fail fast on a bad seed model here, on the main thread —
            // the per-world factory below can then only repeat a fit
            // that already succeeded.
            experiments::make_forecaster(model, None, &hist[0], seed as u32)
                .context("fitting the edge seed model")?;
            experiments::make_forecaster(model, None, hist.last().unwrap(), seed as u32)
                .context("fitting the cloud seed model")?;
            let factory = |svc: usize| -> Box<dyn Autoscaler> {
                let pre = if svc + 1 == n_services {
                    hist.last().unwrap()
                } else {
                    &hist[0]
                };
                let forecaster = experiments::make_forecaster(model, None, pre, seed as u32)
                    .expect("seed-model fit succeeded in the up-front check");
                let mut cfg = ppa_edge::autoscaler::PpaConfig::default();
                if let Some(specs) = &specs {
                    cfg.specs = specs.clone();
                }
                if let Some(behavior) = behavior {
                    cfg.behavior = behavior;
                }
                Box::new(ppa_edge::autoscaler::Ppa::new(cfg, forecaster))
            };
            run_sharded(&cfg, generators, &factory, &spec)?
        }
        other => bail!("unknown scaler '{other}' (hpa|ppa)"),
    };
    let elapsed = wall.elapsed();

    let sort_stats = run.sort_stats();
    let eigen_stats = run.eigen_stats();
    let sort = sort_stats.summary();
    let eigen = eigen_stats.summary();
    let rirs: Vec<f64> = run.rir_log().iter().map(|s| s.rir).collect();
    let rir = summarize(&rirs);
    println!(
        "done: {} events in {:.2}s ({:.0}x real time)",
        run.events(),
        elapsed.as_secs_f64(),
        minutes as f64 * 60.0 / elapsed.as_secs_f64()
    );
    println!(
        "  sort  resp: {:.4} ± {:.4} s (n={}, p95 ≈ {:.4})",
        sort.mean,
        sort.std,
        sort.n,
        sort_stats.quantile(95.0)
    );
    println!(
        "  eigen resp: {:.3} ± {:.3} s (n={}, p95 ≈ {:.3})",
        eigen.mean,
        eigen.std,
        eigen.n,
        eigen_stats.quantile(95.0)
    );
    println!("  RIR: {:.3} ± {:.3}", rir.mean, rir.std);
    for outcome in &run.outcomes {
        if let Some(selection) = &outcome.selection {
            print_selection(outcome.world, selection);
        }
    }
    if !chaos.is_empty() {
        print_chaos_summary(&run.chaos_counters());
    }
    println!("  fingerprint: identical for any --shards >= 1 at this seed");
    Ok(())
}

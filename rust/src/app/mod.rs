//! The example edge application (paper §5.1): a two-tier CPU-intensive
//! service.
//!
//! Requests arrive at their origin edge zone's entrypoint. *Sort* tasks
//! (cheap, `n log n`) are handled by that zone's edge worker pool; *Eigen*
//! tasks (expensive, `n³`) are forwarded to the cloud worker pool. Each
//! worker pool is one autoscaled deployment plus a shared FIFO task queue
//! (the Celery broker); worker pods are single-slot (Celery concurrency 1).

mod request;

pub use request::{Request, ResponseRecord, TaskType};

use crate::cluster::{Cluster, PodPhase};
use crate::sim::{Event, EventQueue, PodId, ServiceId, Time, MS};
use crate::util::rng::Pcg64;
use std::collections::{HashMap, VecDeque};

/// Calibrated task costs. The paper gives complexities (Sort: 1e4 ops,
/// Eigen: 1e9 ops) and measures ~0.5 s / ~13.6 s end-to-end responses on
/// its Celery workers; we express costs in core-seconds so the same task
/// takes proportionally longer on smaller pods (DESIGN.md §Substitutions).
#[derive(Debug, Clone, Copy)]
pub struct TaskCosts {
    /// Core-seconds for a Sort task (3000-element array).
    pub sort_core_secs: f64,
    /// Core-seconds for an Eigen task (1000x1000 matrix).
    pub eigen_core_secs: f64,
    /// Per-request dispatch overhead **executed on the worker pod**
    /// (broker fetch + deserialization + result publish) — occupies the
    /// pod and burns its CPU, like a Celery worker.
    pub overhead: Time,
    /// Client→entrypoint network latency (not pod time).
    pub network_latency: Time,
    /// Extra one-way latency for edge→cloud forwarding of Eigen tasks.
    pub forward_latency: Time,
    /// Multiplicative service-time jitter std (lognormal-ish via normal).
    pub jitter_std: f64,
    /// Fraction of a pod's CPU request burned continuously while Running
    /// (interpreter, broker polling, exporter sidecar) — this is what
    /// keeps real Celery pods from ever reading 0% CPU and is included in
    /// the utilization metric the autoscalers see.
    pub base_burn_frac: f64,
}

impl Default for TaskCosts {
    /// Calibrated against the paper's measured scales (see
    /// `examples/calibrate.rs` and DESIGN.md §Substitutions): Sort
    /// ≈ 0.59 s and Eigen ≈ 14 s mean response under HPA on the Table-2
    /// cluster.
    fn default() -> Self {
        TaskCosts {
            // Chosen so the NASA peak keeps the cloud Eigen pool at
            // ~60-70% of its 4-pod capacity: the paper scaled its
            // workload "so that the peak ... does not exceed resource
            // limitations" (§5.2.2) — under saturation the CPU metric
            // clips at 100%/pod and no CPU-keyed autoscaler can see
            // residual demand.
            sort_core_secs: 0.12,
            eigen_core_secs: 5.5,
            overhead: 250 * MS,
            network_latency: 20 * MS,
            forward_latency: 40 * MS,
            jitter_std: 0.05,
            // Must stay below threshold/(2*100) = 0.35 of the 70% Eq-1
            // target: at 0.5 the idle-pod CPU sum alone makes k=3 an
            // absorbing replica state (ceil(50k/70) == k) and both
            // autoscalers get pinned high.
            base_burn_frac: 0.30,
        }
    }
}

/// Per-service (per worker pool) traffic counters, drained at each scrape.
#[derive(Debug, Default, Clone, Copy)]
pub struct TrafficCounters {
    pub arrivals: u64,
    pub net_in_bytes: u64,
    pub net_out_bytes: u64,
}

/// One worker pool: an autoscaled deployment + its shared FIFO queue.
#[derive(Debug)]
pub struct Service {
    pub id: ServiceId,
    pub name: String,
    pub deployment: crate::cluster::DeploymentId,
    pub queue: VecDeque<u64>,
    pub counters: TrafficCounters,
}

/// Request payload sizes for network metrics (bytes).
const SORT_IN: u64 = 24_000; // 3000 x i64
const SORT_OUT: u64 = 24_000;
const EIGEN_IN: u64 = 8_000_000; // 1000x1000 f64
const EIGEN_OUT: u64 = 16_000;

/// The application: services, in-flight requests, response log.
#[derive(Debug)]
pub struct App {
    pub services: Vec<Service>,
    pub costs: TaskCosts,
    /// zone index -> edge service handling that zone's Sort tasks.
    edge_service_by_zone: HashMap<u32, ServiceId>,
    cloud_service: ServiceId,
    in_flight: HashMap<u64, Request>,
    next_id: u64,
    /// Completed-request log (the experiments' response-time source).
    pub responses: Vec<ResponseRecord>,
}

impl App {
    /// Build the app over deployments already registered in the cluster.
    /// `edge` maps zone -> deployment; `cloud` is the Eigen pool.
    pub fn new(
        costs: TaskCosts,
        edge: &[(u32, crate::cluster::DeploymentId)],
        cloud: crate::cluster::DeploymentId,
    ) -> Self {
        let mut services = Vec::new();
        let mut edge_service_by_zone = HashMap::new();
        for &(zone, dep) in edge {
            let id = ServiceId(services.len() as u32);
            services.push(Service {
                id,
                name: format!("edge-workers-z{zone}"),
                deployment: dep,
                queue: VecDeque::new(),
                counters: TrafficCounters::default(),
            });
            edge_service_by_zone.insert(zone, id);
        }
        let cloud_service = ServiceId(services.len() as u32);
        services.push(Service {
            id: cloud_service,
            name: "cloud-workers".to_string(),
            deployment: cloud,
            queue: VecDeque::new(),
            counters: TrafficCounters::default(),
        });
        App {
            services,
            costs,
            edge_service_by_zone,
            cloud_service,
            in_flight: HashMap::new(),
            next_id: 0,
            responses: Vec::new(),
        }
    }

    pub fn service(&self, id: ServiceId) -> &Service {
        &self.services[id.0 as usize]
    }

    /// Total queue depth across services (back-pressure indicator).
    pub fn queued_total(&self) -> usize {
        self.services.iter().map(|s| s.queue.len()).sum()
    }

    /// A client submits a task from `zone` at `now`. Routes per the paper:
    /// Sort → that zone's edge pool; Eigen → the cloud pool (with forward
    /// latency). Returns the request id.
    pub fn submit(
        &mut self,
        task: TaskType,
        zone: u32,
        now: Time,
        queue: &mut EventQueue,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let (service, latency, bytes_in) = match task {
            TaskType::Sort => {
                let svc = *self
                    .edge_service_by_zone
                    .get(&zone)
                    .expect("unknown origin zone");
                (svc, self.costs.network_latency, SORT_IN)
            }
            TaskType::Eigen => (
                self.cloud_service,
                self.costs.network_latency + self.costs.forward_latency,
                EIGEN_IN,
            ),
        };
        self.in_flight.insert(
            id,
            Request {
                id,
                task,
                origin_zone: zone,
                service,
                created: now,
            },
        );
        self.services[service.0 as usize].counters.arrivals += 1;
        self.services[service.0 as usize].counters.net_in_bytes += bytes_in;
        queue.schedule_in(latency, Event::RequestArrival { request_id: id });
        id
    }

    /// `RequestArrival` handler: enqueue at the service and try dispatch.
    pub fn on_arrival(
        &mut self,
        request_id: u64,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let service = match self.in_flight.get(&request_id) {
            Some(r) => r.service,
            None => return, // cancelled
        };
        self.services[service.0 as usize].queue.push_back(request_id);
        self.dispatch(service, cluster, queue, rng);
    }

    /// Pull queued work onto idle running pods of the service's deployment.
    pub fn dispatch(
        &mut self,
        service: ServiceId,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let dep = self.services[service.0 as usize].deployment;
        loop {
            if self.services[service.0 as usize].queue.is_empty() {
                return;
            }
            // Deterministic idle-pod choice: lowest pod id.
            let idle: Option<PodId> = {
                let mut ids: Vec<PodId> = cluster
                    .running_pods(dep)
                    .filter(|p| p.current_request.is_none())
                    .map(|p| p.id)
                    .collect();
                ids.sort();
                ids.first().copied()
            };
            let Some(pid) = idle else { return };
            let req_id = self.services[service.0 as usize]
                .queue
                .pop_front()
                .unwrap();
            let task = self.in_flight[&req_id].task;
            let pod = cluster.pod_mut(pid);
            pod.start_service(req_id, queue.now());
            let service_time = self.service_time(task, pod.spec.cpu_millis, rng);
            queue.schedule_in(
                service_time,
                Event::ServiceComplete {
                    pod: pid,
                    request_id: req_id,
                },
            );
        }
    }

    /// Pod occupancy of `task` on a pod with `cpu_millis` CPU: dispatch
    /// overhead (on-pod) plus compute scaled by the pod's CPU share.
    fn service_time(&self, task: TaskType, cpu_millis: u32, rng: &mut Pcg64) -> Time {
        let core_secs = match task {
            TaskType::Sort => self.costs.sort_core_secs,
            TaskType::Eigen => self.costs.eigen_core_secs,
        };
        let cores = cpu_millis as f64 / 1000.0;
        let jitter = (1.0 + self.costs.jitter_std * rng.normal()).max(0.5);
        self.costs.overhead + crate::sim::from_secs(core_secs / cores * jitter)
    }

    /// `ServiceComplete` handler: record the response, free (or drain) the
    /// pod, and keep the queue moving.
    pub fn on_complete(
        &mut self,
        pid: PodId,
        request_id: u64,
        cluster: &mut Cluster,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) {
        let now = queue.now();
        let pod = cluster.pod_mut(pid);
        let finished = pod.finish_service(now);
        debug_assert_eq!(finished, Some(request_id));
        let draining = pod.phase == PodPhase::Terminating;
        if draining {
            queue.schedule_in(
                crate::cluster::TERMINATION_GRACE,
                Event::PodTerminated { pod: pid },
            );
        }

        if let Some(req) = self.in_flight.remove(&request_id) {
            let out = match req.task {
                TaskType::Sort => SORT_OUT,
                TaskType::Eigen => EIGEN_OUT,
            };
            self.services[req.service.0 as usize].counters.net_out_bytes += out;
            self.responses.push(ResponseRecord {
                task: req.task,
                origin_zone: req.origin_zone,
                created: req.created,
                completed: now,
            });
            // Keep the queue moving — even when this pod is draining,
            // another pod may be idle.
            self.dispatch(req.service, cluster, queue, rng);
        }
    }

    /// Drain traffic counters for a scrape (returns per-service snapshot).
    pub fn take_counters(&mut self) -> Vec<TrafficCounters> {
        self.services
            .iter_mut()
            .map(|s| std::mem::take(&mut s.counters))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, DeploymentId, NodeSpec, PodSpec, Selector, Tier};
    use crate::sim::SEC;

    fn world() -> (App, Cluster, EventQueue, Pcg64) {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        cluster.add_node(NodeSpec::new("c1", Tier::Cloud, 0, 3000, 3072));
        let edge_dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            1,
            8,
        ));
        let cloud_dep = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Cloud, None),
            PodSpec::new(1000, 512),
            1,
            8,
        ));
        let app = App::new(TaskCosts::default(), &[(1, edge_dep)], cloud_dep);
        (app, cluster, EventQueue::new(), Pcg64::new(42, 7))
    }

    /// Run the event loop to exhaustion, handling app/cluster events.
    fn run(app: &mut App, cluster: &mut Cluster, q: &mut EventQueue, rng: &mut Pcg64) {
        while let Some((_, ev)) = q.pop() {
            match ev {
                Event::RequestArrival { request_id } => {
                    app.on_arrival(request_id, cluster, q, rng)
                }
                Event::ServiceComplete { pod, request_id } => {
                    app.on_complete(pod, request_id, cluster, q, rng)
                }
                Event::PodRunning { pod } => {
                    if cluster.on_pod_running(pod) {
                        // A fresh pod may unblock a queue.
                        let dep = cluster.pod(pod).deployment;
                        let svc = app
                            .services
                            .iter()
                            .find(|s| s.deployment == dep)
                            .map(|s| s.id);
                        if let Some(svc) = svc {
                            app.dispatch(svc, cluster, q, rng);
                        }
                    }
                }
                Event::PodTerminated { pod } => cluster.on_pod_terminated(pod),
                _ => {}
            }
        }
    }

    #[test]
    fn sort_request_completes_with_expected_latency() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        cluster.reconcile(DeploymentId(1), 1, &mut q, &mut rng);
        app.submit(TaskType::Sort, 1, 0, &mut q);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.responses.len(), 1);
        let r = &app.responses[0];
        // 0.2 core-sec on 500m = 0.4 s (+80 ms overhead + init wait).
        let resp = r.response_secs();
        assert!(resp > 0.4 && resp < 15.0, "resp={resp}");
        assert_eq!(r.task, TaskType::Sort);
    }

    #[test]
    fn eigen_routes_to_cloud() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        cluster.reconcile(DeploymentId(1), 1, &mut q, &mut rng);
        app.submit(TaskType::Eigen, 1, 0, &mut q);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.responses.len(), 1);
        // 5.5 core-sec on 1000m ≈ 5.5 s service.
        let resp = app.responses[0].response_secs();
        assert!(resp > 5.0, "resp={resp}");
        // Cloud service counted the arrival.
        assert_eq!(app.services[1].counters.arrivals, 1);
        assert!(app.services[1].counters.net_in_bytes >= EIGEN_IN);
    }

    #[test]
    fn fifo_queueing_when_single_pod() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        for _ in 0..3 {
            app.submit(TaskType::Sort, 1, 0, &mut q);
        }
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.responses.len(), 3);
        // Sequential service: responses strictly increasing.
        let times: Vec<f64> = app.responses.iter().map(|r| r.response_secs()).collect();
        assert!(times.windows(2).all(|w| w[1] > w[0]), "{times:?}");
    }

    #[test]
    fn more_replicas_cut_queueing() {
        let measure = |replicas: usize| {
            let (mut app, mut cluster, mut q, mut rng) = world();
            cluster.reconcile(DeploymentId(0), replicas, &mut q, &mut rng);
            // Let pods come up first.
            run(&mut app, &mut cluster, &mut q, &mut rng);
            for _ in 0..6 {
                app.submit(TaskType::Sort, 1, q.now(), &mut q);
            }
            run(&mut app, &mut cluster, &mut q, &mut rng);
            let mean: f64 = app
                .responses
                .iter()
                .map(|r| r.response_secs())
                .sum::<f64>()
                / app.responses.len() as f64;
            mean
        };
        let slow = measure(1);
        let fast = measure(3);
        assert!(
            fast < slow * 0.7,
            "3 replicas should be much faster: {fast} vs {slow}"
        );
    }

    #[test]
    fn draining_pod_finishes_then_terminates() {
        let (mut app, mut cluster, mut q, mut rng) = world();
        cluster.reconcile(DeploymentId(0), 1, &mut q, &mut rng);
        // Bring pod up.
        while let Some((_, ev)) = q.pop() {
            if let Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
                break;
            }
        }
        app.submit(TaskType::Sort, 1, q.now(), &mut q);
        // Arrival event then dispatch.
        if let Some((_, Event::RequestArrival { request_id })) = q.pop() {
            app.on_arrival(request_id, &mut cluster, &mut q, &mut rng);
        }
        // Scale to zero while busy (min_replicas=1 clamps to 1... use 0-min dep)
        cluster.deployments[0].min_replicas = 0;
        cluster.reconcile(DeploymentId(0), 0, &mut q, &mut rng);
        assert_eq!(cluster.count_phase(DeploymentId(0), PodPhase::Terminating), 1);
        run(&mut app, &mut cluster, &mut q, &mut rng);
        assert_eq!(app.responses.len(), 1, "in-flight request must finish");
        assert_eq!(cluster.live_replicas(DeploymentId(0)), 0);
    }

    #[test]
    fn counters_drain_on_take() {
        let (mut app, _cluster, mut q, _rng) = world();
        app.submit(TaskType::Sort, 1, 0, &mut q);
        app.submit(TaskType::Sort, 1, 0, &mut q);
        let snap = app.take_counters();
        assert_eq!(snap[0].arrivals, 2);
        let snap2 = app.take_counters();
        assert_eq!(snap2[0].arrivals, 0);
    }

    #[test]
    fn service_time_scales_with_cpu() {
        let (app, _c, _q, mut rng) = world();
        // Compute portion scales ~4x between 500m and 2000m; the fixed
        // dispatch overhead does not.
        let ovh = app.costs.overhead;
        let t_small = app.service_time(TaskType::Sort, 500, &mut rng) - ovh;
        let t_big = app.service_time(TaskType::Sort, 2000, &mut rng) - ovh;
        assert!(
            t_small > 3 * t_big,
            "compute on 500m should be ~4x slower than 2000m: {t_small} vs {t_big}"
        );
        let _ = SEC;
    }
}

"""AOT pipeline: artifacts lower, parse as HLO text, and carry sane shapes."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built():
    return aot.build_artifacts()


def test_all_artifacts_emitted(built):
    artifacts, manifest = built
    assert set(artifacts) == {
        "lstm_init.hlo.txt",
        "lstm_predict.hlo.txt",
        "lstm_train_step.hlo.txt",
        "lstm_train_epoch.hlo.txt",
    }
    assert manifest["artifacts"] == sorted(artifacts)


def test_hlo_text_not_proto(built):
    artifacts, _ = built
    for name, text in artifacts.items():
        # HLO text starts with an HloModule header — never raw proto bytes.
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_shapes(built):
    _, manifest = built
    assert manifest["input_dim"] == model.INPUT_DIM
    assert manifest["hidden_dim"] == model.HIDDEN_DIM == 50
    assert manifest["output_dim"] == model.OUTPUT_DIM == 5
    assert manifest["param_shapes"]["w"] == [
        model.INPUT_DIM + model.HIDDEN_DIM,
        4 * model.HIDDEN_DIM,
    ]
    assert manifest["adam"]["lr"] == model.ADAM_LR


def test_predict_entry_signature_in_hlo(built):
    artifacts, _ = built
    text = artifacts["lstm_predict.hlo.txt"]
    # 5 params: w, b, wd, bd, x — the rust runtime feeds them positionally.
    assert f"f32[1,{model.SEQ_LEN},{model.INPUT_DIM}]" in text
    assert f"f32[{model.INPUT_DIM + model.HIDDEN_DIM},{4 * model.HIDDEN_DIM}]" in text


def test_manifest_roundtrips_json(built):
    _, manifest = built
    assert json.loads(json.dumps(manifest)) == manifest

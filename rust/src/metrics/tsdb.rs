//! Ring-buffer time-series store (the Prometheus TSDB stand-in).
//!
//! Series are *interned*: every series is registered once (allocating its
//! name and a [`SeriesId`]) and thereafter addressed by the copyable id —
//! the scrape→query hot path never touches a string or a hash map. The
//! string-keyed API ([`Tsdb::insert`], [`Tsdb::series`], [`Tsdb::range`])
//! is kept as a debug/report convenience and resolves through the
//! interner, so even the legacy path allocates only on the first sighting
//! of a name.

use crate::sim::Time;
// The name index below is lookup-only (never iterated), so HashMap's
// nondeterministic order can't leak into simulation state — see
// clippy.toml / detlint rule D2.
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;
use std::collections::VecDeque;

/// Default per-series retention cap (samples). At a 10 s scrape interval
/// this holds > 48 h of history — enough for the NASA evaluation runs.
const DEFAULT_CAPACITY: usize = 20_000;

/// Interned handle to one series — the hot-path address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeriesId(pub(crate) u32);

impl SeriesId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One series: a bounded deque of (time, value), chronological.
#[derive(Debug)]
pub struct Series {
    samples: VecDeque<(Time, f64)>,
    capacity: usize,
}

impl Series {
    fn new(capacity: usize) -> Self {
        Series {
            samples: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
        }
    }

    fn push(&mut self, t: Time, v: f64) {
        if let Some(&(last, _)) = self.samples.back() {
            debug_assert!(t >= last, "samples must be appended in time order");
        }
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
        }
        self.samples.push_back((t, v));
    }

    pub fn latest(&self) -> Option<(Time, f64)> {
        self.samples.back().copied()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// All samples, oldest first (CSV dumps, debug).
    pub fn iter(&self) -> impl Iterator<Item = (Time, f64)> + '_ {
        self.samples.iter().copied()
    }

    /// Samples with `from < t <= to` (inclusive upper bound).
    pub fn range(&self, from: Time, to: Time) -> Vec<(Time, f64)> {
        self.range_iter(from, to).collect()
    }

    /// Allocation-free variant of [`Series::range`]: samples are stored
    /// chronologically, so both bounds are found by `partition_point`
    /// binary search — O(log n + k) instead of the old full-deque scan.
    pub fn range_iter(&self, from: Time, to: Time) -> impl Iterator<Item = (Time, f64)> + '_ {
        let start = self.samples.partition_point(|&(t, _)| t <= from);
        let end = self.samples.partition_point(|&(t, _)| t <= to);
        self.samples.range(start..end.max(start)).copied()
    }
}

/// The store: a slab of series addressed by [`SeriesId`], with a name
/// index used only at registration time and by the debug/report API.
#[derive(Debug, Default)]
#[allow(clippy::disallowed_types)] // lookup-only name index; never iterated
pub struct Tsdb {
    series: Vec<Series>,
    names: Vec<String>,
    by_name: HashMap<String, SeriesId>,
}

impl Tsdb {
    pub fn new() -> Self {
        Tsdb::default()
    }

    /// Intern `name`, creating the series on first sight. Idempotent:
    /// re-registering an existing name returns its id without allocating.
    pub fn register(&mut self, name: &str) -> SeriesId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = SeriesId(self.series.len() as u32);
        self.series.push(Series::new(DEFAULT_CAPACITY));
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        id
    }

    /// Resolve a name without creating anything.
    pub fn id(&self, name: &str) -> Option<SeriesId> {
        self.by_name.get(name).copied()
    }

    /// The interned name of a series.
    pub fn name(&self, id: SeriesId) -> &str {
        &self.names[id.index()]
    }

    pub fn series_count(&self) -> usize {
        self.series.len()
    }

    /// Hot path: append a sample through a handle. No allocation, no
    /// hashing — a bounds-checked slab index.
    pub fn push(&mut self, id: SeriesId, t: Time, v: f64) {
        self.series[id.index()].push(t, v);
    }

    pub fn series_by_id(&self, id: SeriesId) -> &Series {
        &self.series[id.index()]
    }

    pub fn latest_by_id(&self, id: SeriesId) -> Option<(Time, f64)> {
        self.series[id.index()].latest()
    }

    /// Allocation-free handle-based range query (`from < t <= to`).
    pub fn range_by_id(
        &self,
        id: SeriesId,
        from: Time,
        to: Time,
    ) -> impl Iterator<Item = (Time, f64)> + '_ {
        self.series[id.index()].range_iter(from, to)
    }

    // -- string-keyed debug/report conveniences -----------------------------

    /// Insert by name: interner lookup + push. Allocates only when the
    /// series does not exist yet (the old implementation paid a
    /// `to_string` on *every* call).
    pub fn insert(&mut self, name: &str, t: Time, v: f64) {
        let id = self.register(name);
        self.push(id, t, v);
    }

    pub fn series(&self, name: &str) -> Option<&Series> {
        self.id(name).map(|id| self.series_by_id(id))
    }

    pub fn latest(&self, name: &str) -> Option<(Time, f64)> {
        self.series(name).and_then(|s| s.latest())
    }

    pub fn range(&self, name: &str, from: Time, to: Time) -> Vec<(Time, f64)> {
        self.series(name)
            .map(|s| s.range(from, to))
            .unwrap_or_default()
    }

    pub fn series_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.names.iter().map(|s| s.as_str()).collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_query() {
        let mut db = Tsdb::new();
        for t in 1..=5u64 {
            db.insert("a.cpu", t * 10, t as f64);
        }
        assert_eq!(db.latest("a.cpu"), Some((50, 5.0)));
        assert_eq!(db.range("a.cpu", 10, 40), vec![(20, 2.0), (30, 3.0), (40, 4.0)]);
        assert!(db.range("missing", 0, 100).is_empty());
        assert_eq!(db.latest("missing"), None);
    }

    #[test]
    fn ring_buffer_caps() {
        let mut s = Series::new(3);
        for t in 0..10u64 {
            s.push(t, t as f64);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest(), Some((9, 9.0)));
        assert_eq!(s.range(0, 100).len(), 3);
    }

    #[test]
    fn series_names_sorted() {
        let mut db = Tsdb::new();
        db.insert("b", 1, 0.0);
        db.insert("a", 1, 0.0);
        assert_eq!(db.series_names(), vec!["a", "b"]);
    }

    #[test]
    fn interner_reuses_ids() {
        // Regression guard for the old per-insert `name.to_string()`:
        // repeated registration/insert of an existing name must resolve to
        // the same slab slot and never grow the store.
        let mut db = Tsdb::new();
        let a = db.register("a.cpu");
        let b = db.register("b.cpu");
        assert_ne!(a, b);
        for t in 0..100u64 {
            db.insert("a.cpu", t, 1.0);
            assert_eq!(db.register("a.cpu"), a);
        }
        assert_eq!(db.series_count(), 2);
        assert_eq!(db.id("a.cpu"), Some(a));
        assert_eq!(db.name(b), "b.cpu");
        assert_eq!(db.series_by_id(a).len(), 100);
    }

    #[test]
    fn handle_and_string_queries_agree() {
        let mut db = Tsdb::new();
        let id = db.register("svc.cpu");
        for t in 1..=50u64 {
            db.push(id, t * 7, t as f64);
        }
        let by_name = db.range("svc.cpu", 70, 280);
        let by_id: Vec<(Time, f64)> = db.range_by_id(id, 70, 280).collect();
        assert_eq!(by_name, by_id);
        assert_eq!(db.latest("svc.cpu"), db.latest_by_id(id));
    }

    #[test]
    fn range_pins_half_open_bound_semantics() {
        // The adapter contract is `(from, to]`: the sample AT `from` is
        // excluded, the sample AT `to` is included — binary search must
        // preserve exactly what the old linear scan returned.
        let mut s = Series::new(100);
        for t in [10u64, 20, 20, 30, 40] {
            s.push(t, t as f64);
        }
        assert_eq!(s.range(10, 30), vec![(20, 20.0), (20, 20.0), (30, 30.0)]);
        assert_eq!(s.range(0, 10), vec![(10, 10.0)]);
        assert_eq!(s.range(40, 100), vec![]);
        assert_eq!(s.range(35, 35), vec![]);
        assert_eq!(s.range(0, u64::MAX).len(), 5);
        // Degenerate inverted window is empty, not a panic.
        assert_eq!(s.range(30, 10), vec![]);
    }

    #[test]
    fn range_matches_linear_scan_reference() {
        let mut s = Series::new(1000);
        for t in 0..200u64 {
            s.push(t * 3, (t as f64).sin());
        }
        let reference = |from: Time, to: Time| -> Vec<(Time, f64)> {
            s.iter().filter(|&(t, _)| t > from && t <= to).collect()
        };
        for (from, to) in [(0, 0), (0, 599), (1, 2), (100, 400), (598, 700)] {
            assert_eq!(s.range(from, to), reference(from, to), "window ({from}, {to}]");
        }
    }
}

//! Integration check: the real repository lints clean.
//!
//! This is the teeth of the determinism contract — if a PR introduces a
//! wall-clock read, a hash traversal, a nexus bypass, or a hot-path
//! panic anywhere in the scanned tree, `cargo test -q` fails here with
//! the exact `file:line rule message` list (and `cargo run -p detlint`
//! fails in CI with the same output).

use std::path::Path;

fn workspace_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("tools/detlint sits two levels under the workspace root")
}

#[test]
fn repository_lints_clean() {
    let root = workspace_root();
    let diags = detlint::lint_repo(root).expect("walk + read the scanned tree");
    assert!(
        diags.is_empty(),
        "determinism-contract violations (fix them or add `// detlint: allow(RULE) — reason`):\n{}",
        diags
            .iter()
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn scan_actually_covers_the_tree() {
    // Guard against a silent path bug making the clean check vacuous:
    // the workspace has dozens of Rust files in the scanned roots, and
    // the simulator core must be among them.
    let root = workspace_root();
    let files = detlint::collect_rs_files(root).expect("walk the scanned tree");
    assert!(
        files.len() >= 40,
        "expected to scan >= 40 files, found {} — scan roots moved?",
        files.len()
    );
    let labels: Vec<String> = files
        .iter()
        .map(|f| detlint::rel_label(root, f))
        .collect();
    for must_have in [
        "rust/src/sim/queue.rs",
        "rust/src/app/mod.rs",
        "rust/src/cluster/mod.rs",
        "rust/src/experiments/sweep.rs",
        "examples/quickstart.rs",
    ] {
        assert!(
            labels.iter().any(|l| l == must_have),
            "scan missed {must_have}"
        );
    }
}

#[test]
fn suppressions_in_tree_are_rare_and_reasoned() {
    // The contract allows escapes but keeps them visible: every pragma
    // in the real tree must parse cleanly (S1 enforces the reason), and
    // the total count stays small enough to audit by hand. Raise the
    // bound consciously if a future PR needs another sanctioned escape.
    let root = workspace_root();
    let mut pragmas = 0usize;
    for file in detlint::collect_rs_files(root).expect("walk") {
        let src = std::fs::read_to_string(&file).expect("read");
        pragmas += src
            .lines()
            .filter(|l| l.contains("// detlint: allow("))
            .count();
    }
    assert!(
        pragmas <= 4,
        "suppression pragma count grew to {pragmas}; audit each escape before raising this bound"
    );
}

//! The real PJRT-backed runtime (`pjrt` feature): compiles the AOT HLO
//! artifacts on the PJRT CPU client and dispatches them. Requires the
//! `xla` bindings in the crate graph.

use super::{AdamState, LstmParams, Manifest};
use anyhow::{bail, Context};
use std::path::Path;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compiled forecaster: all four artifacts, ready to dispatch.
pub struct LstmRuntime {
    manifest: Manifest,
    exe_init: PjRtLoadedExecutable,
    exe_predict: PjRtLoadedExecutable,
    exe_train_step: PjRtLoadedExecutable,
    exe_train_epoch: PjRtLoadedExecutable,
}

fn load_exe(client: &PjRtClient, dir: &Path, name: &str) -> crate::Result<PjRtLoadedExecutable> {
    let path = dir.join(name);
    let proto = HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

fn literal_f32(data: &[f32], dims: &[i64]) -> crate::Result<Literal> {
    let expected: i64 = dims.iter().product();
    if expected as usize != data.len() {
        bail!("literal shape {:?} wants {} elems, got {}", dims, expected, data.len());
    }
    Ok(Literal::vec1(data).reshape(dims)?)
}

impl LstmRuntime {
    /// Load and compile all artifacts from `dir`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let exe_init = load_exe(&client, dir, "lstm_init.hlo.txt")?;
        let exe_predict = load_exe(&client, dir, "lstm_predict.hlo.txt")?;
        let exe_train_step = load_exe(&client, dir, "lstm_train_step.hlo.txt")?;
        let exe_train_epoch = load_exe(&client, dir, "lstm_train_epoch.hlo.txt")?;
        Ok(LstmRuntime {
            manifest,
            exe_init,
            exe_predict,
            exe_train_step,
            exe_train_epoch,
        })
    }

    /// Load from the default artifact location.
    pub fn load_default() -> crate::Result<Self> {
        let dir = super::find_artifacts_dir()
            .context("artifacts/ not found — run `make artifacts` first")?;
        Self::load(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn param_literals(&self, params: &LstmParams) -> crate::Result<Vec<Literal>> {
        if params.tensors.len() != self.manifest.param_shapes.len() {
            bail!(
                "expected {} param tensors, got {}",
                self.manifest.param_shapes.len(),
                params.tensors.len()
            );
        }
        params
            .tensors
            .iter()
            .zip(&self.manifest.param_shapes)
            .map(|(data, (_, shape))| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                literal_f32(data, &dims)
            })
            .collect()
    }

    fn unpack(result: Literal, expect: usize) -> crate::Result<Vec<Vec<f32>>> {
        let parts = result.to_tuple()?;
        if parts.len() != expect {
            bail!("artifact returned {}-tuple, expected {}", parts.len(), expect);
        }
        parts
            .into_iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[Literal]) -> crate::Result<Literal> {
        let result = exe.execute::<Literal>(args)?;
        Ok(result[0][0].to_literal_sync()?)
    }

    /// Seeded parameter initialization (same numbers as the python init).
    pub fn init(&self, seed: u32) -> crate::Result<LstmParams> {
        let out = self.run(&self.exe_init, &[Literal::scalar(seed)])?;
        let tensors = Self::unpack(out, 4)?;
        Ok(LstmParams { tensors })
    }

    /// Forecast the next metric vector from one scaled window.
    ///
    /// `window` is row-major `(seq_len, input_dim)`; returns `output_dim`
    /// predictions.
    pub fn predict(&self, params: &LstmParams, window: &[f32]) -> crate::Result<Vec<f32>> {
        let m = &self.manifest;
        let x = literal_f32(window, &[1, m.seq_len as i64, m.input_dim as i64])?;
        let mut args = self.param_literals(params)?;
        args.push(x);
        let out = self.run(&self.exe_predict, &args)?;
        let mut parts = Self::unpack(out, 1)?;
        Ok(parts.pop().unwrap())
    }

    fn train_args(
        &self,
        params: &LstmParams,
        opt: &AdamState,
        xs: &[f32],
        ys: &[f32],
        x_dims: &[i64],
        y_dims: &[i64],
    ) -> crate::Result<Vec<Literal>> {
        let mut args = self.param_literals(params)?;
        for moments in [&opt.m, &opt.v] {
            for (m_i, (_, shape)) in moments.iter().zip(&self.manifest.param_shapes) {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                args.push(literal_f32(m_i, &dims)?);
            }
        }
        args.push(Literal::scalar(opt.t));
        args.push(literal_f32(xs, x_dims)?);
        args.push(literal_f32(ys, y_dims)?);
        Ok(args)
    }

    fn apply_train_output(
        out: Literal,
        params: &mut LstmParams,
        opt: &mut AdamState,
    ) -> crate::Result<f32> {
        // (4 params, 4 m, 4 v, t, loss) = 14 outputs.
        let mut parts = Self::unpack(out, 14)?;
        let loss = parts.pop().unwrap()[0];
        let t = parts.pop().unwrap()[0];
        let v: Vec<Vec<f32>> = parts.split_off(8);
        let m: Vec<Vec<f32>> = parts.split_off(4);
        params.tensors = parts;
        opt.m = m;
        opt.v = v;
        opt.t = t;
        Ok(loss)
    }

    /// One fused fwd+bwd+Adam step on a `(batch, seq_len, input_dim)`
    /// minibatch. Updates `params`/`opt` in place; returns the loss.
    pub fn train_step(
        &self,
        params: &mut LstmParams,
        opt: &mut AdamState,
        xb: &[f32],
        yb: &[f32],
    ) -> crate::Result<f32> {
        let m = &self.manifest;
        let x_dims = [m.batch as i64, m.seq_len as i64, m.input_dim as i64];
        let y_dims = [m.batch as i64, m.output_dim as i64];
        let args = self.train_args(params, opt, xb, yb, &x_dims, &y_dims)?;
        let out = self.run(&self.exe_train_step, &args)?;
        Self::apply_train_output(out, params, opt)
    }

    /// `epoch_batches` fused train steps in a single dispatch
    /// (`(k, batch, seq_len, input_dim)` inputs). Returns the mean loss.
    pub fn train_epoch(
        &self,
        params: &mut LstmParams,
        opt: &mut AdamState,
        xs: &[f32],
        ys: &[f32],
    ) -> crate::Result<f32> {
        let m = &self.manifest;
        let k = m.epoch_batches as i64;
        let x_dims = [k, m.batch as i64, m.seq_len as i64, m.input_dim as i64];
        let y_dims = [k, m.batch as i64, m.output_dim as i64];
        let args = self.train_args(params, opt, xs, ys, &x_dims, &y_dims)?;
        let out = self.run(&self.exe_train_epoch, &args)?;
        Self::apply_train_output(out, params, opt)
    }
}

#[cfg(test)]
mod tests {
    use super::super::find_artifacts_dir;
    use super::*;

    /// Runtime tests need `make artifacts` to have run; skip (with a
    /// loud marker) when the artifacts are absent so plain `cargo test`
    /// stays usable in a fresh checkout.
    fn runtime() -> Option<LstmRuntime> {
        let dir = find_artifacts_dir()?;
        Some(LstmRuntime::load(&dir).expect("artifacts present but failed to load"))
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let p1 = rt.init(42).unwrap();
        let p2 = rt.init(42).unwrap();
        assert_eq!(p1, p2);
        let m = rt.manifest();
        for (tensor, (name, shape)) in p1.tensors.iter().zip(&m.param_shapes) {
            assert_eq!(
                tensor.len(),
                shape.iter().product::<usize>(),
                "shape mismatch for {name}"
            );
        }
        // unit forget bias: b[H..2H] == 1.0
        let h = m.hidden_dim;
        assert!(p1.tensors[1][h..2 * h].iter().all(|&x| x == 1.0));
        assert!(p1.tensors[1][..h].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn predict_shape_and_nonnegative() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let m = rt.manifest();
        let params = rt.init(1).unwrap();
        let window = vec![0.3f32; m.seq_len * m.input_dim];
        let y = rt.predict(&params, &window).unwrap();
        assert_eq!(y.len(), m.output_dim);
        assert!(y.iter().all(|&v| v >= 0.0), "{y:?}");
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let m = rt.manifest();
        let mut params = rt.init(0).unwrap();
        let mut opt = AdamState::zeros(m);
        // Learnable mapping: target = mean over window.
        let mut rng = crate::util::rng::Pcg64::new(5, 0);
        let xb: Vec<f32> = (0..m.batch * m.seq_len * m.input_dim)
            .map(|_| rng.f64() as f32)
            .collect();
        let mut yb = vec![0f32; m.batch * m.output_dim];
        for b in 0..m.batch {
            for i in 0..m.input_dim {
                let mut s = 0.0;
                for t in 0..m.seq_len {
                    s += xb[b * m.seq_len * m.input_dim + t * m.input_dim + i];
                }
                yb[b * m.output_dim + i] = s / m.seq_len as f32;
            }
        }
        let first = rt.train_step(&mut params, &mut opt, &xb, &yb).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = rt.train_step(&mut params, &mut opt, &xb, &yb).unwrap();
        }
        assert!(last < first * 0.6, "first={first} last={last}");
        assert_eq!(opt.t, 61.0);
    }

    #[test]
    fn train_epoch_matches_sequential_steps() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let m = rt.manifest();
        let k = m.epoch_batches;
        let mut rng = crate::util::rng::Pcg64::new(9, 0);
        let xs: Vec<f32> = (0..k * m.batch * m.seq_len * m.input_dim)
            .map(|_| rng.f64() as f32)
            .collect();
        let ys: Vec<f32> = (0..k * m.batch * m.output_dim)
            .map(|_| rng.f64() as f32)
            .collect();

        let mut p_seq = rt.init(3).unwrap();
        let mut o_seq = AdamState::zeros(m);
        let step_len_x = m.batch * m.seq_len * m.input_dim;
        let step_len_y = m.batch * m.output_dim;
        let mut losses = Vec::new();
        for i in 0..k {
            let xb = &xs[i * step_len_x..(i + 1) * step_len_x];
            let yb = &ys[i * step_len_y..(i + 1) * step_len_y];
            losses.push(rt.train_step(&mut p_seq, &mut o_seq, xb, yb).unwrap());
        }

        let mut p_ep = rt.init(3).unwrap();
        let mut o_ep = AdamState::zeros(m);
        let mean = rt.train_epoch(&mut p_ep, &mut o_ep, &xs, &ys).unwrap();

        let want: f32 = losses.iter().sum::<f32>() / k as f32;
        assert!((mean - want).abs() < 1e-4, "mean={mean} want={want}");
        for (a, b) in p_seq.tensors.iter().zip(&p_ep.tensors) {
            let max_diff = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_diff < 1e-4, "param divergence {max_diff}");
        }
    }

    #[test]
    fn predict_rejects_bad_window() {
        let Some(rt) = runtime() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let params = rt.init(1).unwrap();
        assert!(rt.predict(&params, &[0.0; 3]).is_err());
    }
}

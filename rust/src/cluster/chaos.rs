//! Deterministic fault injection — the chaos plane.
//!
//! A [`FaultPlan`] is a copyable grid-axis descriptor (like
//! `workload::Scenario`): it *describes* which fault classes a run
//! injects, while every concrete fault timing is derived from the cell
//! seed through dedicated RNG streams at install time. The plan itself
//! never draws randomness, so an empty plan is a strict no-op — zero
//! extra RNG draws, zero extra events — and a faulted run is
//! bit-reproducible across runs, worker-thread counts, and shard counts
//! (see DESIGN.md §Chaos plane).
//!
//! # RNG stream layout
//!
//! Chaos never touches the engine streams (monolith 1/2/3, sharded
//! `shard_stream(world, role)`). Each world draws its fault randomness
//! from three dedicated streams keyed by the world index (0 for the
//! monolith):
//!
//! * [`chaos_schedule_stream`] — the node crash/rejoin schedule, drawn
//!   entirely at install time (absolute-time events, one pass per node
//!   in ascending node order).
//! * [`chaos_pod_stream`] — cold-start / crash-loop perturbation of the
//!   container-init delay, drawn once per successful placement in
//!   `Cluster::try_place` (placements happen in event order, so the
//!   draw sequence is deterministic).
//! * [`chaos_net_stream`] — extra edge→cloud network delay, drawn once
//!   per Eigen forward in submit order (monolith) or barrier-merge
//!   order (cloud shard world — the merge order is shard-count
//!   invariant).
//!
//! # Fault classes
//!
//! * **Node crash / rejoin** ([`NodeCrashPlan`]): per-node renewal
//!   process — exponential up-gaps, uniform outage lengths. A crashed
//!   node leaves every matching-node cache (the scheduler stops seeing
//!   it), its pods are killed through the `set_phase` nexus, and their
//!   in-flight requests are re-queued with fresh generational handles.
//! * **Cold start / crash loop** ([`ColdStartPlan`] /
//!   [`CrashLoopPlan`]): multiplies or extends the `PodRunning` init
//!   delay — the reactive-lag window proactive scaling attacks.
//! * **Network delay** ([`NetDelayPlan`]): uniform extra one-way delay
//!   on the edge→cloud Eigen forward path.

use super::{Cluster, DeploymentId, PodPhase, Tier};
use crate::sim::{Event, EventQueue, NodeId, RequestId, Time};
use crate::stats::StreamingStats;
use crate::util::rng::Pcg64;

/// Node crash/rejoin schedule parameters: each eligible node alternates
/// exponential(mean `mean_gap`) up-time with uniform
/// `[outage_min, outage_max]` outages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeCrashPlan {
    /// Mean up-time between crashes per node.
    pub mean_gap: Time,
    /// Outage length bounds (inclusive).
    pub outage_min: Time,
    pub outage_max: Time,
    /// Whether cloud-tier nodes crash too (edge nodes always do).
    pub cloud: bool,
}

/// Cold-start perturbation: with probability `slow_prob` a placement's
/// init delay is multiplied by uniform `[factor_min, factor_max]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColdStartPlan {
    pub slow_prob: f64,
    pub factor_min: f64,
    pub factor_max: f64,
}

/// Crash-loop perturbation: each restart attempt (up to `max_restarts`)
/// independently fails with probability `prob`, adding one more full
/// init delay before the pod comes up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashLoopPlan {
    pub prob: f64,
    pub max_restarts: u32,
}

/// Extra one-way delay on each edge→cloud Eigen forward, uniform in
/// `[extra_min, extra_max]` (inclusive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetDelayPlan {
    pub extra_min: Time,
    pub extra_max: Time,
}

/// Which fault classes a run injects. `Default`/[`FaultPlan::none`] is
/// the empty plan — a strict no-op.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultPlan {
    pub node_crash: Option<NodeCrashPlan>,
    pub cold_start: Option<ColdStartPlan>,
    pub crash_loop: Option<CrashLoopPlan>,
    pub net_delay: Option<NetDelayPlan>,
}

impl FaultPlan {
    /// The empty plan: no faults, no RNG draws, no extra events.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.node_crash.is_none()
            && self.cold_start.is_none()
            && self.crash_loop.is_none()
            && self.net_delay.is_none()
    }

    /// Compact report/JSON label, e.g. `"crash+coldstart"`; `"none"`
    /// for the empty plan.
    pub fn label(&self) -> String {
        let mut parts: Vec<&str> = Vec::new();
        if self.node_crash.is_some() {
            parts.push("crash");
        }
        if self.cold_start.is_some() {
            parts.push("coldstart");
        }
        if self.crash_loop.is_some() {
            parts.push("crashloop");
        }
        if self.net_delay.is_some() {
            parts.push("netdelay");
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join("+")
        }
    }
}

/// RNG stream for a world's node-fault schedule (world 0 = monolith).
/// The chaos streams sit far above the engine streams (monolith 1/2/3,
/// sharded `10 + 3*world + role`) so they can never collide.
pub fn chaos_schedule_stream(world: usize) -> u64 {
    1_000_000 + world as u64
}

/// RNG stream for a world's pod cold-start / crash-loop perturbations.
pub fn chaos_pod_stream(world: usize) -> u64 {
    2_000_000 + world as u64
}

/// RNG stream for a world's edge→cloud network-delay perturbations.
pub fn chaos_net_stream(world: usize) -> u64 {
    3_000_000 + world as u64
}

/// Pre-draw the whole node crash/rejoin schedule for `[0, end)` and
/// enqueue it as absolute-time events. One renewal pass per eligible
/// node in ascending node order — the event count and every timestamp
/// are functions of (plan, node list, rng seed) only, never of the
/// run's interleaving. A crash whose rejoin would land at or past `end`
/// leaves the node down for the rest of the run.
pub fn schedule_node_faults(
    cluster: &Cluster,
    plan: &NodeCrashPlan,
    end: Time,
    rng: &mut Pcg64,
    queue: &mut EventQueue,
) {
    let mean_gap_secs = crate::sim::to_secs(plan.mean_gap.max(1));
    for (idx, node) in cluster.nodes.iter().enumerate() {
        if node.spec.tier == Tier::Cloud && !plan.cloud {
            continue;
        }
        let nid = NodeId(idx as u32);
        let mut t: Time = 0;
        loop {
            let gap = crate::sim::from_secs(rng.exponential(1.0 / mean_gap_secs));
            t = t.saturating_add(gap.max(1));
            if t >= end {
                break;
            }
            queue.schedule_at(t, Event::NodeCrash { node: nid });
            let outage = rng.int_range(plan.outage_min, plan.outage_max + 1);
            let rejoin = t.saturating_add(outage.max(1));
            if rejoin >= end {
                break; // stays down through the end of the run
            }
            queue.schedule_at(rejoin, Event::NodeRejoin { node: nid });
            t = rejoin;
        }
    }
}

/// Per-world pod-chaos state: one RNG stream perturbing every
/// placement's init delay, plus the streaming stats the fault counters
/// report. Installed on the [`Cluster`] via [`Cluster::set_pod_chaos`];
/// `Cluster::try_place` consults it after drawing the base delay.
#[derive(Debug)]
pub struct PodChaos {
    rng: Pcg64,
    cold_start: Option<ColdStartPlan>,
    crash_loop: Option<CrashLoopPlan>,
    /// Total simulated restart failures across all placements.
    pub crash_loops: u64,
    /// Distribution of effective init delays (seconds) — perturbed and
    /// unperturbed alike, so the p95 exposes the slowdown tail.
    pub init_delays: StreamingStats,
}

impl PodChaos {
    pub fn new(
        rng: Pcg64,
        cold_start: Option<ColdStartPlan>,
        crash_loop: Option<CrashLoopPlan>,
    ) -> Self {
        PodChaos {
            rng,
            cold_start,
            crash_loop,
            crash_loops: 0,
            init_delays: StreamingStats::new(),
        }
    }

    /// Perturb a placement's base init delay. Called once per successful
    /// placement; the draw count per call depends only on the plan and
    /// this stream's own history, never on engine-stream state.
    pub fn perturb_init_delay(&mut self, base: Time) -> Time {
        let mut delay = base;
        if let Some(cs) = self.cold_start {
            if self.rng.chance(cs.slow_prob) {
                let factor = self.rng.range(cs.factor_min, cs.factor_max);
                delay = (delay as f64 * factor).round() as Time;
            }
        }
        if let Some(cl) = self.crash_loop {
            let mut restarts = 0;
            while restarts < cl.max_restarts && self.rng.chance(cl.prob) {
                delay = delay.saturating_add(
                    self.rng
                        .int_range(super::INIT_DELAY_MIN, super::INIT_DELAY_MAX + 1),
                );
                restarts += 1;
                self.crash_loops += 1;
            }
        }
        self.init_delays.record(crate::sim::to_secs(delay));
        delay
    }
}

/// Per-world network-chaos state: one RNG stream drawing extra
/// edge→cloud forward delay, one draw per Eigen forward.
#[derive(Debug)]
pub struct NetChaos {
    rng: Pcg64,
    extra_min: Time,
    extra_max: Time,
}

impl NetChaos {
    pub fn new(rng: Pcg64, plan: &NetDelayPlan) -> Self {
        NetChaos {
            rng,
            extra_min: plan.extra_min,
            extra_max: plan.extra_max,
        }
    }

    /// Extra one-way delay for the next Eigen forward.
    pub fn draw_extra(&mut self) -> Time {
        self.rng.int_range(self.extra_min, self.extra_max + 1)
    }
}

/// What a node crash did to the cluster — the driver uses it to
/// reschedule replacements and re-queue orphaned requests.
#[derive(Debug, Clone, Default)]
pub struct CrashOutcome {
    /// Requests that were in flight on killed pods, in ascending pod-id
    /// order. The handles are still live in the request arena — the
    /// caller re-queues them (`App::requeue_orphans`).
    pub orphans: Vec<RequestId>,
    /// Deployments that lost pods, ascending, deduplicated.
    pub deployments: Vec<DeploymentId>,
    /// Pods killed by the crash.
    pub pods_killed: usize,
}

/// Per-run fault counters, merged across shard worlds and surfaced in
/// `CellMetrics` / the sweep report.
#[derive(Debug, Clone, Default)]
pub struct ChaosCounters {
    pub crashes: u64,
    pub rejoins: u64,
    pub pods_killed: u64,
    pub pods_rescheduled: u64,
    pub crash_loops: u64,
    /// Total node downtime (sum over nodes, clamped to the run window).
    pub downtime: Time,
    /// Effective init-delay distribution (seconds).
    pub init_delays: StreamingStats,
}

impl ChaosCounters {
    pub fn merge(&mut self, other: &ChaosCounters) {
        self.crashes += other.crashes;
        self.rejoins += other.rejoins;
        self.pods_killed += other.pods_killed;
        self.pods_rescheduled += other.pods_rescheduled;
        self.crash_loops += other.crash_loops;
        self.downtime += other.downtime;
        self.init_delays.merge(&other.init_delays);
    }

    /// p95 of the effective init delay in seconds (NaN when no pod ever
    /// placed — e.g. a run too short to scale).
    pub fn cold_start_p95(&self) -> f64 {
        self.init_delays.quantile(95.0)
    }
}

impl Cluster {
    /// Install (or clear) the pod-chaos perturbation consulted by
    /// `try_place`. `None` restores the unperturbed init delay.
    pub fn set_pod_chaos(&mut self, chaos: Option<PodChaos>) {
        self.pod_chaos = chaos;
    }

    /// The installed pod-chaos state, if any (for counter finalization).
    pub fn pod_chaos(&self) -> Option<&PodChaos> {
        self.pod_chaos.as_ref()
    }

    /// Whether a node is currently up (down nodes are invisible to the
    /// scheduler and the Algorithm-1 capacity cap).
    pub fn node_up(&self, node: NodeId) -> bool {
        self.nodes[node.0 as usize].up
    }

    /// Crash a node: mark it down, drop it from every matching-node
    /// cache, and kill every pod bound to it (straight to `Gone`
    /// through the `set_phase` nexus — a crash skips graceful
    /// termination). Returns `None` if the node was already down.
    ///
    /// Killed pods may leave stale `PodRunning` / `PodTerminated` /
    /// `ServiceComplete` events in the queue; the handlers tolerate
    /// them (phase guards and request-generation checks), exactly like
    /// the pre-existing stale-event tolerance on the graceful path.
    pub fn crash_node(&mut self, nid: NodeId) -> Option<CrashOutcome> {
        if !self.nodes[nid.0 as usize].up {
            return None;
        }
        self.nodes[nid.0 as usize].up = false;
        // Drop the node from every matching-node cache (ascending order
        // is preserved by point removal).
        for dep in &mut self.deployments {
            if let Ok(i) = dep.matching_nodes.binary_search(&nid) {
                dep.matching_nodes.remove(i);
            }
        }
        // Kill bound pods in ascending pod-id order (node.pods is
        // swap_remove-ordered, so sort the snapshot).
        let mut victims: Vec<_> = self.nodes[nid.0 as usize].pods.clone();
        victims.sort_unstable();
        let mut out = CrashOutcome {
            pods_killed: victims.len(),
            ..CrashOutcome::default()
        };
        for pid in victims {
            let dep = self.pods[pid.0 as usize].deployment;
            let spec = self.pods[pid.0 as usize].spec;
            if let Some(req) = self.pods[pid.0 as usize].finish_service(0) {
                out.orphans.push(req);
            }
            self.nodes[nid.0 as usize].unbind(pid, dep, spec);
            self.pods[pid.0 as usize].node = None;
            self.set_phase(pid, PodPhase::Gone);
            self.detach(pid, dep);
            if out.deployments.last() != Some(&dep) {
                match out.deployments.binary_search(&dep) {
                    Ok(_) => {}
                    Err(i) => out.deployments.insert(i, dep),
                }
            }
        }
        Some(out)
    }

    /// Rejoin a crashed node: mark it up and restore it to every
    /// matching-node cache (sorted insertion keeps ascending order).
    /// No-op if the node is already up. The caller retries Pending pods
    /// so the recovered capacity is used.
    pub fn rejoin_node(&mut self, nid: NodeId) -> bool {
        if self.nodes[nid.0 as usize].up {
            return false;
        }
        self.nodes[nid.0 as usize].up = true;
        let spec = self.nodes[nid.0 as usize].spec.clone();
        for dep in &mut self.deployments {
            if dep.selector.matches(&spec) {
                if let Err(i) = dep.matching_nodes.binary_search(&nid) {
                    dep.matching_nodes.insert(i, nid);
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector};
    use crate::sim::{MIN, SEC};

    fn chaos_cluster() -> (Cluster, EventQueue, Pcg64) {
        let mut c = Cluster::new();
        c.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        c.add_node(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048));
        c.add_node(NodeSpec::new("c1", Tier::Cloud, 0, 3000, 3072));
        c.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            1,
            16,
        ));
        (c, EventQueue::new(), Pcg64::new(9, 1))
    }

    #[test]
    fn empty_plan_is_empty() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert_eq!(p.label(), "none");
        let storm = FaultPlan {
            node_crash: Some(NodeCrashPlan {
                mean_gap: 10 * MIN,
                outage_min: 30 * SEC,
                outage_max: 2 * MIN,
                cloud: false,
            }),
            net_delay: Some(NetDelayPlan {
                extra_min: 0,
                extra_max: 20_000,
            }),
            ..FaultPlan::none()
        };
        assert!(!storm.is_empty());
        assert_eq!(storm.label(), "crash+netdelay");
    }

    #[test]
    fn crash_kills_pods_and_hides_node() {
        let (mut c, mut q, mut rng) = chaos_cluster();
        let dep = DeploymentId(0);
        c.reconcile(dep, 4, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let Event::PodRunning { pod } = ev {
                c.on_pod_running(pod);
            }
        }
        assert_eq!(c.live_replicas(dep), 4);
        let max_before = c.max_replicas(dep);

        // Mark one pod busy so the crash orphans its request.
        let busy = c.min_idle_pod(dep).unwrap();
        let req = RequestId::new(5, 0);
        c.start_service(busy, req, 0);
        let victim_node = c.pod(busy).node.unwrap();
        let killed = c.nodes[victim_node.0 as usize].pods.len();

        let out = c.crash_node(victim_node).expect("node was up");
        assert_eq!(out.pods_killed, killed);
        assert_eq!(out.orphans, vec![req]);
        assert_eq!(out.deployments, vec![dep]);
        assert!(!c.node_up(victim_node));
        assert_eq!(c.live_replicas(dep), 4 - killed);
        assert!(c.max_replicas(dep) < max_before, "down node must not count");
        c.verify_indices();

        // Idempotent: crashing a down node is None.
        assert!(c.crash_node(victim_node).is_none());

        // Rejoin restores capacity and the matching cache.
        assert!(c.rejoin_node(victim_node));
        assert!(!c.rejoin_node(victim_node), "already up");
        assert_eq!(c.max_replicas(dep), max_before);
        c.verify_indices();
    }

    #[test]
    fn crashed_node_rejected_by_scheduler() {
        let (mut c, mut q, mut rng) = chaos_cluster();
        let dep = DeploymentId(0);
        c.crash_node(NodeId(0)).unwrap();
        c.reconcile(dep, 6, &mut q, &mut rng);
        // Only e2 is schedulable: 1800m/500m = 3 placements, rest Pending.
        assert_eq!(c.count_phase(dep, PodPhase::Initializing), 3);
        assert_eq!(c.count_phase(dep, PodPhase::Pending), 3);
        for p in c.pods.iter() {
            assert_ne!(p.node, Some(NodeId(0)), "placed on a down node");
        }
        c.verify_indices();
        // Rejoin + retry drains the Pending backlog onto e1.
        c.rejoin_node(NodeId(0));
        c.retry_pending(&mut q, &mut rng);
        assert_eq!(c.count_phase(dep, PodPhase::Pending), 0);
        c.verify_indices();
    }

    #[test]
    fn fault_schedule_is_deterministic_and_bounded() {
        let (c, _, _) = chaos_cluster();
        let plan = NodeCrashPlan {
            mean_gap: 5 * MIN,
            outage_min: 30 * SEC,
            outage_max: 2 * MIN,
            cloud: false,
        };
        let end = 60 * MIN;
        let drain = |seed: u64| -> Vec<(Time, Event)> {
            let mut q = EventQueue::new();
            let mut rng = Pcg64::new(seed, chaos_schedule_stream(0));
            schedule_node_faults(&c, &plan, end, &mut rng, &mut q);
            let mut events = Vec::new();
            while let Some((t, ev)) = q.pop() {
                events.push((t, ev));
            }
            events
        };
        let a = drain(42);
        assert_eq!(a, drain(42), "same seed, same schedule");
        assert_ne!(a, drain(43), "seeds must differ");
        assert!(!a.is_empty(), "an hour at 5-min gaps must crash something");
        assert!(a.iter().all(|(t, _)| *t < end));
        // Only edge nodes appear (cloud: false), and per-node the
        // crash/rejoin events alternate.
        for (_, ev) in &a {
            match ev {
                Event::NodeCrash { node } | Event::NodeRejoin { node } => {
                    assert!(node.0 < 2, "cloud node crashed with cloud: false");
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        for n in 0..2u32 {
            let mut expect_crash = true;
            for (_, ev) in a.iter().filter(|(_, ev)| {
                matches!(ev,
                    Event::NodeCrash { node } | Event::NodeRejoin { node }
                        if node.0 == n)
            }) {
                match ev {
                    Event::NodeCrash { .. } => {
                        assert!(expect_crash, "double crash for node {n}");
                        expect_crash = false;
                    }
                    Event::NodeRejoin { .. } => {
                        assert!(!expect_crash, "rejoin before crash for node {n}");
                        expect_crash = true;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn pod_chaos_perturbs_init_delay() {
        let mut pc = PodChaos::new(
            Pcg64::new(7, chaos_pod_stream(0)),
            Some(ColdStartPlan {
                slow_prob: 0.5,
                factor_min: 2.0,
                factor_max: 4.0,
            }),
            Some(CrashLoopPlan {
                prob: 0.3,
                max_restarts: 3,
            }),
        );
        let base = super::super::INIT_DELAY_MIN;
        let delays: Vec<Time> = (0..200).map(|_| pc.perturb_init_delay(base)).collect();
        assert!(delays.iter().all(|&d| d >= base), "never faster than base");
        assert!(
            delays.iter().any(|&d| d > base),
            "perturbation never fired in 200 draws"
        );
        assert_eq!(pc.init_delays.n(), 200);
        assert!(pc.crash_loops > 0, "crash loops never fired");
        // Crash loops bounded: worst case base*4 + 3 extra full delays.
        let cap = base * 4 + 3 * (super::super::INIT_DELAY_MAX + 1);
        assert!(delays.iter().all(|&d| d <= cap));

        // A plan-free PodChaos is the identity.
        let mut inert = PodChaos::new(Pcg64::new(7, 2), None, None);
        assert_eq!(inert.perturb_init_delay(base), base);
        assert_eq!(inert.crash_loops, 0);
    }

    #[test]
    fn net_chaos_draws_within_bounds() {
        let plan = NetDelayPlan {
            extra_min: 20_000,
            extra_max: 200_000,
        };
        let mut nc = NetChaos::new(Pcg64::new(11, chaos_net_stream(0)), &plan);
        for _ in 0..100 {
            let extra = nc.draw_extra();
            assert!((plan.extra_min..=plan.extra_max).contains(&extra));
        }
    }

    #[test]
    fn counters_merge() {
        let mut a = ChaosCounters {
            crashes: 2,
            rejoins: 1,
            pods_killed: 5,
            pods_rescheduled: 4,
            crash_loops: 3,
            downtime: 90 * SEC,
            ..ChaosCounters::default()
        };
        a.init_delays.record(12.0);
        let mut b = ChaosCounters {
            crashes: 1,
            downtime: 30 * SEC,
            ..ChaosCounters::default()
        };
        b.init_delays.record(48.0);
        a.merge(&b);
        assert_eq!(a.crashes, 3);
        assert_eq!(a.rejoins, 1);
        assert_eq!(a.pods_killed, 5);
        assert_eq!(a.downtime, 120 * SEC);
        assert_eq!(a.init_delays.n(), 2);
        assert!(a.cold_start_p95() > 12.0);
    }
}

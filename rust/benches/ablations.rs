//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * HPA baseline strength: full K8s semantics vs the paper's bare Eq 1.
//! * PPA static policy: literal Eq-1-on-prediction vs conservative ceil.
//! * PPA downscale stabilization window: 0 / 1 min / 2 min / 5 min.
//! * Injected model: naive / ARMA / LSTM on the identical NASA workload.
//!
//! Each cell replays the same seeded NASA trace and reports Sort mean
//! response + system RIR. Run with `cargo bench --bench ablations`
//! (scale via PPA_ABLATION_HOURS, default 4).

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::ppa::{ConservativeCeilPolicy, HpaCeilPolicy, StaticPolicy};
use ppa_edge::autoscaler::{Autoscaler, Hpa, Ppa, PpaConfig, ScalingBehavior};
use ppa_edge::config::paper_cluster;
use ppa_edge::experiments::{make_forecaster, pretrain_histories, try_runtime, ModelKind, SimWorld};
use ppa_edge::forecast::UpdatePolicy;
use ppa_edge::sim::{Time, HOUR, MIN, SEC};
use ppa_edge::stats::summarize;
use ppa_edge::workload::{nasa_synthetic, Generator, NasaTraceConfig, TraceGen};
use std::sync::Arc;

struct Cell {
    label: String,
    sort_mean: f64,
    sort_std: f64,
    eigen_mean: f64,
    rir_mean: f64,
    wall_s: f64,
}

fn run_world(
    label: &str,
    counts: &Arc<Vec<f64>>,
    hours: f64,
    mut make_scaler: impl FnMut(usize) -> Box<dyn Autoscaler>,
) -> Cell {
    let cfg = paper_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 2021);
    world.add_generator(Generator::Trace(TraceGen::new(1, counts.clone(), 0.5)));
    world.add_generator(Generator::Trace(TraceGen::new(2, counts.clone(), 0.5)));
    for svc in 0..world.app.services.len() {
        world.add_scaler(make_scaler(svc), svc);
    }
    let wall = std::time::Instant::now();
    world.run_until((hours * HOUR as f64) as Time);
    let sort = world.app.stats.sort.summary();
    let eigen = world.app.stats.eigen.summary();
    let rirs: Vec<f64> = world.rir_log.iter().map(|s| s.rir).collect();
    Cell {
        label: label.to_string(),
        sort_mean: sort.mean,
        sort_std: sort.std,
        eigen_mean: eigen.mean,
        rir_mean: summarize(&rirs).mean,
        wall_s: wall.elapsed().as_secs_f64(),
    }
}

fn print_cells(title: &str, cells: &[Cell]) {
    println!("\n### {title}");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "configuration", "sort mean", "sort std", "eigen", "RIR", "wall"
    );
    println!("{}", "-".repeat(96));
    for c in cells {
        println!(
            "{:<44} {:>9.4}s {:>9.4}s {:>9.3}s {:>8.3} {:>7.1}s",
            c.label, c.sort_mean, c.sort_std, c.eigen_mean, c.rir_mean, c.wall_s
        );
    }
}

fn main() -> anyhow::Result<()> {
    let hours: f64 = std::env::var("PPA_ABLATION_HOURS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4.0);
    println!("ablation benches: {hours} h NASA replays (PPA_ABLATION_HOURS to change)");
    let counts = Arc::new(nasa_synthetic(&NasaTraceConfig::default()));

    // --- HPA baseline strength -------------------------------------------
    let mut cells = Vec::new();
    cells.push(run_world("hpa: full k8s semantics", &counts, hours, |_| {
        Box::new(Hpa::with_defaults())
    }));
    cells.push(run_world("hpa: bare Eq 1 (paper text)", &counts, hours, |_| {
        Box::new(Hpa::pure_eq1(70.0, 15 * SEC))
    }));
    print_cells("HPA baseline ablation", &cells);

    // --- PPA variants (need artifacts) -----------------------------------
    let Some(runtime) = try_runtime() else {
        println!("\nLSTM artifacts missing — PPA ablations need `make artifacts`.");
        return Ok(());
    };
    let (hist, _) = pretrain_histories(2.0, 20, 2021);

    let ppa_with = |svc: usize,
                    model: ModelKind,
                    stab: Time,
                    policy: Box<dyn StaticPolicy>|
     -> Box<dyn Autoscaler> {
        let pre = if svc == 1 { &hist[0] } else { &hist[svc.min(1)] };
        let pre = if svc + 1 == 3 { hist.last().unwrap() } else { pre };
        let forecaster = make_forecaster(model, Some(&runtime), pre, 2021).unwrap();
        let cfg = PpaConfig {
            update_policy: UpdatePolicy::FineTune,
            behavior: ScalingBehavior::stabilize_down(stab),
            ..PpaConfig::default()
        };
        Box::new(Ppa::new(cfg, forecaster).with_policy(policy))
    };

    let mut cells = Vec::new();
    for (label, stab) in [
        ("ppa: stabilization 0", 0),
        ("ppa: stabilization 1 min", MIN),
        ("ppa: stabilization 2 min (default)", 2 * MIN),
        ("ppa: stabilization 5 min", 5 * MIN),
    ] {
        cells.push(run_world(label, &counts, hours, |svc| {
            ppa_with(svc, ModelKind::Lstm, stab, Box::new(ConservativeCeilPolicy))
        }));
    }
    print_cells("PPA stabilization-window ablation", &cells);

    let mut cells = Vec::new();
    cells.push(run_world("ppa: conservative ceil (default)", &counts, hours, |svc| {
        ppa_with(svc, ModelKind::Lstm, 2 * MIN, Box::new(ConservativeCeilPolicy))
    }));
    cells.push(run_world("ppa: literal Eq1-on-prediction", &counts, hours, |svc| {
        ppa_with(svc, ModelKind::Lstm, 2 * MIN, Box::new(HpaCeilPolicy))
    }));
    print_cells("PPA static-policy ablation", &cells);

    let mut cells = Vec::new();
    for model in [ModelKind::Naive, ModelKind::Arma, ModelKind::Lstm] {
        cells.push(run_world(
            &format!("ppa model: {}", model.name()),
            &counts,
            hours,
            |svc| ppa_with(svc, model, 2 * MIN, Box::new(ConservativeCeilPolicy)),
        ));
    }
    print_cells("PPA injected-model ablation", &cells);

    Ok(())
}

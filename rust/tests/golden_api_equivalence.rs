//! Golden decision-log equivalence for the multi-metric API redesign.
//!
//! The redesign moved `Hpa` and `Ppa` onto the spec → recommendation →
//! combine → behavior pipeline. These tests pin that a single-metric
//! `cpu:70` [`MetricSpec`] reproduces the *pre-redesign* decision
//! sequences bit-identically on the paper scenario (Table-2 topology,
//! Random-Access workload on both zones): `LegacyHpa`/`LegacyPpa` below
//! are verbatim ports of the old monolithic `evaluate` bodies, and a
//! world driven by them must match a world driven by the redesigned
//! scalers decision-for-decision — and therefore event-for-event and
//! response-for-response.

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::{eq1_replicas, Autoscaler, Hpa, Ppa, PpaConfig, ScaleDecision};
use ppa_edge::cluster::{Cluster, DeploymentId};
use ppa_edge::config::paper_cluster;
use ppa_edge::experiments::SimWorld;
use ppa_edge::forecast::{ArmaForecaster, Forecaster, NaiveForecaster, UpdatePolicy};
use ppa_edge::metrics::{MetricsPipeline, M_CPU, METRIC_DIM};
use ppa_edge::sim::{ServiceId, Time, HOUR, MIN, SEC};
use ppa_edge::workload::{Generator, RandomAccessGen};
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Legacy reference implementations (pre-redesign logic, ported verbatim)
// ---------------------------------------------------------------------------

/// The old `Hpa::evaluate`: one hard-wired key metric, tolerance band,
/// inline scale-down stabilization deque.
struct LegacyHpa {
    key_metric: usize,
    threshold: f64,
    sync_period: Time,
    tolerance: f64,
    stabilization_window: Time,
    recent_desired: VecDeque<(Time, usize)>,
}

impl LegacyHpa {
    fn with_defaults() -> Self {
        LegacyHpa {
            key_metric: M_CPU,
            threshold: 70.0,
            sync_period: 15 * SEC,
            tolerance: 0.1,
            stabilization_window: 5 * MIN,
            recent_desired: VecDeque::new(),
        }
    }
}

impl Autoscaler for LegacyHpa {
    fn name(&self) -> &str {
        "legacy-hpa"
    }

    fn control_interval(&self) -> Time {
        self.sync_period
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        let key_value = metrics.latest_metric(service, self.key_metric);
        let current = cluster.live_replicas(target).max(1);

        let ratio = key_value / (self.threshold * current as f64);
        let mut desired = if (ratio - 1.0).abs() <= self.tolerance {
            current
        } else {
            eq1_replicas(key_value, self.threshold).max(1)
        };

        if self.stabilization_window > 0 {
            self.recent_desired.push_back((now, desired));
            let cutoff = now.saturating_sub(self.stabilization_window);
            while matches!(self.recent_desired.front(), Some(&(t, _)) if t < cutoff) {
                self.recent_desired.pop_front();
            }
            if desired < current {
                let stabilized = self
                    .recent_desired
                    .iter()
                    .map(|&(_, d)| d)
                    .max()
                    .unwrap_or(desired);
                desired = stabilized.min(current);
            }
        }

        ScaleDecision {
            desired,
            key_value,
            predicted: None,
            used_fallback: false,
            recommendations: Vec::new(),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The old `Ppa::evaluate`: formulator history + Algorithm 1 with the
/// conservative-ceil policy on one key metric + inline downscale
/// stabilization; the old `Updater::run` inside `model_update`.
struct LegacyPpa {
    key_metric: usize,
    threshold: f64,
    control_interval: Time,
    update_interval: Time,
    downscale_stabilization: Time,
    forecaster: Box<dyn Forecaster>,
    history: Vec<[f64; METRIC_DIM]>,
    recent_desired: VecDeque<(Time, usize)>,
}

impl LegacyPpa {
    fn new(forecaster: Box<dyn Forecaster>, update_interval: Time) -> Self {
        LegacyPpa {
            key_metric: M_CPU,
            threshold: 70.0,
            control_interval: 20 * SEC,
            update_interval,
            downscale_stabilization: 2 * MIN,
            forecaster,
            history: Vec::new(),
            recent_desired: VecDeque::new(),
        }
    }
}

impl Autoscaler for LegacyPpa {
    fn name(&self) -> &str {
        "legacy-ppa"
    }

    fn control_interval(&self) -> Time {
        self.control_interval
    }

    fn update_interval(&self) -> Option<Time> {
        Some(self.update_interval)
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        // Formulator (HISTORY_CAP = 40_000 is unreachable in-test).
        let vector = metrics.latest_vector(service);
        self.history.push(vector);
        self.forecaster.observe(&vector);

        // Evaluator — Algorithm 1.
        let current_key = vector[self.key_metric];
        let max_replicas = cluster.max_replicas(target);
        let (key_value, predicted, used_fallback) = match self.forecaster.predict(&self.history)
        {
            Some(pred) => (pred[self.key_metric], Some(pred[self.key_metric]), false),
            None => (current_key, None, true),
        };
        // ConservativeCeilPolicy, then the resource cap.
        let mut desired = eq1_replicas(key_value.max(current_key), self.threshold)
            .max(1)
            .min(max_replicas)
            .max(1);

        // Control-plane downscale stabilization (short window).
        if self.downscale_stabilization > 0 {
            self.recent_desired.push_back((now, desired));
            let cutoff = now.saturating_sub(self.downscale_stabilization);
            while matches!(self.recent_desired.front(), Some(&(t, _)) if t < cutoff) {
                self.recent_desired.pop_front();
            }
            let current = cluster.live_replicas(target);
            if desired < current {
                let stabilized = self
                    .recent_desired
                    .iter()
                    .map(|&(_, d)| d)
                    .max()
                    .unwrap_or(desired);
                desired = stabilized.min(current);
            }
        }

        ScaleDecision {
            desired,
            key_value,
            predicted,
            used_fallback,
            recommendations: Vec::new(),
        }
    }

    fn model_update(&mut self, _now: Time) -> ppa_edge::Result<()> {
        // Old Updater::run — MIN_RECORDS gate, clear-on-success.
        if self.history.len() < 16 {
            return Ok(());
        }
        self.forecaster.retrain(&self.history, UpdatePolicy::FineTune)?;
        self.history.clear();
        Ok(())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

/// The paper scenario: Table-2 cluster, Random Access on both zones.
fn paper_world(seed: u64) -> SimWorld {
    let cfg = paper_cluster();
    let mut w = SimWorld::build(&cfg, TaskCosts::default(), seed);
    w.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
    w.add_generator(Generator::RandomAccess(RandomAccessGen::new(2)));
    w
}

/// Run two worlds (same seed, different scaler builders) and assert
/// bit-identical decisions and world evolution.
fn assert_equivalent(
    seed: u64,
    minutes: u64,
    mut new_scaler: impl FnMut(usize) -> Box<dyn Autoscaler>,
    mut legacy_scaler: impl FnMut(usize) -> Box<dyn Autoscaler>,
) {
    let mut new_world = paper_world(seed);
    let mut legacy_world = paper_world(seed);
    new_world.record_decisions();
    legacy_world.record_decisions();
    let n_services = new_world.app.services.len();
    assert_eq!(n_services, 3, "paper topology: z1 + z2 + cloud");
    for svc in 0..n_services {
        new_world.add_scaler(new_scaler(svc), svc);
        legacy_world.add_scaler(legacy_scaler(svc), svc);
    }
    new_world.run_until(minutes * MIN);
    legacy_world.run_until(minutes * MIN);

    // Decision-log equivalence, per service, time-for-time.
    for svc in 0..n_services {
        let new_d = new_world.decisions_for(svc);
        let legacy_d = legacy_world.decisions_for(svc);
        assert!(!new_d.is_empty(), "service {svc} made no decisions");
        assert_eq!(
            new_d, legacy_d,
            "service {svc}: redesigned pipeline must reproduce the legacy \
             decision sequence bit-identically"
        );
    }
    // Decision-identical scalers ⇒ identical worlds.
    assert_eq!(new_world.events_processed, legacy_world.events_processed);
    assert_eq!(new_world.app.completed(), legacy_world.app.completed());
    assert_eq!(
        new_world.app.stats.fingerprint(),
        legacy_world.app.stats.fingerprint(),
        "bit-identical response streams"
    );
}

#[test]
fn golden_hpa_single_metric_matches_legacy() {
    assert_equivalent(
        2021,
        40,
        |_| Box::new(Hpa::with_defaults()),
        |_| Box::new(LegacyHpa::with_defaults()),
    );
}

#[test]
fn golden_ppa_naive_single_metric_matches_legacy() {
    // Naive model, default config (update loop at 1 h never fires in a
    // 40-minute run — matching schedules on both sides).
    assert_equivalent(
        2021,
        40,
        |_| Box::new(Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster))),
        |_| Box::new(LegacyPpa::new(Box::new(NaiveForecaster), HOUR)),
    );
}

#[test]
fn golden_ppa_arma_with_update_loop_matches_legacy() {
    // ARMA trained online by the 10-minute update loop: exercises the
    // fallback path (model-less start), live retrains with history
    // clearing, and real forecast-driven decisions — all of which must
    // survive the redesign unchanged.
    let update = 10 * MIN;
    assert_equivalent(
        7,
        35,
        move |_| {
            Box::new(Ppa::new(
                PpaConfig {
                    update_interval: update,
                    ..PpaConfig::default()
                },
                Box::new(ArmaForecaster::new()),
            ))
        },
        move |_| Box::new(LegacyPpa::new(Box::new(ArmaForecaster::new()), update)),
    );
}

//! Scenario-matrix driver: the `model_comparison`-style example for the
//! parallel sweep harness. Runs PPA (ARMA, trained online, plus the naive
//! last-value model) against HPA over a topology's full preset scenario
//! library — the Table-2 presets on `paper`, the generated N-zone
//! composites on `city-N[xW]` — across several seeds, in parallel, and
//! writes a JSON report.
//!
//! ```bash
//! cargo run --release --example scenario_sweep              # 30 min cells, 4 seeds, paper
//! cargo run --release --example scenario_sweep -- 60 8      # 60 min cells, 8 seeds
//! cargo run --release --example scenario_sweep -- 30 2 city-50   # city-scale grid
//! cargo run --release --example scenario_sweep -- 30 2 city-8 cpu:70,req_rate:1.5
//! #   ^ every cell scales its fleet on BOTH metrics (max wins)
//! ```

use ppa_edge::autoscaler::{MetricSource, MetricSpec, ScalerPolicy, ScalerRegistry};
use ppa_edge::cluster::FaultPlan;
use ppa_edge::config::Topology;
use ppa_edge::experiments::{run_sweep, AutoscalerKind, SweepConfig};
use ppa_edge::report;
use ppa_edge::sim::CoreKind;

fn main() -> anyhow::Result<()> {
    let minutes: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);
    let n_seeds: u64 = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(4);
    let topology = match std::env::args().nth(3) {
        Some(s) => Topology::parse(&s)?,
        None => Topology::Paper,
    };
    // Optional 4th arg: comma-separated metric specs for a uniform
    // fleet, e.g. `cpu:70,req_rate:1.5`.
    let fleet = match std::env::args().nth(4) {
        Some(list) => {
            let specs = list
                .split(',')
                .map(|s| MetricSpec::parse(s.trim(), MetricSource::Forecast))
                .collect::<anyhow::Result<Vec<_>>>()?;
            // Metric-only policy: each scaler kind keeps its stock
            // behavior (HPA 5-min / PPA 2-min down window).
            let policy = ScalerPolicy::from_specs(specs);
            println!("fleet policy: {}", policy.label());
            Some(ScalerRegistry::uniform(policy))
        }
        None => None,
    };

    let cfg = SweepConfig {
        topology,
        scenarios: topology.scenario_presets(),
        scalers: vec![
            AutoscalerKind::Hpa,
            AutoscalerKind::PpaArma,
            AutoscalerKind::PpaNaive,
        ],
        seeds: (0..n_seeds).map(|i| 2021 + i).collect(),
        minutes,
        threads: 0, // one worker per core
        core: CoreKind::Calendar,
        fleet,
        shards: 0, // monolith engine; >=1 selects the sharded cores
        chaos: FaultPlan::none(), // see `--chaos` on the ppa-edge binary for faulted sweeps
    };
    println!(
        "scenario sweep: {} scenarios x {} autoscalers x {} seeds on {} ({} sim-minutes per cell)",
        cfg.scenarios.len(),
        cfg.scalers.len(),
        cfg.seeds.len(),
        topology.label(),
        minutes
    );

    let result = run_sweep(&cfg)?;
    report::print_sweep(&result);

    let out = std::path::Path::new("target/experiments/scenario_sweep.json");
    result.write_json(out)?;
    println!("json report: {}", out.display());
    Ok(())
}

//! Statistics for the experiment harness: summary moments, MSE, Welch's
//! t-test (the paper reports p < 1e-3 on every evaluation comparison),
//! percentiles and histograms. Special functions (log-gamma, regularized
//! incomplete beta) are implemented from scratch — no stats crate offline.
//!
//! Two families live here:
//!
//! * **Batch** — [`summarize`], [`percentile`], [`histogram`],
//!   [`welch_t_test`] over collected `&[f64]` samples; used by the
//!   paper-figure harnesses, which retain exact traces.
//! * **Streaming** — [`StreamingStats`]/[`LogHistogram`]
//!   (`streaming` module): single-pass Welford moments plus fixed-bin
//!   log-histogram quantiles in constant memory. This is what the DES
//!   hot path records completed requests into, so city-scale sweep
//!   cells never accumulate an unbounded response log. See the
//!   `streaming` module docs for the binning and determinism rules.

mod streaming;

pub use streaming::{
    LogHistogram, StreamingStats, LOG_HIST_BINS_PER_OCTAVE, LOG_HIST_MIN, LOG_HIST_OCTAVES,
};

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator).
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute mean / sample-std / extrema.
pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: xs.iter().copied().fold(f64::INFINITY, f64::min),
        max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Mean squared error between two equally long series (the paper's model
/// comparison metric, Figs 7–8).
pub fn mse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len(), "mse length mismatch");
    if pred.is_empty() {
        return f64::NAN;
    }
    pred.iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum::<f64>()
        / pred.len() as f64
}

/// Result of a two-sample Welch t-test.
#[derive(Debug, Clone, Copy)]
pub struct WelchResult {
    pub t: f64,
    pub df: f64,
    /// Two-tailed p-value.
    pub p: f64,
}

/// Welch's unequal-variance t-test (two-tailed).
pub fn welch_t_test(a: &[f64], b: &[f64]) -> WelchResult {
    let sa = summarize(a);
    let sb = summarize(b);
    let va = sa.std * sa.std / sa.n as f64;
    let vb = sb.std * sb.std / sb.n as f64;
    let se = (va + vb).sqrt();
    if se == 0.0 || sa.n < 2 || sb.n < 2 {
        return WelchResult {
            t: f64::NAN,
            df: f64::NAN,
            p: f64::NAN,
        };
    }
    let t = (sa.mean - sb.mean) / se;
    // Welch–Satterthwaite degrees of freedom.
    let df = (va + vb) * (va + vb)
        / (va * va / (sa.n as f64 - 1.0) + vb * vb / (sb.n as f64 - 1.0));
    let p = 2.0 * student_t_sf(t.abs(), df);
    WelchResult { t, df, p }
}

/// Survival function of Student's t: `P(T > t)` for `t >= 0`.
pub fn student_t_sf(t: f64, df: f64) -> f64 {
    if !t.is_finite() || !df.is_finite() || df <= 0.0 {
        return f64::NAN;
    }
    let x = df / (df + t * t);
    0.5 * inc_beta(0.5 * df, 0.5, x)
}

/// Log-gamma via the Lanczos approximation (g = 7, n = 9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta function `I_x(a, b)` via Lentz's continued
/// fraction (Numerical Recipes `betai`).
pub fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    if !(0.0..=1.0).contains(&x) {
        return f64::NAN;
    }
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - front * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// p-th percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Fixed-bin histogram over [min, max] (for the figure-style distribution
/// outputs).
pub fn histogram(xs: &[f64], bins: usize, min: f64, max: f64) -> Vec<(f64, usize)> {
    assert!(bins > 0 && max > min);
    let width = (max - min) / bins as f64;
    let mut counts = vec![0usize; bins];
    for &x in xs {
        if x < min || !x.is_finite() {
            continue;
        }
        let idx = (((x - min) / width) as usize).min(bins - 1);
        counts[idx] += 1;
    }
    counts
        .into_iter()
        .enumerate()
        .map(|(i, c)| (min + (i as f64 + 0.5) * width, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(summarize(&[]).mean.is_nan());
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert_eq!(mse(&[3.0], &[3.0]), 0.0);
    }

    #[test]
    fn ln_gamma_matches_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-10);
        assert!(ln_gamma(2.0).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn inc_beta_symmetry_and_bounds() {
        assert_eq!(inc_beta(2.0, 3.0, 0.0), 0.0);
        assert_eq!(inc_beta(2.0, 3.0, 1.0), 1.0);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        let v = inc_beta(2.5, 1.5, 0.3);
        let w = 1.0 - inc_beta(1.5, 2.5, 0.7);
        assert!((v - w).abs() < 1e-12);
        // I_x(1,1) = x (uniform)
        assert!((inc_beta(1.0, 1.0, 0.42) - 0.42).abs() < 1e-12);
    }

    #[test]
    fn student_t_sf_known_values() {
        // scipy.stats.t.sf(1.0, 10) = 0.17044656615103004
        assert!((student_t_sf(1.0, 10.0) - 0.170446566).abs() < 1e-8);
        // scipy.stats.t.sf(2.0, 30) = 0.027312522481491547
        assert!((student_t_sf(2.0, 30.0) - 0.0273125225).abs() < 1e-6);
        // Large df approaches the normal: t.sf(1.96, 1e6) ≈ 0.0250
        assert!((student_t_sf(1.96, 1e6) - 0.025).abs() < 1e-4);
    }

    #[test]
    fn welch_detects_difference() {
        let mut rng = Pcg64::new(5, 0);
        let a: Vec<f64> = (0..500).map(|_| rng.normal_ms(0.592, 0.067)).collect();
        let b: Vec<f64> = (0..500).map(|_| rng.normal_ms(0.508, 0.038)).collect();
        let r = welch_t_test(&a, &b);
        assert!(r.p < 1e-3, "p={}", r.p);
        assert!(r.t > 0.0);
    }

    #[test]
    fn welch_no_difference() {
        let mut rng = Pcg64::new(6, 0);
        let a: Vec<f64> = (0..300).map(|_| rng.normal_ms(1.0, 0.1)).collect();
        let b: Vec<f64> = (0..300).map(|_| rng.normal_ms(1.0, 0.1)).collect();
        let r = welch_t_test(&a, &b);
        assert!(r.p > 0.01, "identical populations should not differ: p={}", r.p);
    }

    #[test]
    fn welch_degenerate_inputs() {
        let r = welch_t_test(&[1.0], &[2.0, 3.0]);
        assert!(r.p.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.5, 0.9, 1.5, f64::NAN];
        let h = histogram(&xs, 2, 0.0, 1.0);
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].1, 2); // 0.1, 0.2 in [0, 0.5)
        assert_eq!(h[1].1, 3); // 0.5, 0.9 in [0.5, 1.0]; 1.5 clamps to last bin
        assert!((h[0].0 - 0.25).abs() < 1e-12, "bin centers");
    }
}

//! The simulation driver: wires cluster + app + metrics + workload +
//! autoscalers into one event loop (the whole Fig 3 system).

use crate::app::{App, TaskCosts};
use crate::autoscaler::{Autoscaler, Recommendation};
use crate::cluster::{
    chaos_net_stream, chaos_pod_stream, chaos_schedule_stream, schedule_node_faults,
    ChaosCounters, Cluster, DeploymentId, FaultPlan, NetChaos, PodChaos,
};
use crate::config::ClusterConfig;
use crate::metrics::{MetricsPipeline, DEFAULT_SCRAPE_INTERVAL};
use crate::sim::{CoreKind, Event, EventQueue, ServiceId, Time};
use crate::util::rng::Pcg64;
use crate::workload::Generator;

/// An autoscaler bound to its target service/deployment.
pub struct ScalerBinding {
    pub autoscaler: Box<dyn Autoscaler>,
    pub service: ServiceId,
    pub deployment: DeploymentId,
}

/// Per-scrape RIR sample for one service (Figs 10, 13, 14).
#[derive(Debug, Clone, Copy)]
pub struct RirSample {
    pub time: Time,
    pub service: ServiceId,
    pub rir: f64,
}

/// One control-loop decision as the driver applied it — the structured
/// per-metric decision log every harness can read (golden-equivalence
/// tests diff these sequences; the sweep summarizes them).
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    pub time: Time,
    pub service: ServiceId,
    /// The behavior-clamped count handed to `Cluster::reconcile`.
    pub desired: usize,
    /// True when Algorithm 1 fell back to current metrics.
    pub used_fallback: bool,
    /// Per-[`crate::autoscaler::MetricSpec`] provenance, in spec order.
    pub recommendations: Vec<Recommendation>,
}

/// The assembled world.
pub struct SimWorld {
    pub queue: EventQueue,
    pub cluster: Cluster,
    pub app: App,
    pub metrics: MetricsPipeline,
    pub generators: Vec<Generator>,
    pub scalers: Vec<ScalerBinding>,
    pub rir_log: Vec<RirSample>,
    /// (time, service, replicas) per scrape — replica-trajectory data.
    pub replica_log: Vec<(Time, ServiceId, usize)>,
    /// Every autoscaler decision with per-metric provenance. Opt-in
    /// (like the exact response log): empty unless
    /// [`Self::record_decisions`] was called before the run, so sweep
    /// cells keep their flat-memory guarantee.
    pub decision_log: Vec<DecisionRecord>,
    /// Whether [`Self::decision_log`] is populated.
    log_decisions: bool,
    rng_cluster: Pcg64,
    rng_service: Pcg64,
    rng_workload: Pcg64,
    scrape_interval: Time,
    /// Chaos-plane fault counters (all zero on fault-free runs). The
    /// pod-chaos contributions are folded in by
    /// [`Self::chaos_summary`], not here.
    pub chaos: ChaosCounters,
    /// Crash time per node index while it is down (downtime accounting).
    crashed_at: Vec<Option<Time>>,
    /// Events processed (perf counter).
    pub events_processed: u64,
    /// Whether the initial periodic ticks have been armed. Guarding on
    /// `events_processed == 0` is wrong: a first `run_until` that happens
    /// to process zero events (empty window) would re-arm every initial
    /// tick on the next call, duplicating the Scrape/AutoscaleTick/
    /// WorkloadTick streams.
    started: bool,
}

impl SimWorld {
    /// Build from a cluster config. Deployment order in the config maps
    /// to services: all edge deployments (each with its zone), then the
    /// last deployment as the cloud Eigen pool. Runs on the default
    /// calendar event core; see [`SimWorld::build_with_core`].
    pub fn build(cfg: &ClusterConfig, costs: TaskCosts, seed: u64) -> Self {
        SimWorld::build_with_core(cfg, costs, seed, CoreKind::Calendar)
    }

    /// [`SimWorld::build`] on an explicit event-queue core. The heap
    /// core is the golden reference: for equal `(cfg, costs, seed)` both
    /// cores produce bit-identical runs (asserted by the
    /// core-equivalence tests here and in the sweep harness).
    pub fn build_with_core(
        cfg: &ClusterConfig,
        costs: TaskCosts,
        seed: u64,
        core: CoreKind,
    ) -> Self {
        let (mut cluster, dep_ids) = cfg.build();
        assert!(
            dep_ids.len() >= 2,
            "need at least one edge and one cloud deployment"
        );
        let edge: Vec<(u32, DeploymentId)> = cfg.deployments[..dep_ids.len() - 1]
            .iter()
            .zip(&dep_ids)
            .map(|(d, &id)| (d.zone.expect("edge deployments must set zone"), id))
            .collect();
        let cloud = *dep_ids.last().unwrap();
        let app = App::new(costs, &edge, cloud);
        // Handle bundles are interned under the real service names here,
        // so every later scrape is a pure handle-push (no allocation).
        let burn = costs.base_burn_frac;
        let metrics = MetricsPipeline::for_app(DEFAULT_SCRAPE_INTERVAL, &app, burn);

        let mut queue = EventQueue::with_core(core);
        let mut rng_cluster = Pcg64::new(seed, 1);
        // Initial replicas.
        for (dcfg, &id) in cfg.deployments.iter().zip(&dep_ids) {
            cluster.reconcile(id, dcfg.initial_replicas, &mut queue, &mut rng_cluster);
        }

        let crashed_at = vec![None; cluster.nodes.len()];
        SimWorld {
            queue,
            cluster,
            app,
            metrics,
            generators: Vec::new(),
            scalers: Vec::new(),
            rir_log: Vec::new(),
            replica_log: Vec::new(),
            decision_log: Vec::new(),
            log_decisions: false,
            rng_cluster,
            rng_service: Pcg64::new(seed, 2),
            rng_workload: Pcg64::new(seed, 3),
            scrape_interval: DEFAULT_SCRAPE_INTERVAL,
            chaos: ChaosCounters::default(),
            crashed_at,
            events_processed: 0,
            started: false,
        }
    }

    /// Install a fault plan for a run ending at `end` (call before the
    /// first [`Self::run_until`]). An empty plan is a strict no-op —
    /// no RNG construction, no events, no state change — so fault-free
    /// runs stay bit-identical to builds without the chaos plane. All
    /// fault randomness comes from the dedicated chaos streams keyed by
    /// `seed` (world index 0 — the monolith), never from the engine
    /// streams.
    pub fn install_chaos(&mut self, plan: &FaultPlan, seed: u64, end: Time) {
        if plan.is_empty() {
            return;
        }
        if let Some(nc) = &plan.node_crash {
            let mut rng = Pcg64::new(seed, chaos_schedule_stream(0));
            schedule_node_faults(&self.cluster, nc, end, &mut rng, &mut self.queue);
        }
        if plan.cold_start.is_some() || plan.crash_loop.is_some() {
            self.cluster.set_pod_chaos(Some(PodChaos::new(
                Pcg64::new(seed, chaos_pod_stream(0)),
                plan.cold_start,
                plan.crash_loop,
            )));
        }
        if let Some(nd) = &plan.net_delay {
            self.app
                .set_net_chaos(Some(NetChaos::new(Pcg64::new(seed, chaos_net_stream(0)), nd)));
        }
    }

    /// Install an SLA policy + priority mix for the whole run (call
    /// before the first [`Self::run_until`], mirroring
    /// [`Self::install_chaos`]). Absent policy is a strict no-op — no
    /// RNG construction, no timeout events, no priority draws — so
    /// SLA-free runs stay bit-identical to pre-resilience builds. All
    /// SLA randomness (priority draws, backoff jitter) comes from the
    /// dedicated `sla_stream` keyed by `seed` (world index 0 — the
    /// monolith), never from the engine streams.
    pub fn install_sla(&mut self, cfg: &crate::app::SlaConfig, seed: u64) {
        self.app.install_sla(cfg, seed, 0);
    }

    /// Resilience-plane counters + per-class response stats (all zero /
    /// empty when no SLA policy is installed).
    pub fn sla_summary(&self) -> crate::app::SlaSummary {
        self.app.sla_summary()
    }

    /// Cost ledger: cluster node-hours up to `end`, with per-node
    /// downtime (chaos-plane `Node::up` gaps) excluded — a crashed node
    /// stops billing until it rejoins. The other ledger half,
    /// [`crate::cluster::Cluster::pod_churn`], is read directly.
    pub fn cost_node_hours(&self, end: Time) -> f64 {
        let gross = self.cluster.nodes.len() as u64 * end;
        let down = self.chaos_summary(end).downtime;
        crate::sim::to_secs(gross.saturating_sub(down)) / 3600.0
    }

    /// The run's fault counters with end-of-run finalization: nodes
    /// still down at `end` contribute their remaining downtime, and the
    /// pod-chaos restart/init-delay stats are folded in. Non-destructive
    /// (returns a merged clone).
    pub fn chaos_summary(&self, end: Time) -> ChaosCounters {
        let mut out = self.chaos.clone();
        for t in self.crashed_at.iter().flatten() {
            out.downtime += end.saturating_sub(*t);
        }
        if let Some(pc) = self.cluster.pod_chaos() {
            out.crash_loops += pc.crash_loops;
            out.init_delays.merge(&pc.init_delays);
        }
        out
    }

    /// Register a workload generator (started by [`Self::run_until`]).
    pub fn add_generator(&mut self, gen: Generator) {
        self.generators.push(gen);
    }

    /// Turn on the exact per-request response log (unbounded memory).
    /// The streaming [`crate::app::ResponseStats`] are always on; only
    /// harnesses that need full traces (paper figures, CSV dumps,
    /// [`Self::response_times`]) should call this before running.
    pub fn record_responses(&mut self) {
        self.app.retain_responses();
    }

    /// Turn on the structured per-metric decision log (one
    /// [`DecisionRecord`] per autoscaler tick — unbounded over long
    /// runs, so it is opt-in like the response log). Call before the
    /// run; read via [`Self::decision_log`] / [`Self::decisions_for`].
    pub fn record_decisions(&mut self) {
        self.log_decisions = true;
    }

    /// Switch the cluster between its indexed query plane and the
    /// retained scan baseline ([`crate::cluster::QueryMode`]). Both
    /// modes are decision-bit-identical; the golden-equivalence tests
    /// and the hot-path bench run `Scan` worlds as the reference.
    pub fn set_cluster_query_mode(&mut self, mode: crate::cluster::QueryMode) {
        self.cluster.set_query_mode(mode);
    }

    /// Bind an autoscaler to service index `service_idx` (== deployment
    /// order in the config).
    pub fn add_scaler(&mut self, autoscaler: Box<dyn Autoscaler>, service_idx: usize) {
        let service = ServiceId(service_idx as u32);
        let deployment = self.app.services[service_idx].deployment;
        self.scalers.push(ScalerBinding {
            autoscaler,
            service,
            deployment,
        });
    }

    fn schedule_initial(&mut self) {
        // Batched: one wheel insert per run of equal-delay generators,
        // byte-identical to the per-generator `start` loop.
        crate::workload::start_all(&self.generators, &mut self.queue);
        self.queue
            .schedule_in(self.scrape_interval, Event::Scrape);
        for (i, s) in self.scalers.iter().enumerate() {
            self.queue.schedule_in(
                s.autoscaler.control_interval(),
                Event::AutoscaleTick { scaler: i as u32 },
            );
            if let Some(u) = s.autoscaler.update_interval() {
                self.queue
                    .schedule_in(u, Event::ModelUpdateTick { scaler: i as u32 });
            }
        }
    }

    /// Run the world until simulated `end`. Returns the number of events
    /// processed. Subsequent calls continue from where the previous run
    /// stopped (periodic ticks keep self-rescheduling).
    pub fn run_until(&mut self, end: Time) -> u64 {
        if !self.started {
            self.started = true;
            self.schedule_initial();
        }
        let mut processed = 0u64;
        // `pop_due` is the single run-loop primitive: it pops only
        // events due at or before `end`, without the separate peek scan
        // a peek-then-pop loop would repeat on the calendar core.
        while let Some((now, event)) = self.queue.pop_due(end) {
            processed += 1;
            match event {
                Event::RequestArrival { request_id } => {
                    self.app.on_arrival(
                        request_id,
                        &mut self.cluster,
                        &mut self.queue,
                        &mut self.rng_service,
                    );
                }
                Event::ServiceComplete { pod, request_id } => {
                    self.app.on_complete(
                        pod,
                        request_id,
                        &mut self.cluster,
                        &mut self.queue,
                        &mut self.rng_service,
                    );
                }
                Event::PodRunning { pod } => {
                    if self.cluster.on_pod_running(pod) {
                        let dep = self.cluster.pod(pod).deployment;
                        if let Some(svc) = self
                            .app
                            .services
                            .iter()
                            .position(|s| s.deployment == dep)
                        {
                            self.app.dispatch(
                                ServiceId(svc as u32),
                                &mut self.cluster,
                                &mut self.queue,
                                &mut self.rng_service,
                            );
                        }
                    }
                }
                Event::PodTerminated { pod } => {
                    self.cluster.on_pod_terminated(pod);
                }
                Event::Scrape => {
                    self.metrics.scrape(now, &mut self.cluster, &mut self.app);
                    for svc_idx in 0..self.app.services.len() {
                        let svc = ServiceId(svc_idx as u32);
                        let snap = self.metrics.latest_snapshot(svc);
                        if let Some(rir) = snap.rir() {
                            self.rir_log.push(RirSample {
                                time: now,
                                service: svc,
                                rir,
                            });
                        }
                        self.replica_log.push((now, svc, snap.replicas));
                    }
                    self.queue
                        .schedule_in(self.scrape_interval, Event::Scrape);
                }
                Event::AutoscaleTick { scaler } => {
                    let b = &mut self.scalers[scaler as usize];
                    let decision = b.autoscaler.evaluate(
                        now,
                        b.service,
                        b.deployment,
                        &self.metrics,
                        &self.cluster,
                    );
                    self.cluster.reconcile(
                        b.deployment,
                        decision.desired,
                        &mut self.queue,
                        &mut self.rng_cluster,
                    );
                    self.cluster
                        .retry_pending(&mut self.queue, &mut self.rng_cluster);
                    if self.log_decisions {
                        self.decision_log.push(DecisionRecord {
                            time: now,
                            service: b.service,
                            desired: decision.desired,
                            used_fallback: decision.used_fallback,
                            recommendations: decision.recommendations,
                        });
                    }
                    self.queue.schedule_in(
                        b.autoscaler.control_interval(),
                        Event::AutoscaleTick { scaler },
                    );
                }
                Event::ModelUpdateTick { scaler } => {
                    let b = &mut self.scalers[scaler as usize];
                    // A failed model update must not kill the system
                    // (Algorithm 1 robustness); log and continue.
                    if let Err(e) = b.autoscaler.model_update(now) {
                        eprintln!("[t={now}] model update failed: {e:#}");
                    }
                    if let Some(u) = b.autoscaler.update_interval() {
                        self.queue
                            .schedule_in(u, Event::ModelUpdateTick { scaler });
                    }
                }
                Event::WorkloadTick { generator } => {
                    let g = &mut self.generators[generator as usize];
                    let _alive = g.on_tick(
                        generator,
                        &mut self.app,
                        &mut self.queue,
                        &mut self.rng_workload,
                    );
                }
                Event::NodeCrash { node } => {
                    if let Some(out) = self.cluster.crash_node(node) {
                        self.chaos.crashes += 1;
                        self.chaos.pods_killed += out.pods_killed as u64;
                        self.crashed_at[node.0 as usize] = Some(now);
                        // Replace lost capacity immediately (the
                        // ReplicaSet controller reacts to pod deletion,
                        // not the next autoscale tick).
                        for &dep in &out.deployments {
                            let desired =
                                self.cluster.deployments[dep.0 as usize].desired_replicas;
                            let before = self.cluster.live_replicas(dep);
                            self.cluster.reconcile(
                                dep,
                                desired,
                                &mut self.queue,
                                &mut self.rng_cluster,
                            );
                            let after = self.cluster.live_replicas(dep);
                            self.chaos.pods_rescheduled +=
                                after.saturating_sub(before) as u64;
                        }
                        self.app.requeue_orphans(
                            &out.orphans,
                            &mut self.cluster,
                            &mut self.queue,
                            &mut self.rng_service,
                        );
                    }
                }
                Event::RequestTimeout { request_id } => {
                    self.app.on_timeout(request_id, &mut self.queue);
                }
                Event::NodeRejoin { node } => {
                    if self.cluster.rejoin_node(node) {
                        self.chaos.rejoins += 1;
                        if let Some(t) = self.crashed_at[node.0 as usize].take() {
                            self.chaos.downtime += now.saturating_sub(t);
                        }
                        // Recovered capacity absorbs the Pending backlog.
                        self.cluster
                            .retry_pending(&mut self.queue, &mut self.rng_cluster);
                    }
                }
            }
        }
        self.events_processed += processed;
        processed
    }

    /// RIR samples for one service.
    pub fn rir_for(&self, service_idx: usize) -> Vec<f64> {
        self.rir_log
            .iter()
            .filter(|s| s.service == ServiceId(service_idx as u32))
            .map(|s| s.rir)
            .collect()
    }

    /// One service's decision sequence as `(time, desired)` — the
    /// golden-equivalence comparison vector. Needs the opt-in log
    /// ([`Self::record_decisions`] before the run).
    pub fn decisions_for(&self, service_idx: usize) -> Vec<(Time, usize)> {
        self.decision_log
            .iter()
            .filter(|d| d.service == ServiceId(service_idx as u32))
            .map(|d| (d.time, d.desired))
            .collect()
    }

    /// Exact response times (seconds) filtered by task type. Needs the
    /// opt-in log ([`Self::record_responses`] before the run); consumers
    /// that only need counts / moments / quantiles should read the
    /// always-on streaming `self.app.stats` instead.
    pub fn response_times(&self, task: crate::app::TaskType) -> Vec<f64> {
        self.app
            .response_log()
            .expect("response log is off — call record_responses() first, or use app.stats")
            .iter()
            .filter(|r| r.task == task)
            .map(|r| r.response_secs())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscaler::Hpa;
    use crate::config::quickstart_cluster;
    use crate::sim::{MIN, SEC};
    use crate::workload::{Generator, RandomAccessGen};

    fn hpa_world_on(seed: u64, core: CoreKind) -> SimWorld {
        let cfg = quickstart_cluster();
        let mut w = SimWorld::build_with_core(&cfg, TaskCosts::default(), seed, core);
        w.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));
        w.add_scaler(Box::new(Hpa::with_defaults()), 0);
        w.add_scaler(Box::new(Hpa::with_defaults()), 1);
        w
    }

    fn hpa_world(seed: u64) -> SimWorld {
        hpa_world_on(seed, CoreKind::Calendar)
    }

    #[test]
    fn end_to_end_10_minutes_with_hpa() {
        let mut w = hpa_world(11);
        let events = w.run_until(10 * MIN);
        assert!(events > 100, "world should be busy: {events} events");
        assert!(
            w.app.completed() > 50,
            "requests completed: {}",
            w.app.completed()
        );
        // Both task types present (0.9/0.1 mix) in the streaming stats.
        assert!(w.app.stats.sort.n() > 0);
        assert!(!w.rir_log.is_empty());
        // Replica counts stayed within physical bounds.
        assert!(w
            .replica_log
            .iter()
            .all(|&(_, svc, r)| if svc == ServiceId(0) { r <= 16 } else { r <= 8 }));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = hpa_world(42);
        let mut b = hpa_world(42);
        a.run_until(5 * MIN);
        b.run_until(5 * MIN);
        assert_eq!(a.app.completed(), b.app.completed());
        assert_eq!(a.events_processed, b.events_processed);
        // The streaming digest covers every response time bit-exactly —
        // no per-run Vec re-collection needed.
        assert_eq!(
            a.app.stats.fingerprint(),
            b.app.stats.fingerprint(),
            "bit-identical runs for equal seeds"
        );
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = hpa_world(1);
        let mut b = hpa_world(2);
        a.run_until(5 * MIN);
        b.run_until(5 * MIN);
        assert_ne!(a.app.stats.fingerprint(), b.app.stats.fingerprint());
    }

    #[test]
    fn calendar_and_heap_cores_are_bit_identical() {
        // The golden-equivalence contract at world level: same seed on
        // both event cores → same event count, same response stream.
        let mut cal = hpa_world_on(42, CoreKind::Calendar);
        let mut heap = hpa_world_on(42, CoreKind::Heap);
        cal.run_until(8 * MIN);
        heap.run_until(8 * MIN);
        assert!(cal.events_processed > 100);
        assert_eq!(cal.events_processed, heap.events_processed);
        assert_eq!(
            cal.app.stats.fingerprint(),
            heap.app.stats.fingerprint(),
            "calendar core must reproduce the heap reference bit-for-bit"
        );
        assert_eq!(cal.app.completed(), heap.app.completed());
        assert_eq!(cal.rir_log.len(), heap.rir_log.len());
    }

    #[test]
    fn indexed_and_scan_cluster_planes_are_bit_identical() {
        // The index-layer golden contract at world level: the retained
        // scan baseline reproduces the indexed run bit-for-bit (the
        // full grids live in tests/golden_index_equivalence.rs).
        let mut indexed = hpa_world(42);
        let mut scan = hpa_world(42);
        scan.set_cluster_query_mode(crate::cluster::QueryMode::Scan);
        indexed.run_until(8 * MIN);
        scan.run_until(8 * MIN);
        assert!(indexed.events_processed > 100);
        assert_eq!(indexed.events_processed, scan.events_processed);
        assert_eq!(
            indexed.app.stats.fingerprint(),
            scan.app.stats.fingerprint(),
            "scan baseline must reproduce the indexed run bit-for-bit"
        );
        assert_eq!(indexed.app.completed(), scan.app.completed());
        indexed.cluster.verify_indices();
        scan.cluster.verify_indices();
    }

    #[test]
    fn run_can_continue() {
        let mut w = hpa_world(3);
        w.run_until(2 * MIN);
        let n1 = w.app.completed();
        w.run_until(4 * MIN);
        let n2 = w.app.completed();
        assert!(n2 > n1);
    }

    #[test]
    fn zero_event_first_run_does_not_duplicate_ticks() {
        // Regression: with no generator, the first event is the Scrape at
        // t=10s, so run_until(5s) processes zero events. The old
        // `events_processed == 0` guard then re-armed every initial tick
        // on the next call, doubling the Scrape stream (and with it the
        // replica/RIR logs).
        let cfg = quickstart_cluster();
        let mut w = SimWorld::build(&cfg, TaskCosts::default(), 21);
        w.add_scaler(Box::new(Hpa::with_defaults()), 0);
        w.add_scaler(Box::new(Hpa::with_defaults()), 1);
        let first = w.run_until(5 * SEC);
        assert_eq!(first, 0, "no event lands before the first scrape");
        w.run_until(65 * SEC);
        // Scrapes at 10..60 s inclusive: exactly 6 replica-log entries per
        // service; a duplicated Scrape stream would double this.
        let svc0 = w
            .replica_log
            .iter()
            .filter(|&&(_, svc, _)| svc == ServiceId(0))
            .count();
        assert_eq!(svc0, 6, "duplicated initial ticks detected");
    }

    #[test]
    fn decision_log_records_per_metric_provenance() {
        let mut w = hpa_world(5);
        w.record_decisions();
        w.run_until(5 * MIN);
        assert!(!w.decision_log.is_empty());
        // HPA ticks every 15 s: decisions for both services, each with
        // exactly one (cpu:70) recommendation whose provenance lines up.
        for d in &w.decision_log {
            assert_eq!(d.recommendations.len(), 1);
            let rec = &d.recommendations[0];
            assert_eq!(rec.metric, crate::metrics::M_CPU);
            assert!((rec.target - 70.0).abs() < 1e-12);
            assert!(!d.used_fallback);
        }
        let svc0 = w.decisions_for(0);
        assert_eq!(svc0.len(), 5 * 4, "one decision per 15 s tick");
        assert!(svc0.windows(2).all(|p| p[0].0 < p[1].0), "time-ordered");
    }

    #[test]
    fn hpa_scales_up_under_load() {
        let mut w = hpa_world(7);
        w.run_until(30 * MIN);
        let max_replicas = w
            .replica_log
            .iter()
            .filter(|&&(_, svc, _)| svc == ServiceId(0))
            .map(|&(_, _, r)| r)
            .max()
            .unwrap();
        assert!(
            max_replicas > 1,
            "heavy phases must trigger scale-up; max={max_replicas}"
        );
        let _ = SEC;
    }
}

//! Workload generation (paper §5.2): the *Random Access* generator
//! (Algorithm 2), the scaled *NASA* trace, and the scenario library
//! (`scenario.rs`: diurnal / flash-crowd / step-surge / composite behind
//! the [`Scenario`] descriptor).
//!
//! Generators are event-driven: each owns a `WorkloadTick` stream in the
//! DES and submits requests to the [`crate::app::App`] when woken.

mod nasa;
mod scenario;

pub use nasa::{load_azure_minute_counts, load_minute_counts, nasa_synthetic, NasaTraceConfig};
pub use scenario::{
    DiurnalConfig, FlashCrowdConfig, RateGen, RateProfile, Scenario, StepSurgeConfig,
};

use crate::app::{App, TaskType};
use crate::sim::{Event, EventQueue, Time, MIN};
use crate::util::rng::Pcg64;

/// Fraction of requests that are cheap Sort tasks (Algorithm 2: 9/10).
pub const SORT_PROBABILITY: f64 = 0.9;

/// The three load phases of Random Access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadType {
    Light,
    Medium,
    Heavy,
}

impl LoadType {
    /// Inter-request sleep range in seconds (Algorithm 2).
    pub fn sleep_range(self) -> (f64, f64) {
        match self {
            LoadType::Heavy => (0.1, 0.3),
            LoadType::Medium => (0.5, 1.0),
            LoadType::Light => (2.0, 5.0),
        }
    }
}

/// A workload generator bound to one origin zone.
#[derive(Debug)]
pub enum Generator {
    RandomAccess(RandomAccessGen),
    Trace(TraceGen),
    Rate(RateGen),
}

impl Generator {
    /// Schedule this generator's first tick (honouring any start delay, so
    /// multi-zone sweeps can stagger their zones).
    pub fn start(&mut self, index: u32, queue: &mut EventQueue) {
        let delay = match self {
            Generator::RandomAccess(_) => 0,
            Generator::Trace(g) => g.start_delay,
            Generator::Rate(g) => g.start_delay,
        };
        queue.schedule_in(delay, Event::WorkloadTick { generator: index });
    }

    /// Handle a `WorkloadTick`: submit request(s) and schedule the next
    /// tick. Returns false when the generator is exhausted (trace end).
    pub fn on_tick(
        &mut self,
        index: u32,
        app: &mut App,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) -> bool {
        match self {
            Generator::RandomAccess(g) => {
                g.on_tick(index, app, queue, rng);
                true
            }
            Generator::Trace(g) => g.on_tick(index, app, queue, rng),
            Generator::Rate(g) => g.on_tick(index, app, queue, rng),
        }
    }

    pub fn zone(&self) -> u32 {
        match self {
            Generator::RandomAccess(g) => g.zone,
            Generator::Trace(g) => g.zone,
            Generator::Rate(g) => g.zone,
        }
    }

    /// Delay before this generator's first tick (0 for un-staggered
    /// generators).
    pub fn start_delay(&self) -> Time {
        match self {
            Generator::RandomAccess(_) => 0,
            Generator::Trace(g) => g.start_delay,
            Generator::Rate(g) => g.start_delay,
        }
    }
}

/// Start every generator, batching consecutive equal-delay runs through
/// [`EventQueue::schedule_in_batch`] — one wheel insert per run instead
/// of one per generator (a measured win on city topologies, where all N
/// zones of a step-carpet or diurnal preset start together).
///
/// Only *consecutive* generators are grouped, so the `(delay, index)`
/// schedule order — and with it every event `seq` — stays byte-identical
/// to calling [`Generator::start`] in a loop.
pub fn start_all(generators: &[Generator], queue: &mut EventQueue) {
    let mut i = 0;
    while i < generators.len() {
        let delay = generators[i].start_delay();
        let mut j = i + 1;
        while j < generators.len() && generators[j].start_delay() == delay {
            j += 1;
        }
        queue.schedule_in_batch(
            delay,
            (i..j).map(|g| Event::WorkloadTick {
                generator: g as u32,
            }),
        );
        i = j;
    }
}

/// Shared task-mix draw (Algorithm 2's 0.9/0.1 Sort/Eigen split).
fn draw_task(rng: &mut Pcg64) -> TaskType {
    if rng.chance(SORT_PROBABILITY) {
        TaskType::Sort
    } else {
        TaskType::Eigen
    }
}

/// Algorithm 2: infinite loop of bursts. Each burst picks a load type and
/// a length `Random(20, 200)`, then submits that many requests with
/// load-dependent sleeps; task type is Sort w.p. 0.9, Eigen w.p. 0.1.
#[derive(Debug)]
pub struct RandomAccessGen {
    pub zone: u32,
    load: LoadType,
    remaining_in_burst: u32,
}

impl RandomAccessGen {
    pub fn new(zone: u32) -> Self {
        RandomAccessGen {
            zone,
            load: LoadType::Light,
            remaining_in_burst: 0,
        }
    }

    /// Current phase (exposed for tests/recorders).
    pub fn load(&self) -> LoadType {
        self.load
    }

    fn on_tick(&mut self, index: u32, app: &mut App, queue: &mut EventQueue, rng: &mut Pcg64) {
        if self.remaining_in_burst == 0 {
            self.load = *rng.pick(&[LoadType::Light, LoadType::Medium, LoadType::Heavy]);
            self.remaining_in_burst = rng.int_range(20, 200) as u32;
        }
        app.submit(draw_task(rng), self.zone, queue.now(), queue);
        self.remaining_in_burst -= 1;

        let (lo, hi) = self.load.sleep_range();
        let sleep = crate::sim::from_secs(rng.range(lo, hi));
        queue.schedule_in(sleep, Event::WorkloadTick { generator: index });
    }
}

/// Replays a per-minute request-count trace (the scaled NASA dataset) as
/// a piecewise-Poisson arrival process: during minute `m`, arrivals are
/// exponential with rate `counts[m] * scale / 60` per second. Task mix is
/// the same 0.9/0.1 Sort/Eigen split (paper §5.2.2).
#[derive(Debug)]
pub struct TraceGen {
    pub zone: u32,
    counts: std::sync::Arc<Vec<f64>>,
    scale: f64,
    /// Delay before the first tick (staggered multi-zone sweeps).
    start_delay: Time,
    /// Sim time of the first tick. Trace minutes are indexed relative to
    /// this origin: indexing by absolute sim time would silently skip the
    /// leading minutes of any trace started mid-simulation.
    origin: Option<Time>,
}

impl TraceGen {
    pub fn new(zone: u32, counts: std::sync::Arc<Vec<f64>>, scale: f64) -> Self {
        TraceGen {
            zone,
            counts,
            scale,
            start_delay: 0,
            origin: None,
        }
    }

    /// Delay the trace start by `delay` (the trace still plays in full,
    /// indexed from its own start).
    pub fn with_start_delay(mut self, delay: Time) -> Self {
        self.start_delay = delay;
        self
    }

    /// Trace duration.
    pub fn duration(&self) -> Time {
        self.counts.len() as Time * MIN
    }

    /// Arrival rate (req/s) at `elapsed` time since the generator's first
    /// tick; `None` once the trace is exhausted.
    fn rate_at(&self, elapsed: Time) -> Option<f64> {
        let minute = (elapsed / MIN) as usize;
        self.counts
            .get(minute)
            .map(|&c| (c * self.scale / 60.0).max(0.0))
    }

    fn on_tick(
        &mut self,
        index: u32,
        app: &mut App,
        queue: &mut EventQueue,
        rng: &mut Pcg64,
    ) -> bool {
        let now = queue.now();
        // First tick records the origin and only schedules the first
        // arrival; later ticks are arrivals.
        let origin = match self.origin {
            Some(o) => {
                app.submit(draw_task(rng), self.zone, now, queue);
                o
            }
            None => {
                self.origin = Some(now);
                now
            }
        };

        // Next arrival: sample the gap from the current minute's rate; if
        // the minute is silent, hop to the next minute boundary. All
        // minute arithmetic is relative to the origin.
        let mut t = now - origin;
        loop {
            match self.rate_at(t) {
                None => return false, // trace exhausted
                Some(rate) if rate > 1e-9 => {
                    let gap = crate::sim::from_secs(rng.exponential(rate)).max(1);
                    let next = t + gap;
                    // If the gap crosses into the next minute, re-sample
                    // there (rate may differ) — thinning-free approximation
                    // adequate for minute-resolution traces.
                    let minute_end = (t / MIN + 1) * MIN;
                    if next <= minute_end {
                        queue.schedule_at(origin + next, Event::WorkloadTick { generator: index });
                        return true;
                    }
                    t = minute_end;
                }
                Some(_) => {
                    t = (t / MIN + 1) * MIN;
                }
            }
        }
    }
}

/// Convenience: drive only workload ticks (no cluster) to count requests —
/// used by tests and the fig6 experiment.
pub fn replay_arrival_times(
    counts: &std::sync::Arc<Vec<f64>>,
    scale: f64,
    seed: u64,
) -> Vec<Time> {
    use crate::app::TaskCosts;
    use crate::cluster::{Cluster, Deployment, PodSpec, Selector, Tier};

    let mut cluster = Cluster::new();
    let edge = cluster.add_deployment(Deployment::new(
        "edge",
        Selector::new(Tier::Edge, Some(1)),
        PodSpec::new(500, 256),
        0,
        1,
    ));
    let cloud = cluster.add_deployment(Deployment::new(
        "cloud",
        Selector::new(Tier::Cloud, None),
        PodSpec::new(1000, 512),
        0,
        1,
    ));
    let mut app = App::new(TaskCosts::default(), &[(1, edge)], cloud);
    let mut queue = EventQueue::new();
    let mut rng = Pcg64::new(seed, 100);
    let mut gen = Generator::Trace(TraceGen::new(1, counts.clone(), scale));
    gen.start(0, &mut queue);

    let mut arrivals = Vec::new();
    while let Some((t, ev)) = queue.pop() {
        match ev {
            Event::WorkloadTick { generator } => {
                if !gen.on_tick(generator, &mut app, &mut queue, &mut rng) {
                    break;
                }
            }
            Event::RequestArrival { .. } => arrivals.push(t),
            _ => {}
        }
    }
    arrivals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::TaskCosts;
    use crate::cluster::{Cluster, Deployment, PodSpec, Selector, Tier};
    use std::sync::Arc;

    fn app() -> App {
        let mut cluster = Cluster::new();
        let edge = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, Some(1)),
            PodSpec::new(500, 256),
            0,
            1,
        ));
        let cloud = cluster.add_deployment(Deployment::new(
            "cloud",
            Selector::new(Tier::Cloud, None),
            PodSpec::new(1000, 512),
            0,
            1,
        ));
        App::new(TaskCosts::default(), &[(1, edge)], cloud)
    }

    #[test]
    fn random_access_generates_with_correct_mix() {
        let mut a = app();
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(7, 0);
        let mut gen = Generator::RandomAccess(RandomAccessGen::new(1));
        gen.start(0, &mut q);

        let mut sorts = 0usize;
        let mut eigens = 0usize;
        let mut n = 0usize;
        while n < 5000 {
            let Some((_, ev)) = q.pop() else { break };
            match ev {
                Event::WorkloadTick { generator } => {
                    gen.on_tick(generator, &mut a, &mut q, &mut rng);
                }
                Event::RequestArrival { .. } => {
                    n += 1;
                }
                _ => {}
            }
            // Count by service routing.
            sorts = a.services[0].counters.arrivals as usize;
            eigens = a.services[1].counters.arrivals as usize;
        }
        let frac = sorts as f64 / (sorts + eigens) as f64;
        assert!((frac - SORT_PROBABILITY).abs() < 0.02, "sort frac {frac}");
    }

    #[test]
    fn random_access_sleep_ranges_honoured() {
        // Heavy phase: gaps in [0.1, 0.3] s.
        let (lo, hi) = LoadType::Heavy.sleep_range();
        assert_eq!((lo, hi), (0.1, 0.3));
        assert_eq!(LoadType::Light.sleep_range(), (2.0, 5.0));
        assert_eq!(LoadType::Medium.sleep_range(), (0.5, 1.0));
    }

    #[test]
    fn trace_replay_matches_counts() {
        // 3 minutes at 120/min then silence.
        let counts = Arc::new(vec![120.0, 120.0, 120.0, 0.0, 0.0]);
        let arrivals = replay_arrival_times(&counts, 1.0, 11);
        let n = arrivals.len() as f64;
        assert!((n - 360.0).abs() < 70.0, "expected ~360 arrivals, got {n}");
        // All within the 5-minute horizon (+routing latency slack).
        assert!(arrivals.iter().all(|&t| t <= 5 * MIN + crate::sim::SEC));
    }

    #[test]
    fn trace_scale_factor_applies() {
        let counts = Arc::new(vec![100.0; 10]);
        let full = replay_arrival_times(&counts, 1.0, 3).len() as f64;
        let half = replay_arrival_times(&counts, 0.5, 3).len() as f64;
        assert!((half / full - 0.5).abs() < 0.12, "full={full} half={half}");
    }

    #[test]
    fn trace_ends() {
        let counts = Arc::new(vec![10.0, 0.0]);
        let arrivals = replay_arrival_times(&counts, 1.0, 5);
        assert!(arrivals.len() < 30);
    }

    #[test]
    fn staggered_trace_plays_in_full() {
        // Regression: a trace whose first tick lands mid-simulation must
        // replay from its own minute 0. The old absolute-time indexing
        // (`now / MIN`) would read minutes 5.. — pure silence here — and
        // emit nothing.
        let counts = Arc::new(vec![60.0, 60.0, 60.0]);
        let mut a = app();
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(31, 100);
        let mut gen =
            Generator::Trace(TraceGen::new(1, counts.clone(), 1.0).with_start_delay(5 * MIN));
        gen.start(0, &mut q);

        let mut arrivals = Vec::new();
        while let Some((t, ev)) = q.pop() {
            match ev {
                Event::WorkloadTick { generator } => {
                    if !gen.on_tick(generator, &mut a, &mut q, &mut rng) {
                        break;
                    }
                }
                Event::RequestArrival { .. } => arrivals.push(t),
                _ => {}
            }
        }
        let n = arrivals.len() as f64;
        assert!((n - 180.0).abs() < 60.0, "expected ~180 arrivals, got {n}");
        assert!(
            arrivals.iter().all(|&t| t >= 5 * MIN && t <= 8 * MIN + crate::sim::SEC),
            "arrivals must land in the staggered window"
        );
    }

    #[test]
    fn start_all_matches_sequential_starts() {
        // Mixed stagger pattern: [0, 0, 2m, 2m, 0] — two batchable runs
        // plus a trailing singleton that must NOT be grouped with the
        // leading zeros (grouping non-consecutive delays would reorder
        // seqs).
        let counts = Arc::new(vec![60.0; 3]);
        let build = || -> Vec<Generator> {
            vec![
                Generator::RandomAccess(RandomAccessGen::new(1)),
                Generator::RandomAccess(RandomAccessGen::new(2)),
                Generator::Trace(TraceGen::new(1, counts.clone(), 1.0).with_start_delay(2 * MIN)),
                Generator::Trace(TraceGen::new(2, counts.clone(), 1.0).with_start_delay(2 * MIN)),
                Generator::RandomAccess(RandomAccessGen::new(1)),
            ]
        };
        let mut seq_q = EventQueue::new();
        for (i, g) in build().iter_mut().enumerate() {
            g.start(i as u32, &mut seq_q);
        }
        let mut batch_q = EventQueue::new();
        start_all(&build(), &mut batch_q);
        let drain = |mut q: EventQueue| -> Vec<(Time, u32)> {
            std::iter::from_fn(|| q.pop())
                .map(|(t, e)| match e {
                    Event::WorkloadTick { generator } => (t, generator),
                    _ => unreachable!(),
                })
                .collect()
        };
        let seq = drain(seq_q);
        assert_eq!(seq.len(), 5);
        assert_eq!(seq, drain(batch_q));
    }

    #[test]
    fn silent_minutes_are_skipped() {
        let counts = Arc::new(vec![0.0, 0.0, 60.0, 0.0]);
        let arrivals = replay_arrival_times(&counts, 1.0, 9);
        assert!(!arrivals.is_empty());
        assert!(arrivals.iter().all(|&t| t >= 2 * MIN), "{arrivals:?}");
    }
}

"""L1 correctness: Pallas LSTM cell vs the pure-jnp oracle.

Hypothesis sweeps batch/input/hidden shapes; forward values and custom-vjp
gradients must match ``jax.grad`` of the reference to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lstm_cell import lstm_cell, lstm_cell_jit
from compile.kernels.ref import lstm_cell_ref

jax.config.update("jax_platform_name", "cpu")


def _rand_inputs(rng, batch, i_dim, hidden, dtype=np.float32, scale=1.0):
    x = rng.standard_normal((batch, i_dim)).astype(dtype) * scale
    h = rng.standard_normal((batch, hidden)).astype(dtype) * scale
    c = rng.standard_normal((batch, hidden)).astype(dtype) * scale
    w = (rng.standard_normal((i_dim + hidden, 4 * hidden)) * 0.2).astype(dtype)
    b = (rng.standard_normal(4 * hidden) * 0.1).astype(dtype)
    return x, h, c, w, b


def test_cell_matches_ref_basic():
    rng = np.random.default_rng(0)
    args = _rand_inputs(rng, 4, 5, 50)
    h_k, c_k = lstm_cell_jit(*args)
    h_r, c_r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=25)
@given(
    batch=st.integers(1, 16),
    i_dim=st.integers(1, 12),
    hidden=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_cell_matches_ref_shape_sweep(batch, i_dim, hidden, seed):
    rng = np.random.default_rng(seed)
    args = _rand_inputs(rng, batch, i_dim, hidden)
    h_k, c_k = lstm_cell(*args)
    h_r, c_r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=10)
@given(
    batch=st.integers(1, 8),
    i_dim=st.integers(1, 8),
    hidden=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_cell_grads_match_ref(batch, i_dim, hidden, seed):
    """Backward Pallas kernel (via custom_vjp) vs jax.grad of the oracle."""
    rng = np.random.default_rng(seed)
    args = _rand_inputs(rng, batch, i_dim, hidden)

    def loss_kernel(x, h, c, w, b):
        h_n, c_n = lstm_cell(x, h, c, w, b)
        return jnp.sum(h_n**2) + jnp.sum(jnp.sin(c_n))

    def loss_ref(x, h, c, w, b):
        h_n, c_n = lstm_cell_ref(x, h, c, w, b)
        return jnp.sum(h_n**2) + jnp.sum(jnp.sin(c_n))

    g_k = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(*args)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(*args)
    for got, want, name in zip(g_k, g_r, ["dx", "dh", "dc", "dw", "db"]):
        np.testing.assert_allclose(
            got, want, rtol=2e-4, atol=1e-5, err_msg=f"gradient mismatch: {name}"
        )


def test_cell_extreme_values_saturate_not_nan():
    """Saturated gates (large pre-activations) must stay finite."""
    rng = np.random.default_rng(7)
    args = _rand_inputs(rng, 2, 5, 16, scale=50.0)
    h_k, c_k = lstm_cell(*args)
    assert np.all(np.isfinite(np.asarray(h_k)))
    assert np.all(np.isfinite(np.asarray(c_k)))
    h_r, c_r = lstm_cell_ref(*args)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-4, atol=1e-5)


def test_cell_zero_state_identity_gates():
    """With zero weights, i=f=o=0.5, g=0 -> c' = c/2, h' = tanh(c/2)/2."""
    batch, i_dim, hidden = 3, 5, 10
    x = np.ones((batch, i_dim), np.float32)
    h = np.zeros((batch, hidden), np.float32)
    c = np.ones((batch, hidden), np.float32)
    w = np.zeros((i_dim + hidden, 4 * hidden), np.float32)
    b = np.zeros(4 * hidden, np.float32)
    h_k, c_k = lstm_cell(x, h, c, w, b)
    np.testing.assert_allclose(c_k, 0.5 * np.ones_like(c), rtol=1e-6)
    np.testing.assert_allclose(h_k, 0.5 * np.tanh(0.5) * np.ones_like(c), rtol=1e-6)


def test_cell_batch_independence():
    """Rows of a batch must not interact (no cross-batch reduction bugs)."""
    rng = np.random.default_rng(3)
    x, h, c, w, b = _rand_inputs(rng, 6, 5, 20)
    h_full, c_full = lstm_cell(x, h, c, w, b)
    for i in [0, 2, 5]:
        h_i, c_i = lstm_cell(x[i : i + 1], h[i : i + 1], c[i : i + 1], w, b)
        np.testing.assert_allclose(h_full[i : i + 1], h_i, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(c_full[i : i + 1], c_i, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32])
def test_cell_dtype_preserved(dtype):
    rng = np.random.default_rng(11)
    args = _rand_inputs(rng, 2, 5, 8, dtype=dtype)
    h_k, c_k = lstm_cell(*args)
    assert h_k.dtype == dtype and c_k.dtype == dtype

//! Deployments: replica sets of identical worker pods, pinned to a tier
//! (and optionally a zone) by a node selector — the autoscalers' targets.

use super::{NodeSpec, PodSpec, Tier};
use crate::sim::{NodeId, PodId};
use std::collections::BTreeSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeploymentId(pub u32);

/// Node selector: tier + optional zone (edge worker deployments are
/// per-zone; the cloud worker deployment spans the cloud tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Selector {
    pub tier: Tier,
    pub zone: Option<u32>,
}

impl Selector {
    pub fn new(tier: Tier, zone: Option<u32>) -> Self {
        Selector { tier, zone }
    }

    pub fn matches(&self, node: &NodeSpec) -> bool {
        let zone_ok = match self.zone {
            Some(z) => node.zone == z,
            None => true,
        };
        node.tier == self.tier && zone_ok
    }
}

/// A deployment of identical worker pods.
///
/// Besides the user-visible configuration, a deployment carries the
/// incrementally maintained indices of the cluster plane (see
/// DESIGN.md §5): per-phase pod counters, the idle-pod ordered set the
/// dispatcher pops, and the cached matching-node list the scheduler and
/// the Algorithm-1 capacity cap iterate. `Cluster` owns every update;
/// the selector must not change after `Cluster::add_deployment` (the
/// matching-node cache would go stale).
#[derive(Debug, Clone)]
pub struct Deployment {
    pub name: String,
    pub selector: Selector,
    pub pod_spec: PodSpec,
    pub min_replicas: usize,
    pub max_replicas: usize,
    pub desired_replicas: usize,
    /// All live pods (any phase but Gone).
    pub pods: Vec<PodId>,
    /// Live pod count per non-Gone phase, indexed by `PodPhase as
    /// usize` (Pending / Initializing / Running / Terminating) —
    /// maintained by `Cluster::set_phase` so `live_replicas` /
    /// `count_phase` are O(1) reads.
    pub(super) phase_counts: [usize; 4],
    /// Idle Running pods ordered by pod id: `first()` is the
    /// deterministic min-pod-id dispatch choice, updated on every
    /// phase and occupancy transition.
    pub(super) idle_pods: BTreeSet<PodId>,
    /// Node indices matching `selector`, ascending — the scheduler's
    /// pre-computed filter stage and the capacity cap's iteration set.
    pub(super) matching_nodes: Vec<NodeId>,
}

impl Deployment {
    pub fn new(
        name: &str,
        selector: Selector,
        pod_spec: PodSpec,
        min_replicas: usize,
        max_replicas: usize,
    ) -> Self {
        Deployment {
            name: name.to_string(),
            selector,
            pod_spec,
            min_replicas,
            max_replicas,
            desired_replicas: min_replicas,
            pods: Vec::new(),
            phase_counts: [0; 4],
            idle_pods: BTreeSet::new(),
            matching_nodes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selector_matches_tier_and_zone() {
        let edge1 = NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048);
        let edge2 = NodeSpec::new("e2", Tier::Edge, 2, 2000, 2048);
        let cloud = NodeSpec::new("c", Tier::Cloud, 0, 3000, 3072);

        let s_zone1 = Selector::new(Tier::Edge, Some(1));
        assert!(s_zone1.matches(&edge1));
        assert!(!s_zone1.matches(&edge2));
        assert!(!s_zone1.matches(&cloud));

        let s_cloud = Selector::new(Tier::Cloud, None);
        assert!(s_cloud.matches(&cloud));
        assert!(!s_cloud.matches(&edge1));
    }
}

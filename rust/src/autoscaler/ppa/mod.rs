//! The Proactive Pod Autoscaler (paper §4) — the system contribution.
//!
//! Three components, two loops, two files (Fig 4):
//! * [`Formulator`] — extracts the protocol vector from raw metrics each
//!   control loop and appends it to the *metrics history file*.
//! * [`Evaluator`] — Algorithm 1 over the configured [`MetricSpec`] set:
//!   predicts the protocol vector with the injected model (*model file*),
//!   falls back to current metrics when the model is invalid or
//!   under-confident, applies the *static policy* per metric, combines
//!   max-wins and caps at the resource-limited max replicas.
//! * [`Updater`] — the model-update loop: applies one of the three update
//!   policies (§4.2.3) to the model over the history file, then clears
//!   the history file (as the paper's Updater does).
//!
//! The combined decision then runs through the shared
//! [`ScalingBehavior`] stage — the control plane applies the same
//! stabilization/rate machinery to every scaler's requests.

mod evaluator;
mod formulator;
mod policy;
mod updater;

pub use evaluator::Evaluator;
pub use formulator::Formulator;
pub use policy::{ConservativeCeilPolicy, HpaCeilPolicy, StaticPolicy, StepPolicy};
pub use updater::Updater;

use super::behavior::{BehaviorState, ScalingBehavior};
use super::spec::MetricSpec;
use super::{Autoscaler, ScaleDecision};
use crate::cluster::{Cluster, DeploymentId};
use crate::forecast::{Forecaster, UpdatePolicy};
use crate::metrics::MetricsPipeline;
use crate::sim::{ServiceId, Time, HOUR, SEC};
use crate::stats::StreamingStats;

/// PPA configuration — Table 4's arguments, multi-metric form.
#[derive(Debug, Clone)]
pub struct PpaConfig {
    /// Metric targets, combined max-wins. The first spec is the
    /// *primary* metric: its prediction feeds the prediction log (Figs
    /// 7–8). Sources are honoured per spec (`Forecast` = Algorithm 1
    /// proactive path, `Current` = reactive pin).
    pub specs: Vec<MetricSpec>,
    /// `ControlInterval` (paper experiments: 20 s records).
    pub control_interval: Time,
    /// `UpdateInterval` (paper: hours; 1 h in the optimization runs).
    pub update_interval: Time,
    /// Model-update policy (§4.2.3).
    pub update_policy: UpdatePolicy,
    /// Confidence gate for Bayesian models (Algorithm 1).
    pub confidence_threshold: f64,
    /// Scaling behavior applied by the control plane to the PPA's scale
    /// requests. Default: 2-minute downscale stabilization — shorter
    /// than HPA's 5 min because predictions filter transient dips.
    pub behavior: ScalingBehavior,
}

impl Default for PpaConfig {
    fn default() -> Self {
        PpaConfig {
            specs: vec![MetricSpec::forecast(crate::metrics::M_CPU, 70.0)],
            control_interval: 20 * SEC,
            update_interval: HOUR,
            update_policy: UpdatePolicy::FineTune,
            confidence_threshold: 0.5,
            behavior: ScalingBehavior::stabilize_down(2 * crate::sim::MIN),
        }
    }
}

/// One recorded control-loop observation: what the model predicted for
/// this instant (made one interval earlier) vs what actually happened —
/// the data behind Figs 7 and 8.
#[derive(Debug, Clone, Copy)]
pub struct PredictionRecord {
    pub time: Time,
    pub predicted: f64,
    pub actual: f64,
}

/// The assembled PPA.
pub struct Ppa {
    cfg: PpaConfig,
    formulator: Formulator,
    evaluator: Evaluator,
    updater: Updater,
    /// Primary-metric prediction made last tick, awaiting its actual.
    pending_prediction: Option<f64>,
    /// (predicted, actual) log for the primary metric (Figs 7–8).
    /// **Opt-in** via [`Ppa::record_logs`] — unbounded over long
    /// city runs, so sweep cells leave it off and read the streaming
    /// [`Ppa::prediction_mse`] / [`Ppa::prediction_count`] instead.
    pub prediction_log: Vec<PredictionRecord>,
    /// Decision log (desired replicas per tick) — opt-in together with
    /// the prediction log ([`Ppa::record_logs`] gates both).
    pub decision_log: Vec<(Time, usize)>,
    /// Whether the unbounded logs above are populated.
    log_records: bool,
    /// Streaming squared-error moments over the closed predictions —
    /// always on; the MSE is read off in O(1) with no intermediate
    /// collections and no per-tick log growth.
    squared_errors: StreamingStats,
    /// Shared behavior-stage state (stabilization windows, rate limits).
    behavior_state: BehaviorState,
}

impl Ppa {
    pub fn new(cfg: PpaConfig, forecaster: Box<dyn Forecaster>) -> Self {
        assert!(!cfg.specs.is_empty(), "PPA needs >= 1 metric spec");
        Ppa {
            evaluator: Evaluator::new(forecaster, cfg.confidence_threshold),
            updater: Updater::new(cfg.update_policy),
            formulator: Formulator::new(),
            cfg,
            pending_prediction: None,
            prediction_log: Vec::new(),
            decision_log: Vec::new(),
            log_records: false,
            squared_errors: StreamingStats::new(),
            behavior_state: BehaviorState::new(),
        }
    }

    /// Turn on **both** exact logs — [`Ppa::prediction_log`] and
    /// [`Ppa::decision_log`] (unbounded memory — for the paper-figure
    /// harnesses and CSV dumps; sweep cells stay flat-memory on the
    /// streaming MSE). Call before the run, like
    /// `SimWorld::record_decisions`.
    pub fn record_logs(&mut self) {
        self.log_records = true;
    }

    /// Replace the static policy (the paper's "users may inject their own
    /// policies").
    pub fn with_policy(mut self, policy: Box<dyn StaticPolicy>) -> Self {
        self.evaluator.set_policy(policy);
        self
    }

    pub fn forecaster_name(&self) -> &str {
        self.evaluator.forecaster_name()
    }

    /// Champion–challenger state, when this PPA's forecaster is a
    /// [`crate::forecast::ChampionChallenger`] wrapper (`None` for
    /// plain models) — surfaced per service in the sweep JSON.
    pub fn selection(&self) -> Option<crate::forecast::SelectionSummary> {
        self.evaluator.forecaster().selection()
    }

    /// The primary (first-spec) metric index.
    pub fn primary_metric(&self) -> usize {
        self.cfg.specs[0].metric
    }

    /// Mean squared prediction error of the primary metric so far (Figs
    /// 7–8 metric) — a single streaming pass; no per-call collections.
    pub fn prediction_mse(&self) -> f64 {
        self.squared_errors.mean()
    }

    /// Number of closed (predicted, actual) pairs so far — available
    /// whether or not the exact log is recorded.
    pub fn prediction_count(&self) -> usize {
        self.squared_errors.n()
    }
}

impl Autoscaler for Ppa {
    fn name(&self) -> &str {
        "ppa"
    }

    fn control_interval(&self) -> Time {
        self.cfg.control_interval
    }

    fn update_interval(&self) -> Option<Time> {
        Some(self.cfg.update_interval)
    }

    fn specs(&self) -> &[MetricSpec] {
        &self.cfg.specs
    }

    fn evaluate(
        &mut self,
        now: Time,
        service: ServiceId,
        target: DeploymentId,
        metrics: &MetricsPipeline,
        cluster: &Cluster,
    ) -> ScaleDecision {
        // Formulator: raw metrics -> protocol vector -> history file.
        let vector = metrics.latest_vector(service);
        self.formulator.record(vector);

        // Close the loop on last tick's primary prediction (Fig 7/8
        // data) and fold its squared error into the streaming moments.
        if let Some(pred) = self.pending_prediction.take() {
            let actual = vector[self.primary_metric()];
            let err = pred - actual;
            self.squared_errors.record(err * err);
            if self.log_records {
                self.prediction_log.push(PredictionRecord {
                    time: now,
                    predicted: pred,
                    actual,
                });
            }
        }
        self.evaluator.observe_actual(&vector);

        // Evaluator: Algorithm 1 per spec + combine + resource cap.
        let mut decision = self.evaluator.evaluate(
            &self.cfg.specs,
            &vector,
            self.formulator.history(),
            target,
            cluster,
        );
        self.pending_prediction = decision.predicted;

        // Control-plane behavior stage (stabilization / rate limits).
        let current = cluster.live_replicas(target);
        decision.desired =
            self.behavior_state
                .apply(now, decision.desired, current, &self.cfg.behavior);

        if self.log_records {
            self.decision_log.push((now, decision.desired));
        }
        decision
    }

    fn model_update(&mut self, _now: Time) -> crate::Result<()> {
        self.updater
            .run(self.evaluator.forecaster_mut(), &mut self.formulator)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscaler::spec::MetricSource;
    use crate::cluster::{Deployment, NodeSpec, PodSpec, Selector, Tier};
    use crate::forecast::NaiveForecaster;
    use crate::metrics::{M_CPU, M_REQ_RATE, METRIC_DIM};
    use crate::sim::EventQueue;
    use crate::util::rng::Pcg64;

    fn cluster_fixture_min(replicas: usize, min_replicas: usize) -> Cluster {
        let mut cluster = Cluster::new();
        cluster.add_node(NodeSpec::new("e1", Tier::Edge, 1, 2000, 2048));
        cluster.add_node(NodeSpec::new("e2", Tier::Edge, 1, 2000, 2048));
        let dep = cluster.add_deployment(Deployment::new(
            "edge",
            Selector::new(Tier::Edge, None),
            PodSpec::new(500, 256),
            min_replicas,
            16,
        ));
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1, 0);
        cluster.reconcile(dep, replicas, &mut q, &mut rng);
        while let Some((_, ev)) = q.pop() {
            if let crate::sim::Event::PodRunning { pod } = ev {
                cluster.on_pod_running(pod);
            }
        }
        cluster
    }

    fn cluster_fixture(replicas: usize) -> Cluster {
        cluster_fixture_min(replicas, 1)
    }

    fn metrics_with(cpu: f64, replicas: usize) -> MetricsPipeline {
        let mut mp = MetricsPipeline::new(10 * SEC, 1);
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = cpu;
        mp.test_set_latest(ServiceId(0), v, replicas);
        mp
    }

    #[test]
    fn proactive_with_naive_model_scales_on_trend() {
        let cluster = cluster_fixture(2);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        let mp = metrics_with(300.0, 2);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        // Naive predicts 300 → ceil(300/70) = 5.
        assert_eq!(d.desired, 5);
        assert!(!d.used_fallback);
        assert_eq!(d.predicted, Some(300.0));
        assert_eq!(d.recommendations.len(), 1);
        assert_eq!(d.recommendations[0].source, MetricSource::Forecast);
    }

    #[test]
    fn caps_at_resource_limited_max() {
        let cluster = cluster_fixture(2);
        // 2 nodes x 1800m allocatable; 2 pods live (1 per node) leave
        // 2 more slots per node -> cap = 4 additional + 2 live = 6.
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        let mp = metrics_with(10_000.0, 2);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 6, "capped at resource-limited max");
    }

    #[test]
    fn prediction_log_pairs_up() {
        let cluster = cluster_fixture(1);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        ppa.record_logs();
        for (i, cpu) in [100.0, 120.0, 90.0].iter().enumerate() {
            let mp = metrics_with(*cpu, 1);
            ppa.evaluate(i as Time * 20 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        }
        // naive: predicts last value; records pair on next tick.
        assert_eq!(ppa.prediction_log.len(), 2);
        assert_eq!(ppa.prediction_log[0].predicted, 100.0);
        assert_eq!(ppa.prediction_log[0].actual, 120.0);
        assert_eq!(ppa.prediction_log[1].predicted, 120.0);
        assert_eq!(ppa.prediction_log[1].actual, 90.0);
        let mse = ppa.prediction_mse();
        assert!((mse - (400.0 + 900.0) / 2.0).abs() < 1e-9);
        assert_eq!(ppa.prediction_count(), 2);
    }

    #[test]
    fn logs_stay_empty_unless_recorded() {
        // Control-plane memory regression: without the opt-in, neither
        // per-tick log grows — only the streaming MSE moments do.
        let cluster = cluster_fixture(1);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        for i in 0..50u64 {
            let mp = metrics_with(100.0 + i as f64, 1);
            ppa.evaluate(i * 20 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        }
        assert!(ppa.prediction_log.is_empty(), "prediction log is opt-in");
        assert!(ppa.decision_log.is_empty(), "decision log is opt-in");
        assert_eq!(ppa.prediction_count(), 49, "streaming pairs still close");
        assert!(ppa.prediction_mse() > 0.0);
    }

    #[test]
    fn model_update_clears_history() {
        let cluster = cluster_fixture(1);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        for i in 0..20 {
            let mp = metrics_with(100.0, 1);
            ppa.evaluate(i * 20 * SEC, ServiceId(0), DeploymentId(0), &mp, &cluster);
        }
        assert_eq!(ppa.formulator.history().len(), 20);
        ppa.model_update(100 * SEC).unwrap();
        assert_eq!(
            ppa.formulator.history().len(),
            0,
            "updater must clear the metrics history file"
        );
    }

    #[test]
    fn dead_metric_clamped_to_min_replicas() {
        // Regression (scale-to-zero leak): with a NaN/zero metric the
        // old PPA path could decide 1 even when the deployment's floor
        // was higher; the combine stage now clamps to min_replicas.
        let cluster = cluster_fixture_min(3, 3);
        let mut ppa = Ppa::new(PpaConfig::default(), Box::new(NaiveForecaster));
        let mut mp = MetricsPipeline::new(10 * SEC, 1);
        mp.test_set_latest(ServiceId(0), [f64::NAN; METRIC_DIM], 3);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.desired, 3, "min_replicas floor holds on dead metrics");
        assert_eq!(d.recommendations[0].desired, 1, "policy floor is 1");
    }

    #[test]
    fn multi_metric_combines_max() {
        let cluster = cluster_fixture(2);
        let cfg = PpaConfig {
            specs: vec![
                MetricSpec::forecast(M_CPU, 70.0),
                MetricSpec::forecast(M_REQ_RATE, 1.0),
            ],
            ..PpaConfig::default()
        };
        let mut ppa = Ppa::new(cfg, Box::new(NaiveForecaster));
        let mut mp = MetricsPipeline::new(10 * SEC, 1);
        let mut v = [0.0; METRIC_DIM];
        v[M_CPU] = 70.0; // alone: 1 replica
        v[M_REQ_RATE] = 3.5; // alone: 4 replicas
        mp.test_set_latest(ServiceId(0), v, 2);
        let d = ppa.evaluate(0, ServiceId(0), DeploymentId(0), &mp, &cluster);
        assert_eq!(d.recommendations[0].desired, 1);
        assert_eq!(d.recommendations[1].desired, 4);
        assert_eq!(d.desired, 4, "req_rate spec drives the fleet up");
        assert_eq!(d.key_value, 70.0, "primary metric is the first spec");
    }
}

//! Extensibility demo (paper §4.3 / §7): inject a *custom* static policy
//! and a *custom* forecaster into the PPA — the two extension points the
//! paper advertises ("users may inject their own policies" / "custom
//! models ... following protocols of the helper interface").
//!
//! The custom bits here: an EWMA forecaster (a user model that follows
//! the Forecaster protocol) and a queue-aware static policy that adds a
//! replica when the key metric is rising fast.
//!
//! ```bash
//! cargo run --release --example custom_policy
//! ```

use ppa_edge::app::TaskCosts;
use ppa_edge::autoscaler::ppa::StaticPolicy;
use ppa_edge::autoscaler::{eq1_replicas, Ppa, PpaConfig};
use ppa_edge::config::quickstart_cluster;
use ppa_edge::experiments::SimWorld;
use ppa_edge::forecast::{Forecaster, UpdatePolicy};
use ppa_edge::metrics::METRIC_DIM;
use ppa_edge::sim::MIN;
use ppa_edge::workload::{Generator, RandomAccessGen};

/// A user-supplied model: exponentially weighted moving average with a
/// trend term. Follows the `Forecaster` protocol, so the PPA can load,
/// predict with, and "retrain" (re-smooth) it like any other model.
struct EwmaForecaster {
    alpha: f64,
    level: Option<[f64; METRIC_DIM]>,
    trend: [f64; METRIC_DIM],
}

impl EwmaForecaster {
    fn new(alpha: f64) -> Self {
        EwmaForecaster {
            alpha,
            level: None,
            trend: [0.0; METRIC_DIM],
        }
    }
}

impl Forecaster for EwmaForecaster {
    fn name(&self) -> &str {
        "custom-ewma"
    }

    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        let last = history.last()?;
        match &mut self.level {
            None => {
                self.level = Some(*last);
            }
            Some(level) => {
                for i in 0..METRIC_DIM {
                    let new_level = self.alpha * last[i] + (1.0 - self.alpha) * level[i];
                    self.trend[i] =
                        0.3 * (new_level - level[i]) + 0.7 * self.trend[i];
                    level[i] = new_level;
                }
            }
        }
        let level = self.level.as_ref().unwrap();
        let mut out = [0.0; METRIC_DIM];
        for i in 0..METRIC_DIM {
            out[i] = (level[i] + self.trend[i]).max(0.0);
        }
        Some(out)
    }

    fn retrain(
        &mut self,
        _history: &[[f64; METRIC_DIM]],
        _policy: UpdatePolicy,
    ) -> anyhow::Result<()> {
        // Stateless smoother: nothing to retrain.
        Ok(())
    }
}

/// A user-supplied static policy: Eq 1 plus one spare replica whenever
/// the predicted key metric implies >90% utilization of the Eq-1 count.
struct HeadroomPolicy;

impl StaticPolicy for HeadroomPolicy {
    fn name(&self) -> &str {
        "headroom"
    }

    fn replicas(
        &self,
        key_value: f64,
        current_key: f64,
        threshold: f64,
        _current: usize,
    ) -> usize {
        let key = key_value.max(current_key);
        let base = eq1_replicas(key, threshold).max(1);
        let utilization = key / (base as f64 * threshold);
        if utilization > 0.9 {
            base + 1
        } else {
            base
        }
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = quickstart_cluster();
    let mut world = SimWorld::build(&cfg, TaskCosts::default(), 7);
    world.add_generator(Generator::RandomAccess(RandomAccessGen::new(1)));

    for svc in 0..world.app.services.len() {
        let ppa = Ppa::new(
            PpaConfig::default(),
            Box::new(EwmaForecaster::new(0.5)),
        )
        .with_policy(Box::new(HeadroomPolicy));
        world.add_scaler(Box::new(ppa), svc);
    }

    let events = world.run_until(40 * MIN);
    let sort = world.app.stats.sort.summary();
    println!("custom model + custom policy run: {events} events");
    println!(
        "sort response: {:.3} ± {:.3} s over {} requests",
        sort.mean, sort.std, sort.n
    );
    println!("(both extension points of the paper exercised: ModelLink-style injected model, custom Static Policy)");
    Ok(())
}

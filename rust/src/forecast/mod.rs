//! Time-series forecasters for the PPA (paper §4.2.2 model protocol).
//!
//! Every model consumes the 5-metric protocol vector history and predicts
//! the next control-loop's full vector (the protocol: "the model should
//! predict all input variables"). Implementations:
//!
//! * [`LstmForecaster`] — the paper's optimal model: the AOT-compiled
//!   JAX/Pallas LSTM executed via PJRT ([`crate::runtime`]).
//! * [`ArmaForecaster`] — the paper's baseline: per-series ARMA(1,1)
//!   fitted from scratch by conditional-sum-of-squares (what statsmodels
//!   did in the paper's stack).
//! * [`NaiveForecaster`] — last-value persistence (sanity floor).

pub mod arma;
pub mod lstm;
pub mod scaler;
pub mod window;

pub use arma::ArmaForecaster;
pub use lstm::LstmForecaster;
pub use scaler::{MinMaxScaler, Scaler, StandardScaler};

use crate::metrics::METRIC_DIM;

/// The paper's three model-update policies (§4.2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdatePolicy {
    /// Policy 1: never retrain; the seed model runs forever.
    KeepSeed,
    /// Policy 2: drop the model, retrain from scratch on the history file.
    RetrainScratch,
    /// Policy 3: fine-tune the current model for extra epochs on the
    /// history file (paper's winner).
    FineTune,
}

impl UpdatePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            UpdatePolicy::KeepSeed => "policy1-keep-seed",
            UpdatePolicy::RetrainScratch => "policy2-retrain-scratch",
            UpdatePolicy::FineTune => "policy3-fine-tune",
        }
    }
}

/// A one-step-ahead multivariate forecaster.
pub trait Forecaster {
    fn name(&self) -> &str;

    /// Predict the next protocol vector from chronological `history`
    /// (most recent last). `None` when the model cannot predict (not
    /// enough history, invalid model file) — Algorithm 1 then falls back
    /// to the current metric ("Robust" property).
    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]>;

    /// Apply a model-update-loop step with the given policy over the
    /// metrics-history file contents.
    fn retrain(
        &mut self,
        history: &[[f64; METRIC_DIM]],
        policy: UpdatePolicy,
    ) -> crate::Result<()>;

    /// Feed back the realized vector for the instant the last prediction
    /// targeted (confidence calibration; default no-op).
    fn observe(&mut self, _actual: &[f64; METRIC_DIM]) {}

    /// Whether the model produces calibrated uncertainty (Algorithm 1's
    /// confidence gate).
    fn is_bayesian(&self) -> bool {
        false
    }

    /// Confidence of the last prediction in [0, 1] (only meaningful when
    /// `is_bayesian`).
    fn confidence(&self) -> f64 {
        1.0
    }
}

/// Last-value persistence baseline.
#[derive(Debug, Default)]
pub struct NaiveForecaster;

impl Forecaster for NaiveForecaster {
    fn name(&self) -> &str {
        "naive-last-value"
    }

    fn predict(&mut self, history: &[[f64; METRIC_DIM]]) -> Option<[f64; METRIC_DIM]> {
        history.last().copied()
    }

    fn retrain(
        &mut self,
        _history: &[[f64; METRIC_DIM]],
        _policy: UpdatePolicy,
    ) -> crate::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_predicts_last() {
        let mut f = NaiveForecaster;
        let h = vec![[1.0; METRIC_DIM], [2.0; METRIC_DIM]];
        assert_eq!(f.predict(&h), Some([2.0; METRIC_DIM]));
        assert_eq!(f.predict(&[]), None);
        assert!(f.retrain(&h, UpdatePolicy::FineTune).is_ok());
        assert!(!f.is_bayesian());
    }

    #[test]
    fn policy_names() {
        assert!(UpdatePolicy::KeepSeed.name().contains("policy1"));
        assert!(UpdatePolicy::RetrainScratch.name().contains("policy2"));
        assert!(UpdatePolicy::FineTune.name().contains("policy3"));
    }
}

//! The NASA-KSC trace substitute (paper §5.2.2, Fig 6).
//!
//! The paper replays a 2-day subset of the NASA Kennedy Space Center WWW
//! logs (July 1995), bucketed per minute and scaled so the peak fits the
//! edge testbed. That dataset is not redistributable here, so
//! [`nasa_synthetic`] generates a trace with the same *shape*: two diurnal
//! cycles with an afternoon peak, a deep overnight trough (day/night ratio
//! ≈ 3.5x), short-timescale Poisson jitter, and occasional bursts — the
//! properties that actually drive autoscaler behaviour. If you have the
//! real logs, preprocess them to per-minute counts (one integer per line)
//! and feed them through [`load_minute_counts`] instead.

use crate::util::rng::Pcg64;
use std::f64::consts::PI;
use std::path::Path;

/// Shape parameters for the synthetic NASA-like trace.
#[derive(Debug, Clone, Copy)]
pub struct NasaTraceConfig {
    /// Trace length in minutes (paper: 2 days).
    pub minutes: usize,
    /// Peak requests/minute after scaling (paper: scaled so the peak does
    /// not exceed the edge capacity).
    pub peak_per_minute: f64,
    /// Trough-to-peak ratio (NASA KSC shows ~0.25–0.35 overnight).
    pub trough_ratio: f64,
    /// Hour of the daily peak (local time; KSC logs peak mid-afternoon).
    pub peak_hour: f64,
    /// Relative short-term noise (std of multiplicative jitter).
    pub noise: f64,
    /// Expected number of burst events per day.
    pub bursts_per_day: f64,
    pub seed: u64,
}

impl Default for NasaTraceConfig {
    fn default() -> Self {
        NasaTraceConfig {
            minutes: 2 * 24 * 60,
            // Scaled so the peak sweeps the edge pools through their full
            // replica range while the cloud Eigen pool stays at (but
            // within) its Table-2 capacity — the paper's "adjusted to a
            // proper scale so that the peak workload does not exceed
            // resource limitations".
            peak_per_minute: 260.0,
            trough_ratio: 0.2,
            peak_hour: 15.0,
            noise: 0.10,
            bursts_per_day: 3.0,
            seed: 1995,
        }
    }
}

/// Generate per-minute request counts with the NASA trace's shape.
pub fn nasa_synthetic(cfg: &NasaTraceConfig) -> Vec<f64> {
    let mut rng = Pcg64::new(cfg.seed, 1995);
    let mut counts = Vec::with_capacity(cfg.minutes);

    // Pre-draw burst windows: (start_minute, length_minutes, amplitude).
    let days = cfg.minutes as f64 / 1440.0;
    let n_bursts = rng.poisson(cfg.bursts_per_day * days) as usize;
    let bursts: Vec<(usize, usize, f64)> = (0..n_bursts)
        .map(|_| {
            let start = rng.below(cfg.minutes as u64) as usize;
            let len = rng.int_range(5, 30) as usize;
            let amp = rng.range(1.3, 2.0);
            (start, len, amp)
        })
        .collect();

    // Slow day-to-day drift (the two NASA days differ slightly).
    let day_gain: Vec<f64> = (0..days.ceil() as usize + 1)
        .map(|_| rng.range(0.9, 1.1))
        .collect();

    for m in 0..cfg.minutes {
        let hour = (m as f64 / 60.0) % 24.0;
        // Diurnal base: cosine dipped at (peak_hour + 12) mod 24.
        let phase = (hour - cfg.peak_hour) / 24.0 * 2.0 * PI;
        let diurnal = 0.5 * (1.0 + phase.cos()); // 1 at peak, 0 at trough
        let base = cfg.trough_ratio + (1.0 - cfg.trough_ratio) * diurnal;

        let mut v = cfg.peak_per_minute * base * day_gain[m / 1440];
        for &(start, len, amp) in &bursts {
            if m >= start && m < start + len {
                v *= amp;
            }
        }
        // Multiplicative jitter + Poisson integerization.
        let jittered = (v * (1.0 + cfg.noise * rng.normal())).max(0.0);
        counts.push(rng.poisson(jittered) as f64);
    }
    counts
}

/// Load per-minute counts from a preprocessed text file (one count per
/// line, `#` comments allowed) — the path for users who have the real
/// NASA logs.
pub fn load_minute_counts(path: &Path) -> crate::Result<Vec<f64>> {
    use anyhow::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut counts = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v: f64 = line
            .parse()
            .with_context(|| format!("bad count on line {}", i + 1))?;
        anyhow::ensure!(v >= 0.0 && v.is_finite(), "negative count on line {}", i + 1);
        counts.push(v);
    }
    anyhow::ensure!(!counts.is_empty(), "empty trace file");
    Ok(counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_has_diurnal_shape() {
        let cfg = NasaTraceConfig::default();
        let counts = nasa_synthetic(&cfg);
        assert_eq!(counts.len(), 2880);

        // Average around the configured peak hour vs the trough.
        let hour_mean = |h: f64| -> f64 {
            let m0 = (h * 60.0) as usize;
            (0..60).map(|i| counts[m0 + i]).sum::<f64>() / 60.0
        };
        let peak_day1 = hour_mean(cfg.peak_hour);
        let trough_day1 = hour_mean((cfg.peak_hour + 12.0) % 24.0);
        assert!(
            peak_day1 > 2.0 * trough_day1,
            "peak {peak_day1} vs trough {trough_day1}"
        );
        // Peak roughly at configured scale.
        assert!(peak_day1 > cfg.peak_per_minute * 0.6);
    }

    #[test]
    fn synthetic_deterministic_per_seed() {
        let cfg = NasaTraceConfig::default();
        assert_eq!(nasa_synthetic(&cfg), nasa_synthetic(&cfg));
        let other = NasaTraceConfig {
            seed: 7,
            ..NasaTraceConfig::default()
        };
        assert_ne!(nasa_synthetic(&cfg), nasa_synthetic(&other));
    }

    #[test]
    fn synthetic_nonnegative() {
        let counts = nasa_synthetic(&NasaTraceConfig::default());
        assert!(counts.iter().all(|&c| c >= 0.0));
    }

    #[test]
    fn loads_counts_file() {
        let dir = std::env::temp_dir().join("ppa_nasa_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("counts.txt");
        std::fs::write(&path, "# header\n10\n20\n\n30\n").unwrap();
        let counts = load_minute_counts(&path).unwrap();
        assert_eq!(counts, vec![10.0, 20.0, 30.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_file() {
        let dir = std::env::temp_dir().join("ppa_nasa_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "abc\n").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::write(&path, "-5\n").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::write(&path, "").unwrap();
        assert!(load_minute_counts(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

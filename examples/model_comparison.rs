//! Fig 7 driver: inject different predictive models (AOT-compiled
//! JAX/Pallas LSTM vs from-scratch ARMA) into the PPA and compare their
//! live prediction quality on the running application.
//!
//! ```bash
//! cargo run --release --example model_comparison           # paper scale
//! cargo run --release --example model_comparison -- 30 1   # 30 min, 1 h pretrain
//! ```

use ppa_edge::experiments::{fig7_model_comparison, fig8_update_policies, FigParams};
use ppa_edge::report;

fn main() -> anyhow::Result<()> {
    let minutes: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200);
    let pretrain_hours: f64 = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(10.0);
    let params = FigParams {
        minutes,
        pretrain_hours,
        seed: 2021,
    };

    println!("Fig 7 (model comparison): {minutes} min runs, {pretrain_hours} h pretraining");
    let fig7 = fig7_model_comparison(&params)?;
    report::print_fig7(&fig7);

    println!("\nFig 8 (update policies): same configuration, LSTM model");
    let fig8 = fig8_update_policies(&params)?;
    report::print_fig8(&fig8);
    Ok(())
}
